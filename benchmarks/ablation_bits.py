"""Beyond-paper ablation (paper §6 future work, "Ultra-low Bit
Verification"): at what weight precision does verification-accuracy
degradation outweigh the bandwidth gain?

Sweeps the verifier over {BF16, W8A8, W4A8}: measures logit fidelity and
acceptance length L, models the Eq. 13 speedup with the corresponding
weight-streaming bytes (2 / 1 / 0.5 B per param).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import QuantConfig, SpecConfig
from repro.data import lm_batches
from repro.quant import quantize_params

from benchmarks.common import HBM_BW, LatencyModel, get_trained, run_engine, save_json


def rows(quick: bool = False):
    model, params, _ = get_trained("qwen3-sub")
    cfg = model.cfg
    scfg = SpecConfig(gamma=5, temperature=0.0)
    lat = LatencyModel()

    variants = [
        ("bf16", params, 16),
        ("w8a8", quantize_params(params, _calib(model, params), QuantConfig()), 8),
        ("w4a8", quantize_params(params, _calib(model, params),
                                 QuantConfig(w_bits=4)), 4),
    ]
    toks = jnp.asarray(next(lm_batches(4, 64, cfg.vocab_size, seed=3))["tokens"])
    lf, _ = model.forward(params, toks)
    p_ref = jax.nn.softmax(lf, -1)

    out = []
    for name, vp, bits in variants:
        lq, _ = model.forward(vp, toks)
        kl = float(jnp.mean(jnp.sum(
            p_ref * (jnp.log(p_ref + 1e-9) - jax.nn.log_softmax(lq, -1)), -1)))
        top1 = float(jnp.mean(
            (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
        r = run_engine(model, vp, mode="spec", scfg=scfg, task="gsm8k")
        # Eq. 11/12 with bits-proportional weight streaming
        n = lat.cfg.active_param_count()
        t_w = n * bits / 8 / HBM_BW
        out.append({
            "verifier": name,
            "kl_vs_bf16": round(kl, 6),
            "top1_agreement": round(top1, 4),
            "L": round(r["L"], 3),
            "weight_stream_ms_7b": round(t_w * 1e3, 2),
            "modeled_speedup": round(
                lat.speedup(r["L"], 5, verifier_bits=bits), 3),
        })
    save_json("ablation_bits.json", out)
    return out


def _calib(model, params):
    collect = {}
    toks = jnp.asarray(next(lm_batches(4, 96, model.cfg.vocab_size, seed=1,
                                       markov_alpha=0.97))["tokens"])
    model.forward(params, toks, collect=collect)
    return collect


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
