"""Quantized flash verification ablation: int8 vs bf16 KV cache.

The paper's Eq. 11-12 memory term counts weight streaming; at long
context the *cache read* is the larger half of verification HBM traffic
(§Roofline, decode_32k).  This ablation extends the bandwidth argument
to the KV cache:

* **modeled** — ``roofline.kv_cache_read_bytes`` at paper scale
  (quasar-paper-7b) swept over context ∈ {2k, 8k, 32k}: int8 halves the
  K/V payload (≈0.53× including the f32 scale rows) and the Eq. 13
  speedup with the measured L follows;
* **measured fidelity** — acceptance length L on the CPU stand-in model
  with ``kv_cache_dtype`` bf16 vs int8 (same weights, same prompts): the
  quantization fidelity cost speculative decoding actually pays;
* **measured step time** — CPU wall time of ``attend`` over long caches
  at a KV_CHUNK-aligned and a non-aligned S: both must take the chunked
  online-softmax path (the non-aligned case used to fall back silently
  to the O(B·H·T·S) direct path — the padding fix keeps it chunked);
* **paged layout** — the *capacity* half of the bandwidth argument
  (``kv_layout="paged"``, ``core/paged_cache.py``): a mixed-length
  request workload modeled at paper scale (contiguous worst-case slots
  vs block-granular demand) plus a measured CPU run of the scheduler
  under both layouts — actual cache-pytree bytes, throughput, and the
  bit-equality of the served tokens;
* **shared prefix** — the prefix-cache extension of the capacity
  argument (``kv_prefix_sharing``): a long common system prompt × N
  requests, modeled at paper scale and measured on the CPU scheduler
  run sharing on vs off — peak unique-block high-water must shrink
  > 2× with bit-identical tokens (the ``--smoke`` CI gate).

Results land in ``benchmarks/results/ablation_kv.json``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.launch.roofline import kv_cache_capacity_bytes, kv_cache_read_bytes
from repro.models import Model
from repro.models.attention import CHUNK_THRESHOLD, KV_CHUNK, _quant_kv, attend
from repro.serving import GenerationRequest, SpecEngine

from benchmarks.common import LatencyModel, get_trained, run_engine, save_json

CONTEXTS = [2048, 8192, 32768]
GAMMA = 5

# mixed-length serving workload (tokens incl. budget): a long-tail mix —
# mostly chat-sized requests, one 8k and one near-32k outlier, the shape
# that makes worst-case contiguous slot sizing pay 32k rows for everyone
MIXED_DEMANDS = [224, 480, 1310, 2100, 310, 640, 8200, 31900]


def _measured_L(quick: bool):
    """Acceptance length with bf16 vs int8 KV on the trained stand-in."""
    model, params, _ = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=GAMMA, temperature=0.0)
    new_tokens = 16 if quick else 24
    out = {}
    for kv in ("bf16", "int8"):
        m = Model(dataclasses.replace(model.cfg, kv_cache_dtype=kv))
        r = run_engine(m, params, mode="spec", scfg=scfg, task="gsm8k",
                       new_tokens=new_tokens)
        out[kv] = r["L"]
    return out


def _time_attend(S: int, kv: str, *, iters: int = 8):
    """CPU wall μs of one jitted attend over an S-token cache (T=γ+1)."""
    B, T, Hkv, G, dh = 1, GAMMA + 1, 2, 2, 32
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, Hkv * G, dh))
    k = jax.random.normal(kk, (B, S, Hkv, dh))
    v = jax.random.normal(kv_, (B, S, Hkv, dh))
    qpos = jnp.tile(jnp.arange(S - T, S)[None], (B, 1))
    kpos = jnp.arange(S, dtype=jnp.int32)
    if kv == "int8":
        k, ks = _quant_kv(k)
        v, vs = _quant_kv(v)
    else:
        ks = vs = None
    fn = jax.jit(lambda *a: attend(a[0], a[1], a[2], a[3], a[4],
                                   k_scale=ks, v_scale=vs, impl="jnp"))
    o = fn(q, k, v, qpos, kpos)
    jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(iters):
        o = fn(q, k, v, qpos, kpos)
    jax.block_until_ready(o)
    return (time.perf_counter() - t0) / iters * 1e6


def _paged_rows(quick: bool):
    """Paged-vs-contiguous KV footprint + throughput at mixed lengths."""
    # -- modeled at paper scale: 8 concurrent requests, 32k-capable group
    cfg = get_config("quasar-paper-7b")
    max_len = 32768
    out = {"workload_tokens": MIXED_DEMANDS}
    for kv in ("bf16", "int8"):
        cont = kv_cache_capacity_bytes(cfg, MIXED_DEMANDS, max_len, kv,
                                       layout="contiguous")
        paged = kv_cache_capacity_bytes(cfg, MIXED_DEMANDS, max_len, kv,
                                        layout="paged")
        out[f"modeled_{kv}"] = {
            "contiguous_gbytes": round(cont / 1e9, 3),
            "paged_gbytes": round(paged / 1e9, 3),
            "paged_vs_contiguous": round(paged / cont, 4),
        }

    # -- measured on the CPU stand-in: same scheduler run, both layouts
    model, params, _ = get_trained("qwen3-sub")
    rng = np.random.default_rng(5)
    pat = rng.integers(0, model.cfg.vocab_size, 8)
    # heterogeneous prompts/budgets: one long request pins the group buf
    spec = [(24, 8), (4, 6), (6, 10), (3, 4), (5, 8), (2, 5)] if not quick \
        else [(24, 8), (4, 6), (3, 4)]
    reqs = [GenerationRequest(np.tile(pat, k), max_new_tokens=n, seed=i)
            for i, (k, n) in enumerate(spec)]
    scfg = SpecConfig(gamma=GAMMA, temperature=0.0)
    measured = {}
    tokens = {}
    for layout in ("contiguous", "paged"):
        sc = dataclasses.replace(scfg, kv_layout=layout, kv_block_size=32)
        eng = SpecEngine(model, sc, drafter="ngram", verifier="bf16")
        eng.generate_requests(params, reqs, batch_slots=3)    # compile
        t0 = time.perf_counter()
        res = eng.generate_requests(params, reqs, batch_slots=3)
        wall = time.perf_counter() - t0
        tokens[layout] = [r.tokens.tolist() for r in res]
        new_tokens = sum(r.new_tokens for r in res)
        # the cache bytes the engine ACTUALLY allocated for this group
        # (engine.group_stats — no re-derived sizing that could drift)
        measured[layout] = {
            "cache_bytes": sum(g["cache_bytes"] for g in eng.group_stats),
            "cpu_tok_s": round(new_tokens / max(wall, 1e-9), 1),
        }
    measured["paged_vs_contiguous_bytes"] = round(
        measured["paged"]["cache_bytes"]
        / measured["contiguous"]["cache_bytes"], 4)
    measured["tokens_bit_identical"] = tokens["paged"] == tokens["contiguous"]
    out["measured_cpu"] = measured
    return out


def _shared_prefix_rows(quick: bool):
    """Prefix-cache capacity: long common system prompt × N requests.

    The production-dominant workload — every request carries the same
    long system prompt plus a short unique tail.  With
    ``kv_prefix_sharing`` the prompt's full blocks are stored once for
    the whole group (``core/paged_cache.PrefixIndex`` + refcounted
    ``BlockPool``); without it every admission re-stores its full
    prompt.  ``effective_capacity`` is the unshared/shared ratio of the
    pool's peak unique-block high-water — how many more concurrent
    requests the same HBM serves.  The smoke assertion (CI) requires
    > 2x and bit-identical tokens with sharing on vs off.
    """
    # -- modeled at paper scale: 2k system prompt, 256-token tails
    cfg = get_config("quasar-paper-7b")
    n_req, sys_tokens, tail = 8, 2048, 256
    demands = [sys_tokens + tail] * n_req
    modeled = {}
    for kv in ("bf16", "int8"):
        unshared = kv_cache_capacity_bytes(cfg, demands, 32768, kv,
                                           layout="paged")
        shared = kv_cache_capacity_bytes(cfg, demands, 32768, kv,
                                         layout="paged",
                                         shared_prefix_tokens=sys_tokens)
        modeled[f"modeled_{kv}"] = {
            "unshared_gbytes": round(unshared / 1e9, 3),
            "shared_gbytes": round(shared / 1e9, 3),
            "effective_capacity": round(unshared / shared, 2),
        }

    # -- measured on the CPU stand-in: same scheduler run, sharing on/off
    model, params, _ = get_trained("qwen3-sub")
    rng = np.random.default_rng(11)
    n = 4 if quick else 6
    system = rng.integers(0, model.cfg.vocab_size, 96)
    reqs = [GenerationRequest(
                np.concatenate([system,
                                rng.integers(0, model.cfg.vocab_size, 6)]),
                max_new_tokens=6, seed=i)
            for i in range(n)]
    scfg = SpecConfig(gamma=GAMMA, temperature=0.0, kv_layout="paged",
                      kv_block_size=16)
    measured = {}
    tokens = {}
    for label, sharing in (("unshared", False), ("shared", True)):
        sc = dataclasses.replace(scfg, kv_prefix_sharing=sharing)
        eng = SpecEngine(model, sc, drafter="ngram", verifier="bf16")
        eng.generate_requests(params, reqs, batch_slots=n)    # compile
        t0 = time.perf_counter()
        res = eng.generate_requests(params, reqs, batch_slots=n)
        wall = time.perf_counter() - t0
        tokens[label] = [r.tokens.tolist() for r in res]
        new_tokens = sum(r.new_tokens for r in res)
        g = eng.group_stats[0]
        measured[label] = {
            "peak_blocks": g["peak_blocks"],
            "shared_blocks": g["shared_blocks"],
            "cpu_tok_s": round(new_tokens / max(wall, 1e-9), 1),
        }
    measured["effective_capacity"] = round(
        measured["unshared"]["peak_blocks"]
        / max(measured["shared"]["peak_blocks"], 1), 2)
    measured["tokens_bit_identical"] = \
        tokens["shared"] == tokens["unshared"]
    return {**modeled, "workload": {"n_requests": n,
                                    "system_prompt_tokens": 96,
                                    "tail_tokens": 6},
            "measured_cpu": measured}


def rows(quick: bool = False):
    cfg = get_config("quasar-paper-7b")
    contexts = CONTEXTS[:1] + CONTEXTS[-1:] if quick else CONTEXTS

    ls = _measured_L(quick)
    modeled = []
    for ctx in contexts:
        lat = LatencyModel(context=ctx)
        bf16_bytes = kv_cache_read_bytes(cfg, 1, ctx, "bf16")
        for kv, kv_bits in (("bf16", 16), ("int8", 8)):
            b = kv_cache_read_bytes(cfg, 1, ctx, kv)
            modeled.append({
                "context": ctx,
                "kv_cache": kv,
                "kv_read_gbytes": round(b / 1e9, 4),
                "kv_bytes_vs_bf16": round(b / bf16_bytes, 4),
                "t_verify_ms": round(
                    lat.t_verify(GAMMA, 8, kv_bits) * 1e3, 4),
                "modeled_speedup": round(
                    lat.speedup(ls[kv], GAMMA, verifier_bits=8,
                                kv_bits=kv_bits), 3),
            })

    acceptance = [{"kv_cache": kv, "L": round(L, 3),
                   "L_delta_vs_bf16": round(L - ls["bf16"], 4)}
                  for kv, L in ls.items()]

    # chunk-padding fix: aligned and non-aligned long caches both take the
    # online-softmax path — comparable step time, no O(S)-scores blow-up
    s_aligned = CHUNK_THRESHOLD + KV_CHUNK          # 5120
    s_odd = CHUNK_THRESHOLD + KV_CHUNK // 2 + 79    # 4687, non-aligned
    assert s_aligned % KV_CHUNK == 0 and s_odd % KV_CHUNK != 0
    assert min(s_aligned, s_odd) > CHUNK_THRESHOLD  # both take chunked path
    cpu_step = [{"S": S, "kv_cache": kv, "aligned": S % KV_CHUNK == 0,
                 "attend_us": round(_time_attend(S, kv,
                                                 iters=4 if quick else 8), 1)}
                for S in (s_aligned, s_odd) for kv in ("bf16", "int8")]

    out = {"modeled": modeled, "acceptance": acceptance,
           "cpu_step": cpu_step, "paged": _paged_rows(quick),
           "shared_prefix": _shared_prefix_rows(quick)}
    save_json("ablation_kv.json", out)
    return out


def _print_section(section, rs):
    print(f"-- {section}")
    if isinstance(rs, dict):
        for k, v in rs.items():
            print(f"{k}: {v}")
    else:
        for r in rs:
            print(r)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: run only the shared-prefix section at "
                         "quick scale and assert >2x effective capacity "
                         "with bit-identical tokens")
    args = ap.parse_args()
    if args.smoke:
        sp = _shared_prefix_rows(quick=True)
        _print_section("shared_prefix", sp)
        m = sp["measured_cpu"]
        assert m["tokens_bit_identical"], \
            "prefix sharing changed generated tokens"
        assert m["effective_capacity"] > 2.0, \
            f"effective capacity {m['effective_capacity']} <= 2x"
        print("smoke OK: effective_capacity="
              f"{m['effective_capacity']}x, tokens bit-identical")
        return
    out = rows()
    for section, rs in out.items():
        _print_section(section, rs)


if __name__ == "__main__":
    main()
