"""Quantized flash verification ablation: int8 vs bf16 KV cache.

The paper's Eq. 11-12 memory term counts weight streaming; at long
context the *cache read* is the larger half of verification HBM traffic
(§Roofline, decode_32k).  This ablation extends the bandwidth argument
to the KV cache:

* **modeled** — ``roofline.kv_cache_read_bytes`` at paper scale
  (quasar-paper-7b) swept over context ∈ {2k, 8k, 32k}: int8 halves the
  K/V payload (≈0.53× including the f32 scale rows) and the Eq. 13
  speedup with the measured L follows;
* **measured fidelity** — acceptance length L on the CPU stand-in model
  with ``kv_cache_dtype`` bf16 vs int8 (same weights, same prompts): the
  quantization fidelity cost speculative decoding actually pays;
* **measured step time** — CPU wall time of ``attend`` over long caches
  at a KV_CHUNK-aligned and a non-aligned S: both must take the chunked
  online-softmax path (the non-aligned case used to fall back silently
  to the O(B·H·T·S) direct path — the padding fix keeps it chunked).

Results land in ``benchmarks/results/ablation_kv.json``.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.launch.roofline import kv_cache_read_bytes
from repro.models import Model
from repro.models.attention import CHUNK_THRESHOLD, KV_CHUNK, _quant_kv, attend

from benchmarks.common import LatencyModel, get_trained, run_engine, save_json

CONTEXTS = [2048, 8192, 32768]
GAMMA = 5


def _measured_L(quick: bool):
    """Acceptance length with bf16 vs int8 KV on the trained stand-in."""
    model, params, _ = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=GAMMA, temperature=0.0)
    new_tokens = 16 if quick else 24
    out = {}
    for kv in ("bf16", "int8"):
        m = Model(dataclasses.replace(model.cfg, kv_cache_dtype=kv))
        r = run_engine(m, params, mode="spec", scfg=scfg, task="gsm8k",
                       new_tokens=new_tokens)
        out[kv] = r["L"]
    return out


def _time_attend(S: int, kv: str, *, iters: int = 8):
    """CPU wall μs of one jitted attend over an S-token cache (T=γ+1)."""
    B, T, Hkv, G, dh = 1, GAMMA + 1, 2, 2, 32
    key = jax.random.PRNGKey(0)
    kq, kk, kv_ = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, Hkv * G, dh))
    k = jax.random.normal(kk, (B, S, Hkv, dh))
    v = jax.random.normal(kv_, (B, S, Hkv, dh))
    qpos = jnp.tile(jnp.arange(S - T, S)[None], (B, 1))
    kpos = jnp.arange(S, dtype=jnp.int32)
    if kv == "int8":
        k, ks = _quant_kv(k)
        v, vs = _quant_kv(v)
    else:
        ks = vs = None
    fn = jax.jit(lambda *a: attend(a[0], a[1], a[2], a[3], a[4],
                                   k_scale=ks, v_scale=vs, impl="jnp"))
    o = fn(q, k, v, qpos, kpos)
    jax.block_until_ready(o)
    t0 = time.perf_counter()
    for _ in range(iters):
        o = fn(q, k, v, qpos, kpos)
    jax.block_until_ready(o)
    return (time.perf_counter() - t0) / iters * 1e6


def rows(quick: bool = False):
    cfg = get_config("quasar-paper-7b")
    contexts = CONTEXTS[:1] + CONTEXTS[-1:] if quick else CONTEXTS

    ls = _measured_L(quick)
    modeled = []
    for ctx in contexts:
        lat = LatencyModel(context=ctx)
        bf16_bytes = kv_cache_read_bytes(cfg, 1, ctx, "bf16")
        for kv, kv_bits in (("bf16", 16), ("int8", 8)):
            b = kv_cache_read_bytes(cfg, 1, ctx, kv)
            modeled.append({
                "context": ctx,
                "kv_cache": kv,
                "kv_read_gbytes": round(b / 1e9, 4),
                "kv_bytes_vs_bf16": round(b / bf16_bytes, 4),
                "t_verify_ms": round(
                    lat.t_verify(GAMMA, 8, kv_bits) * 1e3, 4),
                "modeled_speedup": round(
                    lat.speedup(ls[kv], GAMMA, verifier_bits=8,
                                kv_bits=kv_bits), 3),
            })

    acceptance = [{"kv_cache": kv, "L": round(L, 3),
                   "L_delta_vs_bf16": round(L - ls["bf16"], 4)}
                  for kv, L in ls.items()]

    # chunk-padding fix: aligned and non-aligned long caches both take the
    # online-softmax path — comparable step time, no O(S)-scores blow-up
    s_aligned = CHUNK_THRESHOLD + KV_CHUNK          # 5120
    s_odd = CHUNK_THRESHOLD + KV_CHUNK // 2 + 79    # 4687, non-aligned
    assert s_aligned % KV_CHUNK == 0 and s_odd % KV_CHUNK != 0
    assert min(s_aligned, s_odd) > CHUNK_THRESHOLD  # both take chunked path
    cpu_step = [{"S": S, "kv_cache": kv, "aligned": S % KV_CHUNK == 0,
                 "attend_us": round(_time_attend(S, kv,
                                                 iters=4 if quick else 8), 1)}
                for S in (s_aligned, s_odd) for kv in ("bf16", "int8")]

    out = {"modeled": modeled, "acceptance": acceptance,
           "cpu_step": cpu_step}
    save_json("ablation_kv.json", out)
    return out


def main():
    out = rows()
    for section, rs in out.items():
        print(f"-- {section}")
        for r in rs:
            print(r)


if __name__ == "__main__":
    main()
