"""Shared benchmark harness.

CPU-scale methodology (this container has no TPU):

* **Acceptance lengths (L)** are *measured* — they are hardware-independent
  (they depend only on the token streams and the verifier's logits).
* **Wall-clock** is measured on CPU and reported for the spec-vs-vanilla
  structure (fewer verifier passes); it can NOT show the W8A8 bandwidth
  win (CPU has no int8 tensor cores — the int8 GEMM simulation is the
  same speed or slower than f32).
* **Modeled TPU speed** uses the paper's own latency model (Eq. 11-13)
  with TPU v5e constants and the measured L: per speculative step,
  T_verify = max(weight+cache bytes / HBM_bw, flops / peak), drafting cost
  per its kind.  This is the column compared against the paper's tables.

Two "target models" stand in for the paper's Qwen3-8B / OpenPangu-7B at
CPU-tractable scale (trained briefly on the synthetic Markov corpus so
logits have real structure); the modeled-speed column uses the *full*
paper-scale config (quasar-paper-7b) for the Eq. 11-13 byte counts.
"""
from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.data import lm_batches, task_prompts
from repro.models import Model
from repro.quant import quantize_params
from repro.serving.engine import SpecEngine
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
TASKS = ["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"]

# TPU v5e
HBM_BW = 819e9
PEAK_BF16 = 197e12
PEAK_INT8 = 394e12


# ---------------------------------------------------------------------------
# Small trained stand-in models (cached on disk)
# ---------------------------------------------------------------------------

_MODEL_DEFS = {
    # reduced smollm family ≈ "Qwen3" stand-in
    "qwen3-sub": ("smollm-135m", 0),
    # slightly different seed/init ≈ "OpenPangu" stand-in
    "openpangu-sub": ("smollm-135m", 7),
}


def get_trained(name: str, steps: int = 400):
    arch, seed = _MODEL_DEFS[name]
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    path = os.path.join(RESULTS_DIR, f"cache_{name}.npz")
    if os.path.exists(path):
        params = load_checkpoint(path)
    else:
        tr = Trainer(m, AdamWConfig(lr=1.5e-3, warmup_steps=20, total_steps=steps))
        params, opt = tr.init(jax.random.PRNGKey(seed))
        # fairly deterministic Markov corpus: a well-trained model then puts
        # high probability on in-pattern continuations, which is what makes
        # T=1 acceptance behave like the paper's real-LLM setting
        params, _, _ = tr.fit(params, opt,
                              lm_batches(8, 96, cfg.vocab_size, seed=seed,
                                         markov_alpha=0.97),
                              steps=steps, log_every=steps, log_fn=None)
        os.makedirs(RESULTS_DIR, exist_ok=True)
        save_checkpoint(path, params)
    # calibrate + quantize
    collect = {}
    batch = next(lm_batches(4, 96, cfg.vocab_size, seed=seed + 1,
                            markov_alpha=0.97))
    m.forward(params, jnp.asarray(batch["tokens"]), collect=collect)
    qparams = quantize_params(params, collect, QuantConfig())
    return m, params, qparams


# ---------------------------------------------------------------------------
# Eq. 11-13 analytic latency model (paper §3.4), paper-scale config
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LatencyModel:
    """Per-speculative-step verify/draft latency for the paper-scale model."""
    cfg: object = None
    batch: int = 1
    context: int = 1024

    def __post_init__(self):
        if self.cfg is None:
            self.cfg = get_config("quasar-paper-7b")

    def _weight_bytes(self, bits: int) -> float:
        n = self.cfg.active_param_count()
        return n * bits / 8 + (n / self.cfg.d_model) * 4.0  # + per-channel scales

    def _kv_bytes(self, kv_bits: int = 16) -> float:
        from repro.launch.roofline import kv_cache_read_bytes
        return kv_cache_read_bytes(
            self.cfg, self.batch, self.context,
            "int8" if kv_bits <= 8 else "bf16")

    def t_verify(self, gamma: int, bits: int, kv_bits: int = 16) -> float:
        """Eq. 11/12: memory term + compute term for a (γ+1)-token window.
        ``kv_bits=8`` models the int8 KV cache (halved K/V stream + f32
        scale rows, matching ``roofline.kv_cache_read_bytes``)."""
        c = self.cfg
        tokens = self.batch * (gamma + 1)
        mem = (self._weight_bytes(bits) + self._kv_bytes(kv_bits)) / HBM_BW
        peak = PEAK_INT8 if bits <= 8 else PEAK_BF16
        comp = 2.0 * c.active_param_count() * tokens / peak
        return max(mem, comp) + 20e-6  # fixed launch overhead

    def t_vanilla_token(self, bits: int = 16) -> float:
        return self.t_verify(0, bits)

    def t_draft_ngram(self) -> float:
        # on-device token-buffer scan: tiny vs a forward pass
        return (self.batch * self.context * 4 * 4) / HBM_BW + 10e-6

    def t_draft_pruned(self, gamma: int, retention: float, bits: int = 16) -> float:
        # γ sequential single-token decodes of the layer-dropped model
        return gamma * retention * self.t_vanilla_token(bits)

    def speedup(self, L: float, gamma: int, *, verifier_bits: int,
                drafter: str = "ngram", retention: float = 1.0,
                kv_bits: int = 16) -> float:
        """Eq. 13 vs the BF16 autoregressive baseline (bf16 weights + KV)."""
        t_v = self.t_verify(gamma, verifier_bits, kv_bits)
        t_d = (self.t_draft_ngram() if drafter == "ngram"
               else self.t_draft_pruned(gamma, retention))
        per_step = t_d + t_v
        return (L * self.t_vanilla_token(16)) / per_step


# ---------------------------------------------------------------------------
# Engine-run helper: measured L + CPU wall
# ---------------------------------------------------------------------------

def run_engine(model, params, *, mode=None, drafter=None, verifier=None,
               scfg, task="gsm8k", batch=2, prompt_len=48, new_tokens=24,
               seed=0, draft_params=None):
    """Measure one engine config.  ``drafter``/``verifier`` name registry
    plugins (``repro.core.protocols``); ``mode`` is the deprecated alias
    ("spec"|"vanilla"|"pruned") used by the seed-era tables.  Benchmarks
    pass pre-prepared params, so the default verifier is passthrough BF16
    — name ``verifier="w8a8"`` to let the engine quantize internally."""
    prompts = jnp.asarray(
        task_prompts(task, batch, prompt_len, model.cfg.vocab_size, seed=seed))
    if mode is not None:
        eng = SpecEngine(model, scfg, mode=mode,
                         drafter=drafter, verifier=verifier)
    else:
        eng = SpecEngine(model, scfg, drafter=drafter or scfg.drafter,
                         verifier=verifier or "bf16")
    # warm-up for compile, then measure
    r = eng.generate(params, prompts, new_tokens, key=jax.random.PRNGKey(seed),
                     draft_params=draft_params)
    t0 = time.perf_counter()
    r = eng.generate(params, prompts, new_tokens, key=jax.random.PRNGKey(seed + 1),
                     draft_params=draft_params)
    wall = time.perf_counter() - t0
    return {
        "L": r.mean_accept_len,
        "steps": r.steps,
        "cpu_tok_s": r.new_tokens / wall,
        "new_tokens": r.new_tokens,
    }


def save_json(name: str, obj) -> str:
    import json
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path
