"""Roofline report (deliverable g): reads the dry-run JSONs and emits the
per-(arch × shape × mesh) table plus the Eq. 11-12 verification-term
comparison (BF16 vs W8A8 weight streaming) that is the paper's central
quantitative claim."""
from __future__ import annotations

import glob
import json
import os

from repro.configs import get_config
from repro.launch.roofline import HBM_BW

from benchmarks.common import RESULTS_DIR, save_json


def load_dryrun_rows():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "dryrun_*.json"))):
        with open(path) as f:
            d = json.load(f)
        rows.extend(d.get("rows", []))
    # dedupe (arch, shape, mesh, verifier) keeping the latest
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"], r.get("verifier"))] = r
    return list(seen.values())


def eq11_12_table():
    """Analytic verify memory term per arch: M·2B vs M·1B over HBM (Eq. 11-12)."""
    out = []
    for arch in ["quasar-paper-7b", "stablelm-12b", "codeqwen1.5-7b",
                 "phi3.5-moe-42b-a6.6b", "moonshot-v1-16b-a3b"]:
        cfg = get_config(arch)
        n = cfg.active_param_count()
        t16 = n * 2 / HBM_BW
        t8 = n * 1 / HBM_BW
        out.append({
            "arch": arch, "active_params_B": round(n / 1e9, 2),
            "t_verify_mem_bf16_ms": round(t16 * 1e3, 3),
            "t_verify_mem_w8a8_ms": round(t8 * 1e3, 3),
            "ratio": round(t16 / t8, 3),
        })
    return out


def rows(quick: bool = False):
    dr = load_dryrun_rows()
    table = [{
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "dominant": r["dominant"],
        "t_compute_s": r["t_compute_s"], "t_memory_s": r["t_memory_s"],
        "t_collective_s": r["t_collective_s"],
        "useful_flops_ratio": r["useful_flops_ratio"],
        "temp_gb_per_dev": round(r["temp_bytes_per_dev"] / 1e9, 2),
    } for r in dr]
    out = {"roofline": table, "eq11_12": eq11_12_table()}
    save_json("roofline_report.json", out)
    return out


def main():
    out = rows()
    print(f"{len(out['roofline'])} dry-run rows")
    for r in out["eq11_12"]:
        print(r)


if __name__ == "__main__":
    main()
