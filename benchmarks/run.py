"""Benchmark harness entry point — one function per paper table.

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` is the measured
CPU wall time of one speculative serve step for that configuration (μs);
``derived`` is the table's headline metric (modeled TPU speedup, L, KL,
...).  Full rows land in benchmarks/results/*.json.

``python -m benchmarks.run [--quick]``
"""
from __future__ import annotations

import argparse
import sys
import time


def _bench_step_us() -> float:
    """One speculative serve step, CPU wall μs (jitted, post-warmup)."""
    import jax
    import jax.numpy as jnp

    from repro.core.config import SpecConfig
    from repro.core.spec_engine import init_state, make_serve_step
    from benchmarks.common import get_trained

    model, params, qparams = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=5, temperature=0.0)
    step = jax.jit(make_serve_step(model, scfg))
    state = init_state(model, 2, 256, jax.random.PRNGKey(0))
    prompts = jnp.tile(jnp.arange(8, dtype=jnp.int32)[None], (2, 6))
    P = prompts.shape[1]
    state["tokens"] = state["tokens"].at[:, :P].set(prompts)
    state["length"] = jnp.full((2,), P, jnp.int32)
    state["cache"] = model.prefill(qparams, state["cache"], prompts[:, :-1])
    state = step(qparams, state)              # warmup/compile
    jax.block_until_ready(state["tokens"])
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        state = step(qparams, state)
    jax.block_until_ready(state["tokens"])
    return (time.perf_counter() - t0) / n * 1e6


def _acceptance_parity(quick: bool) -> str:
    """w8a8-vs-bf16 verify acceptance parity from *live* engine telemetry
    (``SpecEngine.telemetry`` accepted-length histograms), not offline
    tables — the paper's Table-1 invariant as a monitorable signal."""
    import jax.numpy as jnp

    from repro.core.config import SpecConfig
    from repro.data import task_prompts
    from repro.serving.engine import SpecEngine
    from benchmarks.common import get_trained

    model, params, _ = get_trained("qwen3-sub")
    prompts = jnp.asarray(
        task_prompts("gsm8k", 2, 48, model.cfg.vocab_size))
    new_tokens = 16 if quick else 64
    L = {}
    for verifier in ("bf16", "w8a8"):
        engine = SpecEngine(model, SpecConfig(gamma=5, temperature=0.0),
                            drafter="ngram", verifier=verifier)
        engine.generate(params, prompts, new_tokens)
        L[verifier] = engine.telemetry.mean_accept(f"ngram:{verifier}")
    return (f"bf16_L={L['bf16']:.2f};w8a8_L={L['w8a8']:.2f};"
            f"delta={L['w8a8'] - L['bf16']:+.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced sweeps (CI-friendly)")
    args = ap.parse_args()

    from benchmarks import (
        ablation_bits,
        ablation_kv,
        table1_speedup,
        table2_temperature,
        table3_sensitivity,
        table4_accuracy,
        table5_pruning,
        table6_tree,
        roofline_report,
    )

    step_us = _bench_step_us()
    lines = []

    t1 = table1_speedup.rows(quick=args.quick)
    q = [r for r in t1 if r["method"] == "quasar" and r["T"] == 0.0]
    n = [r for r in t1 if r["method"] == "ngram" and r["T"] == 0.0]
    avg = lambda rs, k: sum(r[k] for r in rs) / max(len(rs), 1)
    lines.append(("table1_quasar_T0", step_us,
                  f"speedup={avg(q, 'modeled_speedup'):.2f}x;L={avg(q, 'L'):.2f}"))
    lines.append(("table1_ngram_T0", step_us,
                  f"speedup={avg(n, 'modeled_speedup'):.2f}x;L={avg(n, 'L'):.2f}"))

    t2 = table2_temperature.rows(quick=args.quick)
    qT = [r for r in t2 if r["method"] == "quasar"]
    lines.append(("table2_temperature", step_us,
                  f"quasar_L_T0={qT[0]['L']:.2f};L_T1={qT[-1]['L']:.2f}"))

    t3 = table3_sensitivity.rows(quick=args.quick)
    best = max((r for r in t3 if r["method"] == "quasar"),
               key=lambda r: r["modeled_speedup"])
    lines.append(("table3_sensitivity", step_us,
                  f"best_gamma={best['gamma']};K={best['K']};speedup={best['modeled_speedup']:.2f}x"))

    t4 = table4_accuracy.rows(quick=args.quick)
    lines.append(("table4_accuracy", step_us,
                  f"kl={t4[0]['kl_fp_to_w8a8']:.2e};top1={t4[0]['top1_agreement']:.3f}"))

    t5 = table5_pruning.rows(quick=args.quick)
    qs = [r for r in t5 if r["method"] == "quasar"][0]
    p50 = [r for r in t5 if r["method"].startswith("pruned-5")]
    lines.append(("table5_pruning", step_us,
                  f"quasar={qs['modeled_speedup']:.2f}x;pruned50_L="
                  f"{p50[0]['L'] if p50 else 'n/a'}"))

    t6 = table6_tree.rows(quick=args.quick)
    t6w = [r for r in t6
           if r["verifier"] == "w8a8" and r["task"] == "ambiguous"]
    chain = [r for r in t6w if r["template"].startswith("chain")][0]
    widest = max(t6w, key=lambda r: r["leaves"])
    lines.append(("table6_tree", step_us,
                  f"chain_L={chain['L']:.2f};{widest['template']}_L="
                  f"{widest['L']:.2f};speedup={widest['modeled_speedup']:.2f}x"))

    ab = ablation_bits.rows(quick=args.quick)
    w4 = [r for r in ab if r["verifier"] == "w4a8"][0]
    lines.append(("ablation_bits", step_us,
                  f"w4a8_kl={w4['kl_vs_bf16']:.2e};L={w4['L']:.2f};"
                  f"speedup={w4['modeled_speedup']:.2f}x"))

    akv = ablation_kv.rows(quick=args.quick)
    m_int8 = [r for r in akv["modeled"]
              if r["kv_cache"] == "int8"][-1]          # longest context
    d_int8 = [r for r in akv["acceptance"] if r["kv_cache"] == "int8"][0]
    lines.append(("ablation_kv", step_us,
                  f"kv_bytes_ratio_{m_int8['context'] // 1024}k="
                  f"{m_int8['kv_bytes_vs_bf16']:.3f};"
                  f"L_delta={d_int8['L_delta_vs_bf16']:+.3f};"
                  f"speedup={m_int8['modeled_speedup']:.2f}x"))
    pg = akv["paged"]
    lines.append(("paged_kv", step_us,
                  f"footprint_vs_contig="
                  f"{pg['modeled_bf16']['paged_vs_contiguous']:.3f};"
                  f"measured_bytes_ratio="
                  f"{pg['measured_cpu']['paged_vs_contiguous_bytes']:.3f};"
                  f"lossless={pg['measured_cpu']['tokens_bit_identical']}"))
    sp = akv["shared_prefix"]
    lines.append(("prefix_sharing", step_us,
                  f"modeled_capacity="
                  f"{sp['modeled_bf16']['effective_capacity']:.2f}x;"
                  f"measured_capacity="
                  f"{sp['measured_cpu']['effective_capacity']:.2f}x;"
                  f"lossless={sp['measured_cpu']['tokens_bit_identical']}"))

    rr = roofline_report.rows(quick=args.quick)
    lines.append(("roofline", step_us,
                  f"dryrun_rows={len(rr['roofline'])};"
                  f"eq12_ratio={rr['eq11_12'][0]['ratio']:.2f}"))

    from benchmarks import serve_load
    sl = serve_load.rows(quick=args.quick)["headline"]
    lines.append(("serve_load", step_us,
                  f"fifo_hit={sl['fifo_hit_rate']:.3f};"
                  f"edf_shed_hit={sl['edf_shed_hit_rate']:.3f};"
                  f"edf_ttft_p99={sl['edf_shed_ttft_p99']:.2f}s"))

    lines.append(("acceptance_parity", step_us,
                  _acceptance_parity(args.quick)))

    print("name,us_per_call,derived")
    for name, us, derived in lines:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
