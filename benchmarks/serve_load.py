"""Load-replay benchmark for the serving front-end: FIFO vs EDF+shed.

Drives the *identical* serving code path (``repro.serving.server.
ServingLoop``) on a **deterministic virtual clock**: a seeded Poisson
arrival process (or a recorded trace) is replayed event-for-event, and
time advances by a fixed per-decode-step cost instead of wall time.
Same seed → same arrivals, same token streams, same scheduling decisions
— so the FIFO-vs-EDF comparison is a controlled experiment, not a race.

The workload is deliberately *overloaded* (arrival rate ≈ 2× service
capacity) with a bimodal SLO mix — interactive requests with tight
deadlines interleaved with batch requests that can wait.  That is the
regime where admission policy decides realized quality of service (the
deployment-side argument of the SD survey, arXiv:2401.07851, and the
memory-constrained-serving setting of S3D, arXiv:2405.20314):

* **FIFO, no shedding** — tight-deadline arrivals queue behind earlier
  loose ones and miss; already-late work still burns slots.
* **EDF + shedding** — earliest-deadline-first admission serves urgent
  work first, and queued requests whose deadline already passed are
  dropped, so the queue never silts up with un-meetable work.

Reported per policy: deadline hit-rate, p50/p99 time-to-first-token and
inter-token latency (from the streaming emissions), occupancy, and the
conservation counters (``completed + shed + failed == submitted`` is
asserted — no request silently lost).  Results land in
``benchmarks/results/serve_load.json``.

``--chaos`` runs the robustness gate instead (docs/robustness.md): the
same trace is replayed twice — fault-free, then under a seeded
:class:`repro.serving.FaultPlan` injecting step crashes, NaN verifier
logits, allocator failures, swap corruption, stalls and malformed
submits.  The gate asserts the faulted replay still conserves every
request, returns every KV block, and leaves requests the faults never
touched bit-identical to the fault-free twin.

Usage::

    python benchmarks/serve_load.py             # full comparison
    python benchmarks/serve_load.py --smoke     # CI: tiny burst, seconds
    python benchmarks/serve_load.py --trace t.json   # replay a trace
    python benchmarks/serve_load.py --chaos --smoke  # CI chaos gate

A trace file is a JSON list of ``{"arrival_s", "prompt_reps",
"max_new_tokens", "deadline_s", "seed"}`` rows; ``--export-trace`` writes
the generated Poisson trace in that format for replay elsewhere.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from repro.core.config import SpecConfig                     # noqa: E402
from repro.serving import (                                  # noqa: E402
    GenerationRequest,
    ServerConfig,
    ServingLoop,
    SpecEngine,
)

# virtual seconds one batched decode step costs; deadlines/rates are
# expressed against this, so the experiment is hardware-independent.
# With 2 slots committing ~3-4 tokens/step, 0.25 s/step puts the default
# 6 req/s Poisson mix at roughly 2x service capacity — the overloaded
# regime where admission policy decides the deadline hit-rate (EDF+shed
# beats FIFO on every seed tested; see tests/test_serving_frontend.py).
STEP_COST_S = 0.25


class VirtualClock:
    """Deterministic time source for replay: advanced by the driver."""

    def __init__(self):
        self.t = 0.0

    def read(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------------------
# Trace generation / IO
# ---------------------------------------------------------------------------

def poisson_trace(n: int, rate_per_s: float, *, seed: int = 0,
                  tight_deadline_s: float = 2.0,
                  loose_deadline_s: float = 15.0,
                  tight_frac: float = 0.5,
                  min_new: int = 4, max_new: int = 12) -> list:
    """Seeded Poisson arrivals with a bimodal (interactive/batch) SLO mix."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_per_s, size=n))
    trace = []
    for i in range(n):
        tight = rng.random() < tight_frac
        trace.append({
            "arrival_s": float(arrivals[i]),
            "prompt_reps": int(rng.integers(2, 6)),
            "max_new_tokens": int(rng.integers(min_new, max_new + 1)),
            "deadline_s": float(tight_deadline_s if tight
                                else loose_deadline_s),
            "seed": int(i),
        })
    return trace


def load_trace(path: str) -> list:
    with open(path) as f:
        trace = json.load(f)
    required = {"arrival_s", "prompt_reps", "max_new_tokens", "deadline_s",
                "seed"}
    for row in trace:
        missing = required - set(row)
        if missing:
            raise ValueError(f"trace row missing fields {sorted(missing)}")
    return sorted(trace, key=lambda r: r["arrival_s"])


def _requests_from_trace(trace, vocab: int, *, pattern_seed: int = 3) -> list:
    """Materialize GenerationRequests (repeating-pattern prompts give the
    ngram drafter real acceptance, like the scheduler tests)."""
    rng = np.random.default_rng(pattern_seed)
    pat = rng.integers(0, vocab, 6)
    return [GenerationRequest(np.tile(pat, row["prompt_reps"]),
                              max_new_tokens=row["max_new_tokens"],
                              seed=row["seed"],
                              deadline_s=row["deadline_s"])
            for row in trace]


# ---------------------------------------------------------------------------
# Replay
# ---------------------------------------------------------------------------

def _replay_loop(engine, params, trace, *, admission: str, shed: bool,
                 batch_slots: int = 2, step_cost_s: float = STEP_COST_S,
                 clock=None, tracer=None, faults=None,
                 request_timeout_s=None):
    """Replay core: returns ``(loop, handles-by-rid, summary)`` so the
    chaos gate can inspect handles/pools after the drain."""
    requests = _requests_from_trace(trace, engine.model.cfg.vocab_size)
    if clock is None:
        clock = VirtualClock()
    cfg = ServerConfig(
        batch_slots=batch_slots,
        max_prompt_len=max(r.prompt.size for r in requests),
        max_new_tokens=max(r.max_new_tokens for r in requests),
        admission=admission,
        shed_late=shed,
        request_timeout_s=request_timeout_s,
    )
    loop = ServingLoop(engine, params, cfg, clock=clock.read,
                       tracer=tracer, faults=faults,
                       step_hook=lambda: clock.advance(step_cost_s),
                       stall_hook=clock.advance)

    events = sorted(zip((row["arrival_s"] for row in trace), requests),
                    key=lambda e: e[0])
    handles = {}
    i = 0
    while i < len(events) or loop.busy:
        # inject every arrival due at the current virtual time
        while i < len(events) and events[i][0] <= clock.t:
            h = loop.submit(events[i][1])
            handles[h.rid] = h
            i += 1
        if not loop.busy:
            # idle: jump to the next arrival instead of spinning
            clock.t = max(clock.t, events[i][0])
            continue
        loop.poll()      # virtual time advances inside each decode step

    loop.metrics.check_conservation()
    # streaming contract: per-request deltas concatenate bit-identically
    # to the final RequestResult tokens
    for h in handles.values():
        if h.status == "done":
            np.testing.assert_array_equal(
                h.collected(), h.result(0.0).tokens)
    summary = loop.metrics.summary()
    summary["policy"] = {"admission": admission, "shed": shed,
                         "batch_slots": batch_slots,
                         "step_cost_s": step_cost_s}
    return loop, handles, summary


def replay(engine, params, trace, *, admission: str, shed: bool,
           batch_slots: int = 2, step_cost_s: float = STEP_COST_S,
           clock=None, tracer=None) -> dict:
    """Replay ``trace`` through a ServingLoop on the virtual clock.

    Arrivals are injected exactly at their trace timestamps; every lane
    decode step advances virtual time by ``step_cost_s`` *inside* the
    step (``ServingLoop.step_hook``), so scheduler/decode trace spans
    get real widths and per-step latencies equal the modeled step cost.
    Returns the metrics summary plus the streaming-equality check.

    ``clock`` / ``tracer`` let the caller share the virtual clock with a
    ``repro.serving.trace.Tracer(clock=clock.read)`` — the resulting
    trace is a pure function of (trace, seed, policy): two replays of
    the same inputs serialize byte-identically.
    """
    _, _, summary = _replay_loop(
        engine, params, trace, admission=admission, shed=shed,
        batch_slots=batch_slots, step_cost_s=step_cost_s, clock=clock,
        tracer=tracer)
    return summary


def _build_engine(smoke: bool, paged: bool = False):
    if smoke:
        import jax

        from repro.configs import get_config
        from repro.models import Model
        model = Model(get_config("smollm-135m").reduced())
        params = model.init_params(jax.random.PRNGKey(0))
        verifier = "bf16"
    else:
        from benchmarks.common import get_trained
        model, params, _ = get_trained("qwen3-sub")
        verifier = "w8a8"
    scfg = SpecConfig(temperature=0.0, gamma=3)
    if paged:
        # tight block pool: the overloaded mix forces preempt/swap, so
        # traces exercise the swap-out/in spans (tests/test_observability)
        import dataclasses
        scfg = dataclasses.replace(scfg, kv_layout="paged",
                                   kv_block_size=8, kv_pool_blocks=10)
    engine = SpecEngine(model, scfg, drafter="ngram", verifier=verifier)
    return engine, params


# ---------------------------------------------------------------------------
# Chaos gate (docs/robustness.md)
# ---------------------------------------------------------------------------

#: Default chaos mix: one scalpel fault per containment class plus
#: low-probability shotgun rules on the allocator/stall/submit seams.
DEFAULT_CHAOS_SPEC = ("step@6,nan_verify@4,quant_corrupt@9,alloc~0.04,"
                      "swap_in~0.25,stall~0.05,submit~0.03")


def chaos_rows(quick: bool = False, trace=None, seed: int = 0,
               spec: str = DEFAULT_CHAOS_SPEC) -> dict:
    """Fault-free twin vs. seeded-fault replay of the same trace.

    Hard gates (all assert): three-term conservation on the faulted run,
    zero leaked KV blocks after the drain, at least one fault actually
    fired, and every request the faults never touched (terminal ``done``
    with its rid absent from ``loop.affected``) produced tokens
    bit-identical to the fault-free twin.
    """
    from repro.serving import FaultPlan
    engine, params = _build_engine(smoke=quick, paged=True)
    if trace is None:
        n = 12 if quick else 40
        trace = poisson_trace(n, rate_per_s=6.0, seed=seed)
    _, clean_handles, clean = _replay_loop(
        engine, params, trace, admission="edf", shed=True)
    plan = FaultPlan.parse(spec, seed=seed, stall_s=2.0)
    loop, handles, faulted = _replay_loop(
        engine, params, trace, admission="edf", shed=True,
        faults=plan, request_timeout_s=60.0)
    assert any(v["fired"] for v in plan.summary().values()), \
        "chaos gate is vacuous: no fault fired"
    for lane in loop._lanes.values():
        if lane.ctx is not None:
            lane.ctx.pool.check_invariants()
            assert lane.ctx.pool.unique_allocated == 0, "leaked KV blocks"
    compared = 0
    for rid, h in handles.items():
        twin = clean_handles.get(rid)
        if (h.status == "done" and rid not in loop.affected
                and twin is not None and twin.status == "done"):
            np.testing.assert_array_equal(
                h.result(0.0).tokens, twin.result(0.0).tokens)
            compared += 1
    assert compared >= 1, "chaos gate is vacuous: no untouched request " \
        "completed in both replays"
    return {
        "trace": {"n": len(trace), "seed": seed},
        "fault_spec": spec,
        "plan": plan.summary(),
        "clean": {"counters": clean["counters"]},
        "faulted": {"counters": faulted["counters"],
                    "robustness": faulted["robustness"]},
        "affected": sorted(loop.affected),
        "bit_identical_untouched": compared,
    }


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def rows(quick: bool = False, trace=None, seed: int = 0,
         artifacts=None) -> dict:
    """FIFO vs EDF+shed on the same overloaded trace (same seed).

    Pass an ``artifacts`` dict to additionally capture a Perfetto tracer
    for the EDF replay under ``artifacts["tracer"]`` (the tracer shares
    the replay's virtual clock, so the export is deterministic).
    """
    engine, params = _build_engine(smoke=quick)
    rate = None
    if trace is None:
        # ~2x overload: 2 slots at ~(L/step_cost) tok/s per slot vs
        # Poisson arrivals needing ~8 tokens each
        n = 12 if quick else 40
        rate = 6.0
        trace = poisson_trace(n, rate_per_s=rate, seed=seed)
    fifo = replay(engine, params, trace, admission="fifo", shed=False)
    if artifacts is not None:
        from repro.serving import Tracer
        clock = VirtualClock()
        artifacts["tracer"] = Tracer(clock=clock.read)
        edf = replay(engine, params, trace, admission="edf", shed=True,
                     clock=clock, tracer=artifacts["tracer"])
    else:
        edf = replay(engine, params, trace, admission="edf", shed=True)
    out = {
        "trace": {"n": len(trace), "seed": seed, "rate_per_s": rate},
        "fifo": fifo,
        "edf_shed": edf,
        "headline": {
            "fifo_hit_rate": fifo["deadlines"]["hit_rate"],
            "edf_shed_hit_rate": edf["deadlines"]["hit_rate"],
            "fifo_ttft_p99": fifo["latency"]["ttft_s"].get("p99"),
            "edf_shed_ttft_p99": edf["latency"]["ttft_s"].get("p99"),
        },
    }
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny random-init model, short burst")
    ap.add_argument("--quick", action="store_true",
                    help="alias for --smoke (benchmarks/run.py convention)")
    ap.add_argument("--trace", default=None,
                    help="replay a recorded trace JSON instead of Poisson")
    ap.add_argument("--export-trace", default=None,
                    help="write the generated Poisson trace to this path")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Perfetto/Chrome trace of the EDF replay "
                         "(virtual-clock timestamps; validate with "
                         "tools/check_trace.py)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the full FIFO/EDF metrics summaries "
                         "(latency, acceptance, kv_cache sections) as JSON")
    ap.add_argument("--chaos", action="store_true",
                    help="robustness gate: replay fault-free then under a "
                         "seeded FaultPlan; assert conservation, zero "
                         "leaked blocks, untouched-request bit-identity")
    ap.add_argument("--fault-spec", default=DEFAULT_CHAOS_SPEC,
                    metavar="SPEC",
                    help="chaos fault spec (seam@i / seam~p, "
                         "comma-separated); see repro.serving.FaultPlan")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    smoke = args.smoke or args.quick
    trace = load_trace(args.trace) if args.trace else None

    if args.chaos:
        out = chaos_rows(quick=smoke, trace=trace, seed=args.seed,
                         spec=args.fault_spec)
        from benchmarks.common import save_json
        path = save_json("serve_load_chaos.json", out)
        c = out["faulted"]["counters"]
        rb = out["faulted"]["robustness"]
        print(f"chaos: submitted={c['submitted']} "
              f"completed={c['completed']} shed={c['shed']} "
              f"failed={c['failed']}")
        fired = {s: v["fired"] for s, v in out["plan"].items()
                 if v["fired"]}
        print(f"faults fired: {fired}")
        print("robustness: " + ", ".join(
            f"{k}={v}" for k, v in sorted(rb.items()) if v))
        print(f"untouched bit-identical: "
              f"{out['bit_identical_untouched']}")
        print(f"results -> {path}")
        return 0
    if args.export_trace:
        t = trace or poisson_trace(12 if smoke else 40, 6.0, seed=args.seed)
        with open(args.export_trace, "w") as f:
            json.dump(t, f, indent=1)
        print(f"trace -> {args.export_trace}")

    artifacts = {} if args.trace_out else None
    out = rows(quick=smoke, trace=trace, seed=args.seed, artifacts=artifacts)

    if smoke:
        # CI gate: the summary schema the docs promise actually shipped
        for pol in ("fifo", "edf_shed"):
            s = out[pol]
            assert "acceptance" in s and "kv_cache" in s, \
                f"{pol}: summary missing telemetry sections"
            assert all("accept_len" in v for v in s["acceptance"].values())

    if args.trace_out:
        artifacts["tracer"].save(args.trace_out)
        print(f"trace-out -> {args.trace_out} "
              f"({len(artifacts['tracer'].events)} events)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"metrics-out -> {args.metrics_out}")

    from benchmarks.common import save_json
    path = save_json("serve_load.json", out)

    h = out["headline"]
    print(f"deadline hit-rate: fifo={h['fifo_hit_rate']:.3f}  "
          f"edf+shed={h['edf_shed_hit_rate']:.3f}")
    print(f"ttft p99 (virtual s): fifo={h['fifo_ttft_p99']:.2f}  "
          f"edf+shed={h['edf_shed_ttft_p99']:.2f}")
    print(f"results -> {path}")
    if h["edf_shed_hit_rate"] < h["fifo_hit_rate"]:
        print("FAIL: EDF+shed did not beat FIFO on deadline hit-rate")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
