"""Paper Table 1 / Figure 2: end-to-end speedup + acceptance length across
five tasks × two models × T ∈ {0, 1} for Vanilla / Ngram(BF16) / Quasar(W8A8).

Measured: L, CPU tokens/s.  Modeled: Eq. 11-13 speedup at paper scale.
"""
from __future__ import annotations

from repro.core.config import SpecConfig

from benchmarks.common import (
    TASKS, LatencyModel, get_trained, run_engine, save_json,
)


def rows(quick: bool = False):
    lat = LatencyModel()
    out = []
    models = ["qwen3-sub"] if quick else ["qwen3-sub", "openpangu-sub"]
    temps = [0.0] if quick else [0.0, 1.0]
    tasks = TASKS[:2] if quick else TASKS
    for mname in models:
        model, params, qparams = get_trained(mname)
        for T in temps:
            scfg = SpecConfig(gamma=5, temperature=T)
            for task in tasks:
                van = run_engine(model, params, mode="vanilla", scfg=scfg, task=task)
                ngr = run_engine(model, params, mode="spec", scfg=scfg, task=task)
                qsr = run_engine(model, qparams, mode="spec", scfg=scfg, task=task)
                for method, r, bits in (("vanilla", van, 16),
                                        ("ngram", ngr, 16),
                                        ("quasar", qsr, 8)):
                    if method == "vanilla":
                        speed = 1.0
                    else:
                        speed = lat.speedup(r["L"], scfg.gamma, verifier_bits=bits)
                    out.append({
                        "model": mname, "T": T, "task": task, "method": method,
                        "L": round(r["L"], 3),
                        "modeled_speedup": round(speed, 3),
                        "cpu_tok_s": round(r["cpu_tok_s"], 1),
                        "steps": r["steps"],
                    })
    save_json("table1_speedup.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
