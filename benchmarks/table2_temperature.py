"""Paper Table 2: robustness across sampling temperatures T ∈ [0, 1].
Ngram (BF16 verify) vs Quasar (W8A8 verify), averaged over tasks."""
from __future__ import annotations

import numpy as np

from repro.core.config import SpecConfig

from benchmarks.common import TASKS, LatencyModel, get_trained, run_engine, save_json

TEMPS = [0.0, 0.2, 0.4, 0.6, 0.8, 1.0]


def rows(quick: bool = False):
    lat = LatencyModel()
    model, params, qparams = get_trained("qwen3-sub")
    temps = [0.0, 1.0] if quick else TEMPS
    tasks = TASKS[:2] if quick else TASKS[:3]
    out = []
    for T in temps:
        scfg = SpecConfig(gamma=5, temperature=T)
        for method, p, bits in (("ngram", params, 16), ("quasar", qparams, 8)):
            Ls = [run_engine(model, p, mode="spec", scfg=scfg, task=t)["L"]
                  for t in tasks]
            L = float(np.mean(Ls))
            out.append({
                "T": T, "method": method, "L": round(L, 3),
                "modeled_speedup": round(
                    lat.speedup(L, scfg.gamma, verifier_bits=bits), 3),
            })
    save_json("table2_temperature.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
