"""Paper Table 3: sensitivity to draft length γ and prompt-lookup range K
on the code task (HumanEval preset), Ngram vs Quasar."""
from __future__ import annotations

from repro.core.config import SpecConfig

from benchmarks.common import LatencyModel, get_trained, run_engine, save_json

GAMMAS = [3, 5, 7, 9]
K_RANGES = [(1, 3), (2, 4), (3, 5)]


def rows(quick: bool = False):
    lat = LatencyModel()
    model, params, qparams = get_trained("qwen3-sub")
    gammas = [3, 5] if quick else GAMMAS
    kranges = K_RANGES[:1] if quick else K_RANGES
    out = []
    for (kmin, kmax) in kranges:
        for g in gammas:
            scfg = SpecConfig(gamma=g, k_min=kmin, k_max=kmax, temperature=0.0)
            for method, p, bits in (("ngram", params, 16), ("quasar", qparams, 8)):
                r = run_engine(model, p, mode="spec", scfg=scfg, task="humaneval")
                out.append({
                    "K": f"({kmin},{kmax})", "gamma": g, "method": method,
                    "L": round(r["L"], 3),
                    "modeled_speedup": round(
                        lat.speedup(r["L"], g, verifier_bits=bits), 3),
                })
    save_json("table3_sensitivity.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
