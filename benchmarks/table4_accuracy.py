"""Paper Table 4: accuracy preservation under W8A8.

At CPU scale we cannot run MMLU-pro/CEval; the hardware-independent proxy
for "Δ accuracy ≈ 3%" is logit fidelity between the BF16 model and its
W8A8 quantized verifier: KL divergence, top-1/top-5 agreement, and the
rank correlation of the top tokens — exactly the quantities the paper's
§4.5 discussion attributes the accuracy preservation to ("W8A8 preserves
the relative logit rankings extremely well").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import lm_batches

from benchmarks.common import get_trained, save_json


def _fidelity(model, params, qparams, seed: int, batches: int = 4):
    kls, top1, top5 = [], [], []
    it = lm_batches(4, 64, model.cfg.vocab_size, seed=seed)
    for _ in range(batches):
        toks = jnp.asarray(next(it)["tokens"])
        lf, _ = model.forward(params, toks)
        lq, _ = model.forward(qparams, toks)
        p = jax.nn.softmax(lf, -1)
        kls.append(float(jnp.mean(jnp.sum(
            p * (jnp.log(p + 1e-9) - jax.nn.log_softmax(lq, -1)), -1))))
        top1.append(float(jnp.mean(
            (jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32))))
        _, i5f = jax.lax.top_k(lf, 5)
        a1q = jnp.argmax(lq, -1)
        top5.append(float(jnp.mean(
            jnp.any(i5f == a1q[..., None], -1).astype(jnp.float32))))
    return float(np.mean(kls)), float(np.mean(top1)), float(np.mean(top5))


def rows(quick: bool = False):
    out = []
    for mname in (["qwen3-sub"] if quick else ["qwen3-sub", "openpangu-sub"]):
        model, params, qparams = get_trained(mname)
        kl, t1, t5 = _fidelity(model, params, qparams, seed=11,
                               batches=2 if quick else 4)
        out.append({
            "model": mname,
            "kl_fp_to_w8a8": round(kl, 6),
            "top1_agreement": round(t1, 4),
            "top5_contains_w8a8_top1": round(t5, 4),
            "paper_claim": "avg Δ ≈ 2.9-3.1% on downstream benchmarks",
        })
    save_json("table4_accuracy.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
