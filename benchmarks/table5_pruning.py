"""Paper Table 5 / §5 Discussion: structural pruning vs Quasar.

The pruned baseline drafts with the first ``retention·L`` layers of the
target model (LayerSkip-style self-speculation) and verifies with the full
BF16 model.  The paper's finding: conservative pruning keeps L high but
drafting is too expensive (net slowdown); aggressive pruning is cheap but
distributionally broken (L → 1).  Quasar keeps full depth at INT8 cost.
"""
from __future__ import annotations

from repro.core.config import SpecConfig

from benchmarks.common import LatencyModel, get_trained, run_engine, save_json

RETENTIONS = [0.9, 0.75, 0.5]


def rows(quick: bool = False):
    lat = LatencyModel()
    model, params, qparams = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=5, temperature=0.0)
    out = [{
        "method": "vanilla", "config": "100% layers / BF16",
        "L": 1.0, "modeled_speedup": 1.0,
    }]
    for ret in (RETENTIONS[:2] if quick else RETENTIONS):
        s = SpecConfig(gamma=5, temperature=0.0, pruned_retention=ret)
        r = run_engine(model, params, mode="pruned", scfg=s, task="gsm8k")
        out.append({
            "method": f"pruned-{int(ret*100)}%",
            "config": f"{int(ret*100)}% layers / BF16",
            "L": round(r["L"], 3),
            "modeled_speedup": round(
                lat.speedup(r["L"], 5, verifier_bits=16,
                            drafter="pruned", retention=ret), 3),
        })
    rq = run_engine(model, qparams, mode="spec", scfg=scfg, task="gsm8k")
    out.append({
        "method": "quasar", "config": "100% layers / W8A8",
        "L": round(rq["L"], 3),
        "modeled_speedup": round(
            lat.speedup(rq["L"], 5, verifier_bits=8), 3),
    })
    save_json("table5_pruning.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
