"""Table 6 (extension): chain vs token-tree drafting under quantized
verification.

Tree drafting is the strongest acceptance-length lever in the SD taxonomy
(Xia et al. survey; SpecInfer): one memory-bound verifier pass scores
``num_leaves`` candidate continuations instead of one, so the measured
win is *mean acceptance length* (L, committed tokens per verify step) at
an unchanged per-step weight-streaming cost.  This sweep pits the γ-chain
against progressively wider templates of the same depth, for each
drafter × verifier pair, on the repetition-heavy synthetic tasks — so the
tree win is measured, not asserted (``tests/test_tree.py`` asserts the
strict inequality; this table reports the magnitudes).

The modeled TPU speedup reuses Eq. 11-13 with the window size grown to
the node count: tree windows pay more *compute* per step, but the verify
pass stays memory-bound at paper scale, so higher L converts almost 1:1.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.config import SpecConfig
from repro.core.drafters import NgramTreeDrafter
from repro.core.tree import TreeTemplate
from repro.data import ambiguous_prompts
from repro.serving.engine import SpecEngine

from benchmarks.common import LatencyModel, get_trained, run_engine, save_json

# same depth (4), growing width: 1 / 2 / 6 leaves
TEMPLATES = [
    ("chain-g4", (1, 1, 1, 1)),
    ("tree-2111", (2, 1, 1, 1)),
    ("tree-3211", (3, 2, 1, 1)),
]
VERIFIERS = [("bf16", 16), ("w8a8", 8)]


def _run_ambiguous(model, params, drafter, scfg, new_tokens=10):
    """Measure L on the ambiguous-continuation workload (the tree case
    ``repro.data.ambiguous_prompts`` constructs) — ``run_engine`` covers
    the natural task presets."""
    prompts = jnp.asarray(
        ambiguous_prompts(6, 64, model.cfg.vocab_size, depth=4, seed=0))
    eng = SpecEngine(model, scfg, drafter=drafter, verifier="bf16")
    r = eng.generate(params, prompts, new_tokens)
    return {"L": r.mean_accept_len, "steps": r.steps,
            "new_tokens": r.new_tokens}


def rows(quick: bool = False):
    lat = LatencyModel()
    model, params, qparams = get_trained("qwen3-sub")
    tasks = ["ambiguous"] if quick else ["ambiguous", "gsm8k", "humaneval"]
    templates = TEMPLATES[:2] if quick else TEMPLATES
    out = []
    for vname, bits in VERIFIERS:
        p = qparams if vname == "w8a8" else params
        for tname, branches in templates:
            tpl = TreeTemplate(branches)
            drafter = NgramTreeDrafter(tpl)
            for task in tasks:
                scfg = SpecConfig(gamma=tpl.gamma, temperature=0.0,
                                  tree_branches=branches)
                if task == "ambiguous":
                    r = _run_ambiguous(model, p, drafter, scfg)
                else:
                    r = run_engine(model, p, drafter=drafter,
                                   verifier="bf16", scfg=scfg, task=task)
                out.append({
                    "template": tname,
                    "branches": list(branches),
                    "nodes": tpl.num_nodes,
                    "leaves": tpl.num_leaves,
                    "verifier": vname,
                    "task": task,
                    "L": round(r["L"], 3),
                    "tokens_per_step": round(
                        r["new_tokens"] / max(r["steps"], 1), 3),
                    "modeled_speedup": round(
                        lat.speedup(r["L"], tpl.gamma,
                                    verifier_bits=bits), 3),
                })
    save_json("table6_tree.json", out)
    return out


def main():
    for r in rows():
        print(r)


if __name__ == "__main__":
    main()
