"""Inspect the enhanced-SmoothQuant calibration: how smoothing factors
migrate quantization difficulty from activations to weights (paper Eq. 5),
and what that buys in logit fidelity.

Run:  PYTHONPATH=src python examples/quantize_and_inspect.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.config import QuantConfig
from repro.data import lm_batches
from repro.models import Model
from repro.quant import quantize_params
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def kl_and_top1(model, params, qparams, seed=2):
    toks = jnp.asarray(next(lm_batches(4, 64, model.cfg.vocab_size, seed=seed))["tokens"])
    lf, _ = model.forward(params, toks)
    lq, _ = model.forward(qparams, toks)
    p = jax.nn.softmax(lf, -1)
    kl = float(jnp.mean(jnp.sum(p * (jnp.log(p + 1e-9) - jax.nn.log_softmax(lq, -1)), -1)))
    t1 = float(jnp.mean((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
    return kl, t1


def main():
    cfg = get_config("smollm-135m").reduced()
    model = Model(cfg)
    tr = Trainer(model, AdamWConfig(lr=1.5e-3, warmup_steps=10, total_steps=100))
    params, opt = tr.init(jax.random.PRNGKey(0))
    params, _, _ = tr.fit(params, opt, lm_batches(8, 96, cfg.vocab_size),
                          steps=100, log_every=100, log_fn=None)

    # calibrate
    collect = {}
    model.forward(params, jnp.asarray(
        next(lm_batches(4, 96, cfg.vocab_size, seed=1))["tokens"]), collect=collect)
    print(f"calibrated {len(collect)} apply-sites, e.g.:")
    for path in list(collect)[:4]:
        a = np.asarray(collect[path])
        print(f"  {path:28s} act |max| range [{a.min():.3f}, {a.max():.3f}] "
              f"(outlier ratio {a.max()/np.median(a):.1f}x)")

    for alpha in (0.0, 0.5, 0.8):
        q = quantize_params(params, collect, QuantConfig(alpha=alpha))
        kl, t1 = kl_and_top1(model, params, q)
        print(f"alpha={alpha:.1f}  KL={kl:.3e}  top-1 agreement={t1:.3f}")
    q = quantize_params(params, None, QuantConfig())
    kl, t1 = kl_and_top1(model, params, q)
    print(f"no calib   KL={kl:.3e}  top-1 agreement={t1:.3f}")


if __name__ == "__main__":
    main()
