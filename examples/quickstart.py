"""Quickstart: the full Quasar pipeline in ~60 seconds on CPU.

1. train a tiny llama-family model on a synthetic corpus,
2. calibrate + quantize it to W8A8 (enhanced SmoothQuant, paper §3.2-3.3),
3. serve with quantized self-speculative decoding (n-gram drafting +
   W8A8 verification) and check the output is exactly what the quantized
   model would have produced autoregressively (the lossless guarantee).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.data import lm_batches, task_prompts
from repro.models import Model
from repro.quant import quantize_params
from repro.serving.engine import SpecEngine
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def main():
    cfg = get_config("smollm-135m").reduced()
    model = Model(cfg)

    print("== 1. train ==")
    trainer = Trainer(model, AdamWConfig(lr=1.5e-3, warmup_steps=10, total_steps=120))
    params, opt = trainer.init(jax.random.PRNGKey(0))
    params, _, _ = trainer.fit(params, opt,
                               lm_batches(8, 96, cfg.vocab_size, seed=0),
                               steps=120, log_every=40)

    print("\n== 2. calibrate + quantize (offline weight preparation) ==")
    collect = {}
    calib = next(lm_batches(4, 96, cfg.vocab_size, seed=1))
    model.forward(params, jnp.asarray(calib["tokens"]), collect=collect)
    qparams = quantize_params(params, collect, QuantConfig())
    print(f"calibrated {len(collect)} linear apply-sites; "
          "weights now int8 + per-channel scales")

    print("\n== 3. serve with quantized verification ==")
    # drafter/verifier are registry plugins; the "vanilla" drafter (γ=0)
    # is the autoregressive baseline through the same unified decode step
    prompts = jnp.asarray(task_prompts("gsm8k", 2, 48, cfg.vocab_size))
    scfg = SpecConfig(gamma=5, temperature=0.0)
    quasar = SpecEngine(model, scfg, drafter="ngram",
                        verifier="bf16").generate(qparams, prompts, 32)
    vanilla = SpecEngine(model, scfg, drafter="vanilla",
                         verifier="bf16").generate(qparams, prompts, 32)

    P = prompts.shape[1]
    lossless = bool(jnp.all(quasar.tokens[:, :P + 32] == vanilla.tokens[:, :P + 32]))
    print(f"mean acceptance length L = {quasar.mean_accept_len:.2f}")
    print(f"verifier passes: {quasar.steps} (vanilla needed {vanilla.steps})")
    print(f"lossless vs autoregressive quantized model: {lossless}")
    assert lossless


if __name__ == "__main__":
    main()
