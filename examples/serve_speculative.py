"""End-to-end serving driver: batched requests through the speculative
engine, comparing the three serving modes of the paper —

  vanilla      autoregressive BF16 (1 forward / token)
  ngram        prompt-lookup drafting + BF16 verification
  quasar       prompt-lookup drafting + W8A8 quantized verification

Reports measured acceptance lengths + CPU wall, and the Eq. 11-13 modeled
TPU speedups at paper scale (7B-class target model on one v5e chip).

Run:  PYTHONPATH=src python examples/serve_speculative.py [--task gsm8k]
"""
import argparse

import jax.numpy as jnp

from repro.core.config import SpecConfig

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import LatencyModel, get_trained, run_engine  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="gsm8k",
                    choices=["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"])
    ap.add_argument("--gamma", type=int, default=5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    model, params, qparams = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=args.gamma, temperature=args.temperature)
    lat = LatencyModel()

    print(f"task={args.task} γ={args.gamma} T={args.temperature} "
          f"batch={args.batch}\n")
    print(f"{'method':10s} {'L':>6s} {'cpu tok/s':>10s} {'modeled TPU speedup':>20s}")
    for method, p, bits, mode in (("vanilla", params, 16, "vanilla"),
                                  ("ngram", params, 16, "spec"),
                                  ("quasar", qparams, 8, "spec")):
        r = run_engine(model, p, mode=mode, scfg=scfg, task=args.task,
                       batch=args.batch, new_tokens=args.new_tokens)
        sp = 1.0 if method == "vanilla" else lat.speedup(
            r["L"], args.gamma, verifier_bits=bits)
        print(f"{method:10s} {r['L']:6.2f} {r['cpu_tok_s']:10.1f} {sp:19.2f}x")


if __name__ == "__main__":
    main()
