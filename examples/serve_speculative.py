"""End-to-end serving driver over the pluggable decoding API.

Part 1 — method comparison (the paper's three serving modes, expressed as
(drafter, verifier) registry pairs through one unified decode step):

  vanilla      ("vanilla", bf16)   autoregressive baseline (γ=0 drafter)
  ngram        ("ngram",   bf16)   prompt-lookup drafting, BF16 verify
  quasar       ("ngram",   w8a8)   prompt-lookup + W8A8 quantized verify
                                   (the engine quantizes the BF16 params
                                   internally — no manual quantize call)

Reports measured acceptance lengths + CPU wall, and the Eq. 11-13 modeled
TPU speedups at paper scale (7B-class target model on one v5e chip).

Part 2 — continuous-batching serving: a queue of ``GenerationRequest``s
with heterogeneous prompt lengths, token budgets and seeds flows through
a fixed number of batch slots (``--slots``); finished rows are harvested
and refilled mid-loop without recompiling the decode step, and each
request reports its own queue/service latency.

Run:  PYTHONPATH=src python examples/serve_speculative.py [--task gsm8k]
"""
import argparse

import numpy as np

from repro.core.config import SpecConfig
from repro.serving import GenerationRequest, SpecEngine

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.common import LatencyModel, get_trained, run_engine  # noqa: E402
from repro.data import task_prompts  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="gsm8k",
                    choices=["mtbench", "humaneval", "gsm8k", "alpaca", "cnndm"])
    ap.add_argument("--gamma", type=int, default=5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2,
                    help="decode slots for the continuous-batching demo")
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    # qparams carries SmoothQuant act-stat calibration (benchmarks/common):
    # feed it to the w8a8 rows so the demo measures the same quantization
    # as the paper tables (W8A8Verifier.prepare is idempotent on it)
    model, params, qparams = get_trained("qwen3-sub")
    scfg = SpecConfig(gamma=args.gamma, temperature=args.temperature)
    lat = LatencyModel()

    print(f"task={args.task} γ={args.gamma} T={args.temperature} "
          f"batch={args.batch}\n")
    print(f"{'method':10s} {'L':>6s} {'cpu tok/s':>10s} {'modeled TPU speedup':>20s}")
    for method, p, drafter, verifier, bits in (
            ("vanilla", params, "vanilla", "bf16", 16),
            ("ngram", params, "ngram", "bf16", 16),
            ("quasar", qparams, "ngram", "w8a8", 8)):
        r = run_engine(model, p, drafter=drafter, verifier=verifier,
                       scfg=scfg, task=args.task, batch=args.batch,
                       new_tokens=args.new_tokens)
        sp = 1.0 if method == "vanilla" else lat.speedup(
            r["L"], args.gamma, verifier_bits=bits)
        print(f"{method:10s} {r['L']:6.2f} {r['cpu_tok_s']:10.1f} {sp:19.2f}x")

    # ------------------------------------------------------------------
    print(f"\n== continuous batching: 4 requests through {args.slots} "
          f"slots ==")
    V = model.cfg.vocab_size
    base = np.asarray(task_prompts(args.task, 4, 40, V))
    requests = [
        GenerationRequest(base[0],       max_new_tokens=8,  seed=11),
        GenerationRequest(base[1][:32],  max_new_tokens=24, seed=22),
        GenerationRequest(base[2][:24],  max_new_tokens=16, seed=33),
        GenerationRequest(base[3],       max_new_tokens=12, seed=44),
    ]
    engine = SpecEngine(model, scfg, verifier="w8a8")
    results = engine.generate_requests(qparams, requests,
                                       batch_slots=args.slots)
    for i, r in enumerate(results):
        print(f"req[{i}] prompt={r.prompt_len:3d} budget="
              f"{r.request.max_new_tokens:3d} -> new={r.new_tokens:3d} "
              f"L={r.accept_len:.2f} queue={r.queue_s*1e3:7.1f}ms "
              f"service={r.service_s*1e3:7.1f}ms "
              f"first8={r.tokens[:8].tolist()}")
    print(f"decode-step compilations: {engine.step_traces} "
          f"(admission is retrace-free)")


if __name__ == "__main__":
    main()
