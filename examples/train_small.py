"""End-to-end training driver: train a ~135M-param model (SmolLM-135M
architecture) for a few hundred steps on the synthetic Markov corpus,
checkpointing along the way.

On this CPU container the default runs the reduced config (fast); pass
``--full`` to train the real 135M configuration (slow on CPU, the shapes
and code path are identical to what the dry-run lowers for the 16×16 TPU
mesh).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300] [--full]
"""
import argparse
import os

import jax

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import Model
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the real smollm-135m config (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/repro_smollm.npz")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = cfg.reduced()
    model = Model(cfg)
    print(f"arch={cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"{'full' if args.full else 'reduced'})")

    trainer = Trainer(model, AdamWConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    params, opt = trainer.init(jax.random.PRNGKey(0))
    data = lm_batches(args.batch, args.seq_len, cfg.vocab_size, seed=0)
    params, opt, hist = trainer.fit(params, opt, data, steps=args.steps,
                                    log_every=20)
    save_checkpoint(args.ckpt, {"params": params, "step": args.steps})
    print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
          f"checkpoint: {args.ckpt} "
          f"({os.path.getsize(args.ckpt)/1e6:.1f} MB)")


if __name__ == "__main__":
    main()
