"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

from repro.core.config import ModelConfig

from repro.configs.phi35_moe import CONFIG as phi35_moe
from repro.configs.arctic import CONFIG as arctic
from repro.configs.zamba2 import CONFIG as zamba2
from repro.configs.llama32_vision import CONFIG as llama32_vision
from repro.configs.stablelm import CONFIG as stablelm
from repro.configs.smollm import CONFIG as smollm
from repro.configs.moonshot import CONFIG as moonshot
from repro.configs.mamba2 import CONFIG as mamba2
from repro.configs.codeqwen import CONFIG as codeqwen
from repro.configs.whisper import CONFIG as whisper
from repro.configs.quasar_paper import CONFIG as quasar_paper

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        phi35_moe, arctic, zamba2, llama32_vision, stablelm,
        smollm, moonshot, mamba2, codeqwen, whisper, quasar_paper,
    ]
}

ASSIGNED = [c for c in REGISTRY.values() if c.name != "quasar-paper-7b"]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
