"""arctic-480b [hf:Snowflake/snowflake-arctic-base]

35L, d_model=7168, 56H (GQA kv=8), d_ff=4864, vocab=32000,
MoE 128 experts top-2 with a dense residual MLP branch per layer.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    arch_type="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    moe_d_ff=4864,
    dense_residual=True,
    source="hf:Snowflake/snowflake-arctic-base",
)
