"""llama-3.2-vision-90b [hf:meta-llama/Llama-3.2-11B-Vision]

100L (80 self-attn + 20 cross-attn image layers, every 5th), d_model=8192,
64H (GQA kv=8), d_ff=28672, vocab=128256.  The ViT vision encoder is a stub:
``input_specs`` provides precomputed patch embeddings (B, 1600, d_model).
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    num_image_tokens=1600,
    rope_theta=500000.0,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
