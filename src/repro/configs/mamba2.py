"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality), attention-free.

48L, d_model=1024 (d_inner=2048, 32 SSD heads × P=64), ssm_state N=128,
vocab=50280, tied embeddings.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    arch_type="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    use_rope=False,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
