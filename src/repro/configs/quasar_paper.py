"""quasar-paper-7b — the paper's own model scale (OpenPangu-7B / Qwen3-8B
class dense decoder), used by the paper-table benchmarks as the reference
target-model shape.  [paper §4.1; hf:Qwen/Qwen3-8B]
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="quasar-paper-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=12288,
    vocab_size=151936,
    rope_theta=1000000.0,
    source="paper §4.1 (Qwen3-8B / OpenPangu-7B class)",
)
