"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M] — llama-arch small.

30L, d_model=576, 9H (GQA kv=3, head_dim=64), d_ff=1536, vocab=49152, tied
embeddings.  Also the scale used by the end-to-end train/serve examples.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    arch_type="dense",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
