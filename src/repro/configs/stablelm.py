"""stablelm-12b [hf:stabilityai/stablelm-2-1_6b family, 12B variant]

40L, d_model=5120, 32H (GQA kv=8, head_dim=160), d_ff=13824, vocab=100352.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    arch_type="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    source="hf:stabilityai/stablelm-2-1_6b",
)
