"""whisper-small [arXiv:2212.04356] — encoder-decoder audio backbone.

12L encoder + 12L decoder, d_model=768, 12H (kv=12), d_ff=3072, vocab=51865,
LayerNorm + GELU, non-gated FFN, biases everywhere, tied decoder embeddings.
The mel-spectrogram + conv frontend is a stub: ``input_specs`` provides
precomputed frame embeddings (B, 1500, d_model) consumed by the encoder.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    arch_type="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    num_audio_frames=1500,
    norm="layernorm",
    act="gelu",
    glu=False,
    ffn_bias=True,
    tie_embeddings=True,
    source="arXiv:2212.04356",
)
