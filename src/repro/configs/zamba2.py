"""zamba2-2.7b [arXiv:2411.15242]

54L Mamba2 backbone, d_model=2560, shared attention block (32H MHA, kv=32,
d_ff=10240) applied every 6th layer, vocab=32000, ssm_state=64.
"""
from repro.core.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    arch_type="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    attn_every=6,
    shared_attn=True,
    source="arXiv:2411.15242",
)
