"""The paper's primary contribution: quantized self-speculative decoding."""
from repro.core import prng  # noqa: F401
from repro.core.config import ModelConfig, QuantConfig, SpecConfig  # noqa: F401
from repro.core.paged_cache import (  # noqa: F401
    BlockPool,
    blocks_for_tokens,
    gather_block_rows,
    init_paged_cache,
    request_demand_tokens,
)
from repro.core.drafting import draft_tokens, draft_tree_tokens  # noqa: F401
from repro.core.tree import TreeTemplate  # noqa: F401
from repro.core.verification import (  # noqa: F401
    TreeVerifyResult,
    VerifyResult,
    verify,
    verify_tree,
)
from repro.core.protocols import (  # noqa: F401
    DraftProposal,
    Drafter,
    Verifier,
    available_drafters,
    available_verifiers,
    get_drafter,
    get_verifier,
    register_drafter,
    register_verifier,
)
from repro.core.drafters import (  # noqa: F401
    ChainTreeAdapter,
    NgramDrafter,
    NgramTreeDrafter,
    PrunedDrafter,
    VanillaDrafter,
)
from repro.core.verifiers import BF16Verifier, W4A8Verifier, W8A8Verifier  # noqa: F401
from repro.core.spec_engine import (  # noqa: F401
    init_state,
    make_decode_step,
    make_pruned_step,
    make_serve_step,
    make_vanilla_step,
)
