"""The paper's primary contribution: quantized self-speculative decoding."""
from repro.core.config import ModelConfig, QuantConfig, SpecConfig  # noqa: F401
from repro.core.drafting import draft_tokens  # noqa: F401
from repro.core.verification import verify, VerifyResult  # noqa: F401
from repro.core.spec_engine import (  # noqa: F401
    init_state,
    make_pruned_step,
    make_serve_step,
    make_vanilla_step,
)
