"""Configuration dataclasses for the Quasar reproduction framework.

Everything the framework does is driven by three configs:

* :class:`ModelConfig` — architecture definition (one per assigned arch).
* :class:`QuantConfig` — W8A8 verification settings (the paper's technique).
* :class:`SpecConfig`  — speculative-decoding settings (drafting + verify).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture definition.

    A single config class covers all six assigned arch families
    (dense / moe / ssm / hybrid / vlm / audio); the transformer stack
    builder interprets the fields that apply to each family.
    """

    name: str
    arch_type: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None      # default: d_model // num_heads

    # --- MoE ----------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None      # per-expert ffn dim (default d_ff)
    dense_residual: bool = False        # arctic: dense MLP residual branch
    router_aux_coef: float = 0.01       # load-balance aux loss coefficient
    # Expert capacity factor.  1.25 = production TPU semantics (token
    # dropping possible under load, which makes outputs depend on what else
    # is in the batch — standard).  Setting it to num_experts·k makes the
    # dispatch dropless and exactly path-independent; reduced() smoke
    # configs do that so cached-vs-full equivalence tests are exact.
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) --------------------------------------------
    ssm_state: int = 0                  # N: state dim per head
    ssm_head_dim: int = 64              # P: channels per SSD head
    ssm_expand: int = 2                 # d_inner = expand * d_model
    ssm_chunk: int = 128                # SSD chunk length

    # --- hybrid (zamba2-style) -----------------------------------------
    attn_every: int = 0                 # insert a (shared) attn block every k layers
    shared_attn: bool = False           # zamba2: attention block weights shared

    # --- VLM (llama-3.2-vision-style) ------------------------------------
    cross_attn_every: int = 0           # every k-th layer is a cross-attn layer
    num_image_tokens: int = 0           # patch-embedding stub length

    # --- audio enc-dec (whisper-style) -----------------------------------
    encoder_layers: int = 0             # 0 => decoder-only
    num_audio_frames: int = 0           # mel-frame embedding stub length

    # --- attention / misc ------------------------------------------------
    sliding_window: Optional[int] = None   # None => full causal attention
    rope_theta: float = 10000.0
    use_rope: bool = True
    norm: str = "rmsnorm"               # rmsnorm | layernorm
    act: str = "silu"                   # silu | gelu
    glu: bool = True                    # gated FFN (silu(x W_g) * x W_u) W_d
    attn_bias: bool = False             # bias on q/k/v projections (qwen-style)
    ffn_bias: bool = False              # bias on FFN + attn-out (whisper-style)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: jnp.dtype = jnp.bfloat16
    # "int8": KV cache stored int8 with per-(token, head) scales; scales are
    # folded into attention scores/probs exactly (no dequant temps), halving
    # decode-time cache streaming.  Beyond-paper extension (the paper's
    # "ultra-low bit" future-work direction applied to the KV cache).
    kv_cache_dtype: str = "bf16"
    # attention implementation for the flash-eligible cache-read
    # decode/verify path (contiguous cache, causal, no sliding window):
    #   "auto"   — backend policy: compiled Pallas flash-decode kernel on
    #              TPU, interpret-mode kernel under REPRO_USE_PALLAS=1,
    #              pure-jnp otherwise (numerically identical);
    #   "pallas" — force the kernel (interpret mode off-TPU);
    #   "jnp"    — force the pure-jnp path.
    # Ineligible calls (ring buffer, cross-attn, train/prefill) always
    # run jnp; see docs/decoding_api.md "Kernel dispatch".
    attn_impl: str = "auto"
    source: str = ""                    # citation for the config

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.num_experts and self.moe_d_ff is None:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops and Eq. 11)."""
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn, n_cross, n_ssm, n_moe, n_dense_ffn = self._layer_census()
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        per_ffn = (3 if self.glu else 2) * D * F
        moe_ffn = 0
        if self.is_moe:
            e_ffn = (3 if self.glu else 2) * D * self.moe_d_ff
            moe_ffn = self.num_experts * e_ffn + D * self.num_experts
            if self.dense_residual:
                moe_ffn += per_ffn
        ssm = 0
        if n_ssm:
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = D * (2 * di + 2 * N + H) + di * D + di  # in/out proj + conv-ish
        per_layer = (
            n_attn * (attn + (per_ffn if not self.is_moe else 0))
            + n_cross * attn
            + n_moe * moe_ffn
            + n_ssm * ssm
        )
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + per_ffn)
        return emb + per_layer + enc

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        e_ffn = (3 if self.glu else 2) * self.d_model * self.moe_d_ff
        _, _, _, n_moe, _ = self._layer_census()
        inactive = n_moe * (self.num_experts - self.experts_per_token) * e_ffn
        return full - inactive

    def _layer_census(self) -> Tuple[int, int, int, int, int]:
        """(n_self_attn, n_cross_attn, n_ssm, n_moe_ffn, n_dense_ffn) decoder layers."""
        L = self.num_layers
        if self.arch_type == "ssm":
            return 0, 0, L, 0, 0
        if self.arch_type == "hybrid":
            n_attn = L // self.attn_every if self.attn_every else 0
            return n_attn, 0, L, 0, 0
        n_cross = L // self.cross_attn_every if self.cross_attn_every else 0
        n_self = L - n_cross
        if self.is_moe:
            return n_self, n_cross, 0, L, 0
        return n_self, n_cross, 0, 0, L

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant of the same family: ≤2 layers, d_model ≤ 512, ≤4 experts."""
        heads = min(self.num_heads, 4)
        kv = min(self.num_kv_heads, heads)
        while kv and heads % kv:
            kv -= 1
        hd = 32
        d = hd * max(heads, 4)
        return dataclasses.replace(
            self,
            num_layers=2,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=4 * d if self.d_ff else 0,
            moe_d_ff=2 * d if self.is_moe else None,
            vocab_size=256,
            num_experts=min(self.num_experts, 4) if self.is_moe else 0,
            experts_per_token=min(self.experts_per_token, 2) if self.is_moe else 0,
            moe_capacity_factor=float(min(self.num_experts, 4)) if self.is_moe else 1.25,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 128,
            attn_every=2 if self.attn_every else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            num_image_tokens=8 if self.num_image_tokens else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            num_audio_frames=16 if self.num_audio_frames else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            # f32 for smoke tests: with random-init weights the logit gaps are
            # tiny, and bf16 fusion noise under jit can flip argmax — f32 keeps
            # losslessness tests deterministic.
            dtype=jnp.float32,
        )


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """W8A8 quantized-verification settings (paper §3.2-3.3)."""

    enabled: bool = True
    alpha: float = 0.5                  # SmoothQuant migration strength (Eq. 5)
    w_bits: int = 8
    a_bits: int = 8
    per_channel_weights: bool = True    # per-out-channel Δw
    per_token_activations: bool = True  # per-row dynamic Δx
    quantize_embedding: bool = False    # embeddings/router stay BF16
    calib_batches: int = 4
    calib_seq_len: int = 128
    use_pallas: bool = False            # route W8A8 matmul through the Pallas kernel


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding settings (paper §3.1, §4.4).

    ``drafter`` / ``verifier`` name entries in the plugin registries
    (``repro.core.protocols``); the engine resolves them with
    ``get_drafter`` / ``get_verifier``.  ``verifier="w8a8"`` alone drives
    quantized verification — ``W8A8Verifier.prepare`` quantizes the params
    inside the engine, no manual ``quantize_params`` at call sites.
    """

    gamma: int = 5                      # draft length γ
    k_min: int = 1                      # prompt-lookup n-gram min
    k_max: int = 4                      # prompt-lookup n-gram max (paper: ≤4)
    temperature: float = 0.0
    max_new_tokens: int = 64
    drafter: str = "ngram"              # registered: ngram | vanilla |
    #                                     pruned | ngram-tree
    verifier: str = "w8a8"              # registered: w8a8 | w4a8 | bf16
    pruned_retention: float = 0.75      # for the Table-5 baseline
    # per-depth branch factors for tree drafters ("ngram-tree"); None ⇒
    # the degenerate (1,)*gamma chain template.  E.g. (3, 2, 1, 1) = 3
    # root continuations, each forked once at depth 2, chains below.
    tree_branches: Optional[Tuple[int, ...]] = None
    # KV-cache layout on the continuous-batching serving path
    # (``SpecEngine.generate_requests``):
    #   "contiguous" — one max-length K/V row per scheduler slot (the
    #                  default; also the only layout for solo ``generate``);
    #   "paged"      — block-granular pools + per-slot block tables
    #                  (``repro.core.paged_cache``): admission reserves a
    #                  request's worst-case block demand instead of a
    #                  max-length row, blocks are appended as the row
    #                  commits and released at harvest.  Bit-identical to
    #                  contiguous per drafter × verifier (asserted in
    #                  tests/test_paged_cache.py).  Attention-family
    #                  (dense/moe, full-causal) archs only.
    kv_layout: str = "contiguous"
    kv_block_size: int = 128            # tokens per paged block
    # physical pool size in blocks (incl. the scratch block); None ⇒ the
    # engine sizes it to the batch-slot count's worst-case demand, which
    # makes paged admission never stricter than contiguous admission
    kv_pool_blocks: Optional[int] = None
    # prefix caching: store shared prompt prefixes once via a hash →
    # block-chain index with refcounted blocks; prefill skips cached
    # full blocks (chunked prefill for the cold tail) and tree/chain
    # commits copy-on-write the partially-filled boundary block.
    # Paged layout only; bit-identical to unshared (tests/
    # test_prefix_sharing.py).
    kv_prefix_sharing: bool = True
    # preemption-and-swap: when paged admission fails, evict the
    # lowest-priority running slot's blocks to a host-side numpy swap
    # pool and resume later by re-alloc + copy-back, instead of holding
    # the worst-case reservation as a hard capacity ceiling.
    kv_preempt: bool = True
