"""Registered :class:`~repro.core.protocols.Drafter` implementations.

* ``ngram``      — prompt-lookup (PLD) self-drafting, the paper's strategy.
* ``vanilla``    — degenerate gamma=0 drafter: the unified decode step
  reduces to the autoregressive baseline (one token per forward).
* ``pruned``     — Table-5 baseline: the first ``retention * L`` layers of
  the target model draft gamma tokens autoregressively (stochastic q at
  T>0).
* ``ngram-tree`` — token-tree prompt lookup: a static
  :class:`~repro.core.tree.TreeTemplate` populated from the top-k most
  recent n-gram matches; verified down the tree (longest accepted
  root-to-leaf path).

:class:`ChainTreeAdapter` runs *any* chain drafter through the tree
verification path as the degenerate single-branch tree — the
bit-equality bridge the tree tests are built on.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import prng
from repro.core.config import SpecConfig
from repro.core.drafting import draft_tokens, draft_tree_tokens
from repro.core.protocols import DraftProposal, Drafter, register_drafter
from repro.core.tree import TreeTemplate


@register_drafter("ngram")
class NgramDrafter(Drafter):
    """Prompt-lookup drafting (paper §4.1): match the trailing k-gram of
    the committed text against itself, propose the gamma tokens that
    followed the most recent match.  Deterministic (``probs=None``),
    stateless, and cache-free — drafting cost is a token-buffer scan."""

    def __init__(self, gamma: int = 5, k_min: int = 1, k_max: int = 4):
        self.gamma = gamma
        self.k_min = k_min
        self.k_max = k_max

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "NgramDrafter":
        return cls(gamma=scfg.gamma, k_min=scfg.k_min, k_max=scfg.k_max)

    def propose(self, model, params, tokens, length, dstate, key):
        drafts = draft_tokens(tokens, length, gamma=self.gamma,
                              k_min=self.k_min, k_max=self.k_max)
        return DraftProposal(tokens=drafts, probs=None), dstate, key


@register_drafter("ngram-tree")
class NgramTreeDrafter(Drafter):
    """Token-tree prompt-lookup drafting (SpecInfer-style topology over
    the paper's PLD strategy): one verifier pass scores ``num_leaves``
    candidate continuations instead of one.  Deterministic
    (``probs=None``), stateless, cache-free.  Exposes ``template`` — the
    static topology the decode step builds its tree path from — and
    attaches the template's ``parents``/``tree_mask`` to every proposal.
    """

    def __init__(self, template: TreeTemplate | None = None, *,
                 gamma: int = 5, k_min: int = 1, k_max: int = 4):
        self.template = (template if template is not None
                         else TreeTemplate.chain(gamma))
        self.gamma = self.template.gamma
        self.k_min = k_min
        self.k_max = k_max

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "NgramTreeDrafter":
        tpl = (TreeTemplate(scfg.tree_branches) if scfg.tree_branches
               else TreeTemplate.chain(scfg.gamma))
        return cls(tpl, k_min=scfg.k_min, k_max=scfg.k_max)

    def propose(self, model, params, tokens, length, dstate, key):
        drafts = draft_tree_tokens(tokens, length, self.template,
                                   k_min=self.k_min, k_max=self.k_max)
        return DraftProposal(tokens=drafts, probs=None,
                             parents=self.template.parents_dev,
                             tree_mask=self.template.mask_dev), dstate, key


class ChainTreeAdapter(Drafter):
    """Run any chain drafter through the tree verification path.

    Wraps a base :class:`Drafter` with the degenerate single-branch
    :class:`TreeTemplate`, delegating every lifecycle hook.  The decode
    step then takes the tree route — depth positions, ancestor mask,
    path commit — which must be *bit-identical* to the chain route
    (``tests/test_tree.py`` asserts it per drafter × verifier).  Also the
    template for bolting tree verification onto custom chain drafters.
    """

    name = "chain-tree"

    def __init__(self, base: Drafter):
        self.base = base
        self.gamma = base.gamma
        self.template = TreeTemplate.chain(base.gamma)

    def with_temperature(self, temperature: float) -> "ChainTreeAdapter":
        return ChainTreeAdapter(self.base.with_temperature(temperature))

    def init_state(self, model, params, prompts, buf_len, *,
                   aux_embeds=None, draft_params=None):
        return self.base.init_state(model, params, prompts, buf_len,
                                    aux_embeds=aux_embeds,
                                    draft_params=draft_params)

    def alloc_state(self, model, params, batch, buf_len, *,
                    draft_params=None):
        return self.base.alloc_state(model, params, batch, buf_len,
                                     draft_params=draft_params)

    def prefill_row(self, model, params, dstate, row, prompt, buf_len, *,
                    aux_embeds=None, draft_params=None):
        return self.base.prefill_row(model, params, dstate, row, prompt,
                                     buf_len, aux_embeds=aux_embeds,
                                     draft_params=draft_params)

    def propose(self, model, params, tokens, length, dstate, key):
        proposal, dstate, key = self.base.propose(model, params, tokens,
                                                  length, dstate, key)
        return proposal._replace(parents=self.template.parents_dev,
                                 tree_mask=self.template.mask_dev), \
            dstate, key

    def advance(self, model, dstate, proposal, n_accept):
        return self.base.advance(model, dstate, proposal, n_accept)


@register_drafter("vanilla")
class VanillaDrafter(Drafter):
    """gamma=0: propose nothing.  The verify window degenerates to the last
    committed token, so each decode step commits exactly one token — the
    autoregressive baseline expressed through the same unified step."""

    gamma = 0

    def propose(self, model, params, tokens, length, dstate, key):
        B = tokens.shape[0]
        empty = jnp.zeros((B, 0), jnp.int32)
        return DraftProposal(tokens=empty, probs=None), dstate, key


@register_drafter("pruned")
class PrunedDrafter(Drafter):
    """Structurally pruned self-drafting (paper Table 5): the first
    ``retention * L`` layers draft gamma tokens autoregressively against
    their own KV cache (the ``drafter_state``); the full model verifies.

    Stochastic at T>0, so ``probs`` carries the per-step draft
    distribution q for the full Eq. 2 ratio.  Attention-family archs only
    (SSM drafter rollback would need per-step states inside a scan; the
    paper's Table 5 uses a dense model).
    """

    def __init__(self, gamma: int = 5, retention: float = 0.75,
                 temperature: float = 0.0):
        self.gamma = gamma
        self.retention = retention
        self.temperature = temperature

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "PrunedDrafter":
        return cls(gamma=scfg.gamma, retention=scfg.pruned_retention,
                   temperature=scfg.temperature)

    def with_temperature(self, temperature: float) -> "PrunedDrafter":
        return PrunedDrafter(gamma=self.gamma, retention=self.retention,
                             temperature=temperature)

    def n_keep(self, model) -> int:
        return max(1, int(round(model.cfg.num_layers * self.retention)))

    def init_state(self, model, params, prompts, buf_len: int, *,
                   aux_embeds=None, draft_params=None):
        n_keep = self.n_keep(model)
        B = prompts.shape[0]
        pcache = model.init_cache(B, buf_len, num_layers=n_keep)
        return model.prefill(
            draft_params if draft_params is not None else params,
            pcache, prompts[:, :-1], aux_embeds=aux_embeds,
            num_layers=n_keep,
        )

    def alloc_state(self, model, params, batch: int, buf_len: int, *,
                    draft_params=None):
        # empty (un-prefilled) draft cache; rows are filled on admission
        return model.init_cache(batch, buf_len, num_layers=self.n_keep(model))

    def propose(self, model, params, tokens, length, dstate, key):
        n_keep = self.n_keep(model)
        pcache = dstate
        per_row = prng.is_per_row(key)
        tok = jnp.take_along_axis(
            tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        pos = jnp.maximum(length - 1, 0)
        drafts, qprobs = [], []
        for i in range(self.gamma):  # unrolled: gamma is small and static
            logits, pcache = model.decode_step(params, pcache, tok, pos + i,
                                               num_layers=n_keep)
            lf = logits[:, -1].astype(jnp.float32)
            if self.temperature == 0.0:
                nxt = jnp.argmax(lf, axis=-1).astype(jnp.int32)
                qprobs.append(jax.nn.one_hot(nxt, lf.shape[-1],
                                             dtype=jnp.float32))
            else:
                key, sub = prng.next_key(key)
                q = jax.nn.softmax(lf / self.temperature, axis=-1)
                logq = jnp.log(jnp.maximum(q, 1e-30))
                nxt = (prng.categorical_rows(sub, logq) if per_row
                       else jax.random.categorical(sub, logq)).astype(jnp.int32)
                qprobs.append(q)
            drafts.append(nxt)
            tok = nxt[:, None]
        proposal = DraftProposal(
            tokens=jnp.stack(drafts, axis=1),                 # (B, gamma)
            probs=jnp.stack(qprobs, axis=1),                  # (B, gamma, V)
        )
        return proposal, pcache, key
