"""Prompt-lookup (n-gram) self-speculative drafting — pure ``jax.lax``.

The paper's drafting strategy (§4.1, baseline "Ngram"/PLD, Somasundaram et
al. 2025): match the trailing k-gram of the generated context against the
context itself and propose the γ tokens that followed the most recent
match.  k is adjusted dynamically between ``k_min`` and ``k_max`` (paper:
min 1, max 4): the longest k with a match wins.

Vectorized over the batch; everything is fixed-shape so it jits and lowers
for the production mesh.  When no k-gram matches, the drafted tokens repeat
the last token — verification rejects bad drafts anyway (losslessness,
Eq. 2-3), this only costs acceptance length, exactly as in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _match_k(tokens: jax.Array, length: jax.Array, k: int):
    """Most recent occurrence of the trailing k-gram.

    tokens: (B, S) committed-token buffer; length: (B,) committed counts.
    Returns (found (B,) bool, start (B,) int32 — index *after* the match).
    """
    B, S = tokens.shape
    # trailing k-gram per row: tokens[l-k : l]
    tail_idx = length[:, None] - k + jnp.arange(k)[None, :]          # (B, k)
    tail = jnp.take_along_axis(tokens, jnp.maximum(tail_idx, 0), axis=1)

    # windows[b, j, i] = tokens[b, j + i] for j in [0, S-k]
    win = jnp.stack([tokens[:, i : S - k + 1 + i] for i in range(k)], axis=-1)
    eq = jnp.all(win == tail[:, None, :], axis=-1)                   # (B, S-k+1)

    j = jnp.arange(S - k + 1)[None, :]
    # exclude the trailing gram itself and anything beyond the committed text
    valid = eq & (j < length[:, None] - k) & (length[:, None] >= 2 * k)
    found = jnp.any(valid, axis=1)
    best = jnp.argmax(jnp.where(valid, j, -1), axis=1)               # most recent
    return found, best + k


def draft_tokens(
    tokens: jax.Array,     # (B, S) committed token buffer
    length: jax.Array,     # (B,) committed lengths
    *,
    gamma: int,
    k_min: int = 1,
    k_max: int = 4,
) -> jax.Array:
    """Propose γ draft tokens per row.  Returns (B, γ) int32."""
    B, S = tokens.shape
    start = jnp.zeros((B,), jnp.int32)
    found_any = jnp.zeros((B,), bool)
    # longest matching k wins: scan k from k_min upward, later (longer) k
    # overwrite earlier ones where they match
    for k in range(k_min, k_max + 1):
        found, st = _match_k(tokens, length, k)
        start = jnp.where(found, st.astype(jnp.int32), start)
        found_any = found_any | found

    idx = start[:, None] + jnp.arange(gamma)[None, :]                # (B, γ)
    # clamp reads into the committed region; beyond-text positions fall back
    # to repeating the most recent committed token
    last = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
    in_text = (idx < length[:, None]) & found_any[:, None]
    drafts = jnp.take_along_axis(tokens, jnp.clip(idx, 0, S - 1), axis=1)
    return jnp.where(in_text, drafts, last).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("gamma", "k_min", "k_max"))
def draft_tokens_jit(tokens, length, gamma: int, k_min: int = 1, k_max: int = 4):
    return draft_tokens(tokens, length, gamma=gamma, k_min=k_min, k_max=k_max)
