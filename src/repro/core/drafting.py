"""Prompt-lookup (n-gram) self-speculative drafting — pure ``jax.lax``.

The paper's drafting strategy (§4.1, baseline "Ngram"/PLD, Somasundaram et
al. 2025): match the trailing k-gram of the generated context against the
context itself and propose the γ tokens that followed the most recent
match.  k is adjusted dynamically between ``k_min`` and ``k_max`` (paper:
min 1, max 4): the longest k with a match wins.

Vectorized over the batch; everything is fixed-shape so it jits and lowers
for the production mesh.  When no k-gram matches, the drafted tokens repeat
the last token — verification rejects bad drafts anyway (losslessness,
Eq. 2-3), this only costs acceptance length, exactly as in the paper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _match_valid(tokens: jax.Array, length: jax.Array, k: int):
    """Validity mask of trailing-k-gram matches.

    tokens: (B, S) committed-token buffer; length: (B,) committed counts.
    Returns ``valid`` (B, S-k+1) bool — position j starts an occurrence of
    the trailing k-gram strictly before the trailing gram itself.
    """
    B, S = tokens.shape
    # trailing k-gram per row: tokens[l-k : l]
    tail_idx = length[:, None] - k + jnp.arange(k)[None, :]          # (B, k)
    tail = jnp.take_along_axis(tokens, jnp.maximum(tail_idx, 0), axis=1)

    # windows[b, j, i] = tokens[b, j + i] for j in [0, S-k]
    win = jnp.stack([tokens[:, i : S - k + 1 + i] for i in range(k)], axis=-1)
    eq = jnp.all(win == tail[:, None, :], axis=-1)                   # (B, S-k+1)

    j = jnp.arange(S - k + 1)[None, :]
    # exclude the trailing gram itself and anything beyond the committed text
    return eq & (j < length[:, None] - k) & (length[:, None] >= 2 * k)


def _match_k(tokens: jax.Array, length: jax.Array, k: int):
    """Most recent occurrence of the trailing k-gram.

    Returns (found (B,) bool, start (B,) int32 — index *after* the match).
    """
    valid = _match_valid(tokens, length, k)
    j = jnp.arange(valid.shape[1])[None, :]
    found = jnp.any(valid, axis=1)
    best = jnp.argmax(jnp.where(valid, j, -1), axis=1)               # most recent
    return found, best + k


def _match_k_top(tokens: jax.Array, length: jax.Array, k: int, m: int):
    """The ``m`` most recent trailing-k-gram occurrences (tree drafting).

    Returns (found (B,) bool, starts (B, m) int32 — index after each
    match, most recent first, valid (B, m) bool).  Rows with fewer than
    ``m`` occurrences have trailing invalid slots.
    """
    valid = _match_valid(tokens, length, k)
    j = jnp.arange(valid.shape[1])[None, :]
    scored = jnp.where(valid, j, -1)
    top, _ = jax.lax.top_k(scored, min(m, valid.shape[1]))           # (B, ≤m)
    if top.shape[1] < m:
        top = jnp.pad(top, ((0, 0), (0, m - top.shape[1])),
                      constant_values=-1)
    return jnp.any(valid, axis=1), top + k, top >= 0


def draft_tokens(
    tokens: jax.Array,     # (B, S) committed token buffer
    length: jax.Array,     # (B,) committed lengths
    *,
    gamma: int,
    k_min: int = 1,
    k_max: int = 4,
) -> jax.Array:
    """Propose γ draft tokens per row.  Returns (B, γ) int32."""
    B, S = tokens.shape
    start = jnp.zeros((B,), jnp.int32)
    found_any = jnp.zeros((B,), bool)
    # longest matching k wins: scan k from k_min upward, later (longer) k
    # overwrite earlier ones where they match
    for k in range(k_min, k_max + 1):
        found, st = _match_k(tokens, length, k)
        start = jnp.where(found, st.astype(jnp.int32), start)
        found_any = found_any | found

    idx = start[:, None] + jnp.arange(gamma)[None, :]                # (B, γ)
    # clamp reads into the committed region; beyond-text positions fall back
    # to repeating the most recent committed token
    last = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
    in_text = (idx < length[:, None]) & found_any[:, None]
    drafts = jnp.take_along_axis(tokens, jnp.clip(idx, 0, S - 1), axis=1)
    return jnp.where(in_text, drafts, last).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("gamma", "k_min", "k_max"))
def draft_tokens_jit(tokens, length, gamma: int, k_min: int = 1, k_max: int = 4):
    return draft_tokens(tokens, length, gamma=gamma, k_min=k_min, k_max=k_max)


def draft_tree_tokens(
    tokens: jax.Array,     # (B, S) committed token buffer
    length: jax.Array,     # (B,) committed lengths
    template,              # repro.core.tree.TreeTemplate (static)
    *,
    k_min: int = 1,
    k_max: int = 4,
) -> jax.Array:
    """Populate a token-tree template from top-k prompt-lookup matches.

    Where chain PLD proposes the continuation of the *single* most recent
    trailing-k-gram match, the tree drafter gathers the most recent
    matches (longest matching k wins, as in :func:`draft_tokens`),
    **diversifies** them — matches whose first continuation token
    duplicates an earlier (more recent) match are stably pushed back, so
    the *root's* children cover distinct continuations where the text
    diverges — and routes match ``m``'s continuation down the template's
    ``m``-th root-to-leaf path: a node at depth ``d`` takes token ``d-1``
    of its *representative* (smallest-ordinal) leaf's continuation.
    Child 0 of the root therefore always carries the chain drafter's
    proposal, and rows with fewer matches than leaves fall back to the
    most recent one (duplicate subtrees cost acceptance, never
    correctness).  Returns the (B, N-1) packed draft tokens (node 0 —
    the committed root — excluded).

    Caveat: diversification is applied at the match's *first* token, so
    only forks at depth 1 are guaranteed coherent.  A fork deeper in the
    template splices a different match's tail onto the representative
    leaf's prefix — still lossless, but such branches only accept past
    the fork when the matches happen to agree up to it.  Prefer
    root-heavy templates (e.g. ``(3, 2, 1, 1)`` over ``(1, 1, 2, 3)``);
    trie-consistent population of sub-root forks is a ROADMAP follow-up.
    """
    B, S = tokens.shape
    M, D = template.num_leaves, template.max_depth
    if D == 0:
        return jnp.zeros((B, 0), jnp.int32)

    M2 = M + 8 if M > 1 else M     # extra candidates for the dedupe pass
    starts = jnp.zeros((B, M2), jnp.int32)
    svalid = jnp.zeros((B, M2), bool)
    found_any = jnp.zeros((B,), bool)
    # longest matching k wins, exactly as in the chain drafter
    for k in range(k_min, k_max + 1):
        found, st, v = _match_k_top(tokens, length, k, M2)
        starts = jnp.where(found[:, None], st.astype(jnp.int32), starts)
        svalid = jnp.where(found[:, None], v, svalid)
        found_any = found_any | found

    # slots beyond the row's match count reuse the most recent match
    starts = jnp.where(svalid, starts, starts[:, :1])
    if M2 > M:
        # first continuation token of each candidate match
        tok0 = jnp.take_along_axis(tokens, jnp.clip(starts, 0, S - 1),
                                   axis=1)                        # (B, M2)
        dup = jnp.any((tok0[:, :, None] == tok0[:, None, :])
                      & (jnp.arange(M2)[None, :] < jnp.arange(M2)[:, None]
                         )[None], axis=2)                         # (B, M2)
        # stable compaction: fresh tokens first, recency order inside
        order = jnp.argsort(dup.astype(jnp.int32) * M2
                            + jnp.arange(M2)[None, :], axis=1)
        starts = jnp.take_along_axis(starts, order[:, :M], axis=1)

    # continuations: cont[b, m, d] = tokens[b, starts[b, m] + d]
    idx = starts[:, :, None] + jnp.arange(D)[None, None, :]          # (B, M, D)
    last = jnp.take_along_axis(tokens,
                               jnp.maximum(length - 1, 0)[:, None], axis=1)
    in_text = (idx < length[:, None, None]) & found_any[:, None, None]
    flat = jnp.take_along_axis(tokens, jnp.clip(idx, 0, S - 1).reshape(B, M * D),
                               axis=1).reshape(B, M, D)
    cont = jnp.where(in_text, flat, last[:, :, None])

    # scatter continuations into packed node order (static index tables)
    node_leaf = template.src_leaf[1:]                                # (N-1,)
    node_depth = template.depths[1:] - 1
    return cont[:, node_leaf, node_depth].astype(jnp.int32)
