"""Paged (block-granular) KV cache: the scheduler's serving-path layout.

The contiguous cache layout allocates one ``(S_max, Hkv, dh)`` K/V row
per scheduler slot, so a 32k-capable serving group pays 32k rows of HBM
for every 200-token request and ``batch_slots`` is pinned to worst-case
memory.  The paged layout (vLLM-style, cf. S3D / Zhong & Bharadwaj 2024)
breaks that coupling:

* one **physical pool** per attention layer — ``(num_blocks, block_size,
  Hkv, dh)`` K and V buffers shared by every slot (int8 KV adds the
  per-(token, head) scale pools, same block granularity);
* one **block table** — ``(batch_slots, max_blocks)`` int32 mapping each
  slot's *logical* block ``s // block_size`` to a physical block id.
  Entry 0 is the reserved **scratch block**: unallocated table entries
  point at it, so out-of-range writes land harmlessly in a block no
  request owns and out-of-range reads return junk that position masking
  discards (exactly like the unwritten tail of a contiguous row);
* a host-side :class:`BlockPool` free-list allocator driving the
  admission → append → release lifecycle:

  - **admission** *reserves* the request's worst-case block demand
    (:func:`request_demand_tokens`) — the scheduler admits only when the
    reservation fits, which is what makes ``batch_slots`` a throughput
    knob instead of a memory bound — and *allocates* the prompt's
    blocks, scattering the single-row contiguous prefill into them;
  - **append-on-commit**: as a row's committed length grows, the engine
    tops up its blocks between decode steps (host-side ``.at[].set`` on
    the block table — the jitted step never retraces);
  - **release-on-harvest** returns every block (and the reservation) to
    the free list.

Correctness story: the decode step only ever *reads* logical slots that
are either committed content or freshly written by the current verify
window, so block-granular allocation (and the junk in just-appended or
scratch blocks) is invisible to the logits — paged serving is asserted
**bit-identical** to contiguous serving per drafter × verifier in
``tests/test_paged_cache.py``, the same losslessness bar PRs 2-4 set
for scheduling, trees and kernel dispatch.

Device-side layout helpers (:func:`gather_block_rows`,
:func:`physical_slots`) are shared by the jnp read/write path in
``models/attention.py``, the Pallas ``flash_decode_paged`` kernel's
oracle, and the reconstruction property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK_SIZE = 128    # tokens per block; 128 keeps pools lane-aligned
SCRATCH_BLOCK = 0           # physical block 0: never allocated, absorbs
#                             writes from idle rows / unallocated slots


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows (ceil division)."""
    return -(-int(n_tokens) // int(block_size))


def request_demand_tokens(prompt_len: int, max_new_tokens: int,
                          gamma: int) -> int:
    """Worst-case cache rows one request ever writes.

    The last verify window starts at ``length - 1`` with ``length`` at
    most ``prompt_len + max_new_tokens`` and spans ``gamma + 1`` slots,
    so the highest written row is ``P + max_new + gamma - 1``; +1 slack
    mirrors the contiguous buffer sizing.
    """
    return int(prompt_len) + int(max_new_tokens) + int(gamma) + 1


class BlockPool:
    """Host-side free-list allocator for the physical block pool.

    Tracks three disjoint quantities over ``num_blocks - 1`` allocatable
    blocks (block 0 is scratch):

    * **free** — on the free list, owned by nobody;
    * **allocated** — owned by exactly one request id;
    * **reserved** — admission-time worst-case demand per request;
      ``alloc`` may only draw up to the reservation, which guarantees
      mid-flight appends never fail once a request is admitted.

    Invariants (asserted by the property tests in
    ``tests/test_paged_cache.py``):

    * a block id is owned by at most one request (no double-allocation);
    * ``free + sum(allocated) == num_blocks - 1`` at all times (no leak);
    * ``sum(reserved) <= num_blocks - 1`` (admission control is sound).
    """

    def __init__(self, num_blocks: int, block_size: int = DEFAULT_BLOCK_SIZE):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 scratch + 1 usable), "
                             f"got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO free list: recently released blocks are re-used first
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._owned: Dict[int, List[int]] = {}      # rid -> block ids
        self._reserved: Dict[int, int] = {}         # rid -> total blocks

    # -- capacity ------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the scratch block)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    @property
    def allocated_blocks(self) -> int:
        return sum(len(b) for b in self._owned.values())

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for_tokens(n_tokens, self.block_size)

    # -- lifecycle -----------------------------------------------------
    def can_reserve(self, n_blocks: int) -> bool:
        """Admission check: does a further ``n_blocks`` reservation fit?"""
        return self.reserved_blocks + int(n_blocks) <= self.capacity

    def reserve(self, rid: int, n_blocks: int) -> None:
        """Reserve worst-case demand for request ``rid`` at admission."""
        if rid in self._reserved:
            raise ValueError(f"request {rid} already reserved")
        if not self.can_reserve(n_blocks):
            raise ValueError(
                f"pool over-committed: reserve({n_blocks}) with "
                f"{self.capacity - self.reserved_blocks} unreserved")
        self._reserved[rid] = int(n_blocks)
        self._owned.setdefault(rid, [])

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, []))

    def alloc(self, rid: int, n_blocks: int) -> List[int]:
        """Draw ``n_blocks`` from the free list for ``rid`` (<= its
        reservation; admission control makes this infallible)."""
        if rid not in self._reserved:
            raise ValueError(f"request {rid} has no reservation")
        have = len(self._owned[rid])
        if have + n_blocks > self._reserved[rid]:
            raise ValueError(
                f"request {rid} alloc beyond reservation: "
                f"{have}+{n_blocks} > {self._reserved[rid]}")
        if n_blocks > len(self._free):
            raise RuntimeError(      # unreachable if reservations are honoured
                f"free list exhausted: want {n_blocks}, have "
                f"{len(self._free)} (reservation accounting broken)")
        ids = [self._free.pop() for _ in range(int(n_blocks))]
        self._owned[rid].extend(ids)
        return ids

    def release(self, rid: int) -> List[int]:
        """Free every block owned by ``rid`` and drop its reservation."""
        ids = self._owned.pop(rid, [])
        self._reserved.pop(rid, None)
        self._free.extend(reversed(ids))
        return ids

    def check_invariants(self) -> None:
        """Raise if conservation or exclusivity is violated."""
        owned_all = [b for ids in self._owned.values() for b in ids]
        assert len(owned_all) == len(set(owned_all)), "block double-allocated"
        assert SCRATCH_BLOCK not in owned_all, "scratch block allocated"
        assert SCRATCH_BLOCK not in self._free, "scratch block on free list"
        assert len(self._free) + len(owned_all) == self.capacity, (
            f"pool not conserved: {len(self._free)} free + "
            f"{len(owned_all)} owned != {self.capacity}")
        assert self.reserved_blocks <= self.capacity
        for rid, ids in self._owned.items():
            assert len(ids) <= self._reserved.get(rid, 0), (
                f"request {rid} owns beyond reservation")


# ---------------------------------------------------------------------------
# Device-side layout helpers
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, batch_slots: int, max_blocks: int,
                     num_blocks: int, block_size: int = DEFAULT_BLOCK_SIZE,
                     num_layers: Optional[int] = None) -> dict:
    """Allocate the paged serving-cache pytree.

    Returns ``{"layers": [per-layer pools], "bt": (B, max_blocks) int32}``
    where each layer pool is ``{"k", "v": (num_blocks, block_size, Hkv,
    dh)}`` (+ ``k_scale``/``v_scale`` ``(num_blocks, block_size, Hkv)``
    f32 when ``cfg.kv_cache_dtype == "int8"``).  The block table starts
    all-scratch (0).  Attention-family (dense/moe) decoder stacks only —
    the engine gates other families off before building one.
    """
    int8 = getattr(cfg, "kv_cache_dtype", "bf16") == "int8"
    dt = jnp.int8 if int8 else cfg.dtype
    n_layers = num_layers or cfg.num_layers
    layers = []
    for _ in range(n_layers):
        pool = {
            "k": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
        }
        if int8:
            pool["k_scale"] = jnp.zeros(
                (num_blocks, block_size, cfg.num_kv_heads), jnp.float32)
            pool["v_scale"] = jnp.zeros(
                (num_blocks, block_size, cfg.num_kv_heads), jnp.float32)
        layers.append(pool)
    return {
        "layers": layers,
        "bt": jnp.zeros((batch_slots, max_blocks), jnp.int32),
    }


def physical_slots(bt: jnp.ndarray, slots: jnp.ndarray,
                   block_size: int) -> jnp.ndarray:
    """Map logical cache slots to physical pool rows.

    ``bt`` is ``(B, max_blocks)`` int32, ``slots`` is ``(B, T)`` logical
    slot indices; returns ``(B, T)`` int32 rows into the pool viewed as
    ``(num_blocks * block_size, ...)``.  Out-of-range logical blocks
    clip onto the scratch block's final row — junk that position masking
    already discards.
    """
    nb = bt.shape[1]
    blk_idx = jnp.clip(slots // block_size, 0, nb - 1)
    blk = jnp.take_along_axis(bt, blk_idx, axis=1)
    in_range = (slots // block_size) < nb
    blk = jnp.where(in_range, blk, SCRATCH_BLOCK)
    return blk * block_size + slots % block_size


def gather_block_rows(pool_buf: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the logical contiguous view of one pool buffer.

    ``pool_buf`` is ``(num_blocks, block_size, ...)``; returns
    ``(B, max_blocks * block_size, ...)`` where logical slot ``s`` of
    row ``b`` holds ``pool_buf[bt[b, s // bs], s % bs]``.  This is the
    jnp read path's gather and the oracle for the paged Pallas kernel.
    """
    B, nb = bt.shape
    bs = pool_buf.shape[1]
    g = jnp.take(pool_buf, bt.reshape(-1), axis=0)          # (B*nb, bs, ...)
    return g.reshape((B, nb * bs) + pool_buf.shape[2:])


def scatter_prefill_rows(pool: dict, block_ids: Sequence[int],
                         row_cache: dict, block_size: int) -> dict:
    """Scatter a single-row *contiguous* prefill cache into pool blocks.

    ``row_cache`` leaves are ``(1, S_row, ...)``; the first
    ``len(block_ids) * block_size`` rows (zero-padded if the contiguous
    row is shorter) land in the listed physical blocks.  Writing the
    fresh-init-plus-prefill content into *every* allocated block is what
    keeps admission retrace-free and slot-recycling leak-free, exactly
    like the contiguous ``prefill_into_slot`` row reset.
    """
    n = len(block_ids)
    if n == 0:
        return pool
    idx = jnp.asarray(np.asarray(block_ids, np.int32))
    new = dict(pool)
    for name, buf in pool.items():
        row = row_cache[name][0]                             # (S_row, ...)
        need = n * block_size
        if row.shape[0] < need:
            pad = [(0, need - row.shape[0])] + [(0, 0)] * (row.ndim - 1)
            row = jnp.pad(row, pad)
        vals = row[:need].reshape((n, block_size) + row.shape[1:])
        new[name] = buf.at[idx].set(vals.astype(buf.dtype))
    return new


# ---------------------------------------------------------------------------
# Modeled footprint (used by launch/roofline.py and benchmarks/ablation_kv.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedPlan:
    """Static sizing decisions for one paged serving group."""

    block_size: int
    max_blocks: int          # block-table width (= ceil(buf / block_size))
    num_blocks: int          # physical pool size, incl. the scratch block
    slots: int               # decode rows (dynamic batch_slots)
    demands: tuple           # per-request block demand, request order


def plan_group(prompt_lens: Sequence[int], budgets: Sequence[int],
               gamma: int, buf: int, *, block_size: int,
               pool_blocks: Optional[int] = None,
               batch_slots: Optional[int] = None,
               default_slots: int = 8, max_slots: int = 64) -> PagedPlan:
    """Size the pool and pick the slot count for one serving group.

    * per-request demand = worst-case rows / ``block_size`` (ceil);
    * ``pool_blocks`` defaults to scratch + the ``min(len, default_slots)``
      *largest* demands — capacity comparable to the contiguous layout's
      default slot count, so paged never regresses admission;
    * ``slots`` (when not forced via ``batch_slots``) is **occupancy-
      derived**: the largest number of queued requests whose demands
      can actually be co-reserved (greedy, cheapest-first) — short-
      request mixes get more concurrent rows out of the same HBM than
      the contiguous layout's fixed worst-case sizing (the ROADMAP's
      admission-aware slot sizing), capped at ``max_slots``, and never
      inflated by rows the admission control could never co-house.
    """
    demands = tuple(
        blocks_for_tokens(request_demand_tokens(p, b, gamma), block_size)
        for p, b in zip(prompt_lens, budgets))
    n = len(demands)
    if pool_blocks is None:
        cap = default_slots if batch_slots is None else batch_slots
        top = sorted(demands, reverse=True)[: min(n, cap)]
        pool_blocks = 1 + sum(top)
    if max(demands) > pool_blocks - 1:
        raise ValueError(
            f"request demand {max(demands)} blocks exceeds pool capacity "
            f"{pool_blocks - 1}; raise kv_pool_blocks or shrink the request")
    if batch_slots is not None:
        slots = min(n, batch_slots)
    else:
        # greedy cheapest-first fill: how many queued requests could the
        # pool co-reserve at once?  (an upper bound on live rows — using
        # min-demand alone would allocate decode rows that admission
        # control can never co-house)
        fit, room = 0, pool_blocks - 1
        for d in sorted(demands):
            if d > room:
                break
            fit, room = fit + 1, room - d
        slots = min(n, max_slots, max(1, fit))
    return PagedPlan(block_size=block_size,
                     max_blocks=blocks_for_tokens(buf, block_size),
                     num_blocks=pool_blocks, slots=slots, demands=demands)
