"""Paged (block-granular) KV cache: the scheduler's serving-path layout.

The contiguous cache layout allocates one ``(S_max, Hkv, dh)`` K/V row
per scheduler slot, so a 32k-capable serving group pays 32k rows of HBM
for every 200-token request and ``batch_slots`` is pinned to worst-case
memory.  The paged layout (vLLM-style, cf. S3D / Zhong & Bharadwaj 2024)
breaks that coupling:

* one **physical pool** per attention layer — ``(num_blocks, block_size,
  Hkv, dh)`` K and V buffers shared by every slot (int8 KV adds the
  per-(token, head) scale pools, same block granularity);
* one **block table** — ``(batch_slots, max_blocks)`` int32 mapping each
  slot's *logical* block ``s // block_size`` to a physical block id.
  Entry 0 is the reserved **scratch block**: unallocated table entries
  point at it, so out-of-range writes land harmlessly in a block no
  request owns and out-of-range reads return junk that position masking
  discards (exactly like the unwritten tail of a contiguous row);
* a host-side :class:`BlockPool` free-list allocator driving the
  admission → append → release lifecycle:

  - **admission** *reserves* the request's worst-case block demand
    (:func:`request_demand_tokens`) — the scheduler admits only when the
    reservation fits, which is what makes ``batch_slots`` a throughput
    knob instead of a memory bound — and *allocates* the prompt's
    blocks, scattering the single-row contiguous prefill into them;
  - **append-on-commit**: as a row's committed length grows, the engine
    tops up its blocks between decode steps (host-side ``.at[].set`` on
    the block table — the jitted step never retraces);
  - **release-on-harvest** returns every block (and the reservation) to
    the free list.

**Prefix sharing** (vLLM-style, refcounted) layers on top: a
:class:`PrefixIndex` keyed by a rolling content hash maps full prompt
blocks (and the partially-filled boundary block) to the physical block
that already stores them, so an admission whose prompt shares a prefix
with an earlier request *shares* those blocks (refcount bump) instead of
re-storing them, and prefill only computes the cold tail.  Three rules
keep sharing invisible to the tokens:

* **registered rows are immutable** — an owner only ever writes cache
  rows ``>= P - 1`` (the verify frontier), and registered rows all lie
  below it, so an index entry's content never goes stale while its block
  is alive;
* **copy-on-write boundary forking** — the only block both a writer and
  a sharer can collide on is the partially-filled boundary block; a
  write into a block with ``refcount > 1`` first forks it
  (:meth:`BlockPool.cow` + :func:`clone_block`), and the per-request
  reservation carries the one-block headroom that makes the fork
  infallible (degrading to full-blocks-only donation when the pool is
  too tight to reserve it);
* **release caches, reuse evicts** — released blocks that the index
  still describes park on a *cached-free* LRU list (resurrectable by a
  later admission at zero cost) and only drop their index entries when
  the allocator actually reuses them.

**Preemption and swap**: :meth:`BlockPool.swap_out` evacuates a victim
request's blocks (refcounts decremented, reservation dropped — capacity
is freed *now*) while the engine snapshots their content to a host-side
``numpy`` pool; resuming re-reserves, re-allocates and copies back
(:func:`swap_out_blocks` / :func:`swap_in_blocks`).  ``release`` on a
swapped-out request returns its blocks exactly once — the swap already
freed them, so a finish/shed racing an eviction is a no-op, not a
double-free (regression-tested in ``tests/test_prefix_sharing.py``).

Correctness story: the decode step only ever *reads* logical slots that
are either committed content or freshly written by the current verify
window, so block-granular allocation (and the junk in just-appended or
scratch blocks) is invisible to the logits — paged serving is asserted
**bit-identical** to contiguous serving per drafter × verifier in
``tests/test_paged_cache.py``, the same losslessness bar PRs 2-4 set
for scheduling, trees and kernel dispatch.

Device-side layout helpers (:func:`gather_block_rows`,
:func:`physical_slots`) are shared by the jnp read/write path in
``models/attention.py``, the Pallas ``flash_decode_paged`` kernel's
oracle, and the reconstruction property tests.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK_SIZE = 128    # tokens per block; 128 keeps pools lane-aligned
SCRATCH_BLOCK = 0           # physical block 0: never allocated, absorbs
#                             writes from idle rows / unallocated slots


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` cache rows (ceil division)."""
    return -(-int(n_tokens) // int(block_size))


def request_demand_tokens(prompt_len: int, max_new_tokens: int,
                          gamma: int) -> int:
    """Worst-case cache rows one request ever writes.

    The last verify window starts at ``length - 1`` with ``length`` at
    most ``prompt_len + max_new_tokens`` and spans ``gamma + 1`` slots,
    so the highest written row is ``P + max_new + gamma - 1``; +1 slack
    mirrors the contiguous buffer sizing.
    """
    return int(prompt_len) + int(max_new_tokens) + int(gamma) + 1


@dataclasses.dataclass
class _PrefixEntry:
    """One indexed block: ``tokens`` are the rows it vouches for."""

    key: str                 # rolling chain hash (content-addressed)
    parent: str              # parent chain hash ("" = chain root)
    block: int               # physical block id holding the rows
    tokens: Tuple[int, ...]  # registered rows, chain order (<= block_size)


class PrefixIndex:
    """Prefix-hash → block-chain index over registered prompt blocks.

    Keys are **rolling content hashes**: ``H(parent_key, block_tokens)``,
    so a chain of full blocks is addressed by its entire token prefix and
    two different prompts can never alias (an exact token comparison on
    every hit guards the astronomically-unlikely hash collision too).
    Entries come in two flavours sharing one namespace:

    * **full-block** entries (``len(tokens) == block_size``) — walked
      greedily by :meth:`lookup` as a chain;
    * **boundary** entries (``len(tokens) < block_size``) — the
      partially-filled last prefix block.  A lookup that exhausts the
      full chain scans the parent's children for the longest common
      token prefix, so a boundary (or full) entry can be *partially*
      matched — the sharer uses only the rows both prompts agree on.

    The index never owns blocks: :class:`BlockPool` calls
    :meth:`evict_block` the moment it reuses a cached-free block, which
    drops every entry describing it.  Orphaned descendants (parent
    evicted, child block still alive) become unreachable but revalidate
    for free if the same prefix is ever re-registered — content
    addressing makes the re-registered parent land on the same key.
    """

    ROOT = ""

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self._entries: Dict[str, _PrefixEntry] = {}
        self._children: Dict[str, List[str]] = {}
        self._by_block: Dict[int, List[str]] = {}
        # probe counters: NOTE the admission gate probes speculatively
        # (can_admit may run many times per admission), so ``lookups`` /
        # ``hits`` count *probes*; admission-level hit/miss rates live in
        # PagedGroup (one count per actually-admitted request)
        self.lookups = 0
        self.hits = 0            # probes returning >= 1 shared block
        self.hit_rows = 0        # cache rows covered across hit probes
        self.evictions = 0       # entries dropped via evict_block

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _hash(parent: str, tokens: Tuple[int, ...]) -> str:
        payload = parent.encode() + b"|" + ",".join(
            str(t) for t in tokens).encode()
        return hashlib.sha256(payload).hexdigest()

    def has_block(self, block: int) -> bool:
        return block in self._by_block

    def _add(self, key: str, parent: str, block: int,
             tokens: Tuple[int, ...]) -> None:
        self._entries[key] = _PrefixEntry(key, parent, block, tokens)
        self._children.setdefault(parent, []).append(key)
        self._by_block.setdefault(block, []).append(key)

    # ------------------------------------------------------------------
    def register(self, prompt: np.ndarray, block_ids: Sequence[int], *,
                 include_boundary: bool = True) -> None:
        """Index a freshly-admitted request's prefix blocks.

        ``prompt`` is the full (unpadded) prompt; only its prefill region
        ``prompt[:-1]`` is registered — the last prompt token opens the
        first verify window and its cache row is written later.
        ``block_ids`` is the request's block list in table order.
        Existing entries win (their blocks already hold the rows);
        ``include_boundary=False`` registers the full-block chain only —
        the admission path uses it when the pool is too tight to reserve
        the copy-on-write fork headroom a donated boundary block needs.
        """
        region = np.asarray(prompt).ravel()[:-1]
        bs = self.block_size
        parent, rows, i = self.ROOT, 0, 0
        while rows + bs <= region.size:
            tok = tuple(int(t) for t in region[rows: rows + bs])
            key = self._hash(parent, tok)
            if key not in self._entries:
                self._add(key, parent, int(block_ids[i]), tok)
            parent, rows, i = key, rows + bs, i + 1
        rem = tuple(int(t) for t in region[rows:])
        if rem and include_boundary and i < len(block_ids):
            key = self._hash(parent, rem)
            if key not in self._entries:
                self._add(key, parent, int(block_ids[i]), rem)

    def lookup(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``prompt``'s prefill region.

        Returns ``(block_ids, rows)``: the physical blocks holding the
        shared prefix (chain order; the last may be partially used) and
        the number of cache rows they cover.  ``rows`` is the admission
        prefill's warm frontier — rows ``[0, rows)`` are gathered from
        the pool, rows ``[rows, P - 1)`` are the cold tail.
        """
        region = np.asarray(prompt).ravel()[:-1]
        bs = self.block_size
        ids: List[int] = []
        rows, parent = 0, self.ROOT
        while rows + bs <= region.size:
            tok = tuple(int(t) for t in region[rows: rows + bs])
            key = self._hash(parent, tok)
            e = self._entries.get(key)
            if e is None or e.tokens != tok:
                break
            ids.append(e.block)
            rows, parent = rows + bs, key
        rem = tuple(int(t) for t in region[rows:])
        if rem:
            best_m, best = 0, None
            for ck in self._children.get(parent, ()):
                e = self._entries.get(ck)
                if e is None:
                    continue
                lim = min(len(e.tokens), len(rem))
                m = 0
                while m < lim and e.tokens[m] == rem[m]:
                    m += 1
                # longest match wins; block id breaks ties determin-
                # istically so repeated lookups share the same donor
                if m > best_m or (m == best_m and m > 0
                                  and best is not None
                                  and e.block < best.block):
                    best_m, best = m, e
            if best_m > 0:
                ids.append(best.block)
                rows += best_m
        self.lookups += 1
        if ids:
            self.hits += 1
            self.hit_rows += rows
        return ids, rows

    def evict_block(self, block: int) -> None:
        """Drop every entry describing ``block`` (its content is about
        to be overwritten by a new owner)."""
        for key in self._by_block.pop(block, []):
            e = self._entries.pop(key, None)
            if e is not None:
                self.evictions += 1
                kids = self._children.get(e.parent)
                if kids is not None and key in kids:
                    kids.remove(key)


class BlockPool:
    """Host-side refcounting allocator for the physical block pool.

    Over ``num_blocks - 1`` allocatable blocks (block 0 is scratch) every
    block is in exactly one of three states:

    * **free** — on the free list, owned by nobody, not indexed;
    * **cached-free** — owned by nobody but still described by the
      :class:`PrefixIndex` (resurrectable via :meth:`share`); reused in
      LRU order when the free list runs dry, which evicts its entries;
    * **referenced** — held by ``refcount >= 1`` requests.  A block with
      ``refcount > 1`` is *shared*: it appears in several requests'
      block tables and is freed only when the last reference drops.

    Reservations guarantee appends: :meth:`reserve` books worst-case
    *fresh-block* demand per request and :meth:`alloc` / :meth:`cow` may
    only draw up to it.  The admission gate is the **slack** — free
    blocks minus every request's still-undrawn reservation — so sharing
    an already-referenced block costs nothing, resurrecting a
    cached-free one costs one slack unit, and without sharing the gate
    is provably the legacy ``reserved + n <= capacity`` rule.

    Invariants (property-tested in ``tests/test_paged_cache.py`` and
    ``tests/test_prefix_sharing.py``):

    * ``free + cached + unique_allocated == num_blocks - 1`` (no leak);
    * per-block refcount equals the number of owning requests' tables
      it appears in; blocks free only at refcount zero;
    * the scratch block is never allocated, shared or refcounted;
    * ``drawn <= reserved`` per request and ``slack >= 0`` — admission
      control is sound, mid-flight appends and COW forks never fail.
    """

    def __init__(self, num_blocks: int, block_size: int = DEFAULT_BLOCK_SIZE,
                 prefix: Optional[PrefixIndex] = None):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (1 scratch + 1 usable), "
                             f"got {num_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.prefix = prefix
        # LIFO free list: recently released blocks are re-used first
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU
        self._ref: Dict[int, int] = {}              # block -> refcount
        self._owned: Dict[int, List[int]] = {}      # rid -> table order
        self._reserved: Dict[int, int] = {}         # rid -> fresh budget
        self._drawn: Dict[int, int] = {}            # rid -> fresh drawn
        self._swapped: set = set()                  # rids evicted to host
        self.peak_allocated = 0                     # high-water unique blocks
        # fault-injection seam (serving/faults.py): called with the draw
        # size before alloc() touches the free list, so an injected
        # failure is atomic — it may raise, the pool keeps no partial
        # state.  None (the default) costs one attribute load.
        self.fault_hook = None
        # monotone event counters (observability: ServerMetrics kv_cache
        # section aggregates these through PagedGroup.snapshot)
        self.counters: Dict[str, int] = {
            "alloc_blocks": 0,       # fresh draws (alloc + COW forks)
            "freed_blocks": 0,       # refcount reached zero
            "resurrections": 0,      # cached-free blocks shared back in
            "cached_evicted": 0,     # cached-free blocks reclaimed by _draw
            "cow_forks": 0,          # shared blocks forked for a writer
            "swap_out_blocks": 0,    # blocks released via swap_out
        }

    # -- capacity ------------------------------------------------------
    @property
    def capacity(self) -> int:
        """Allocatable blocks (excludes the scratch block)."""
        return self.num_blocks - 1

    @property
    def free_blocks(self) -> int:
        """Blocks owned by nobody (plain free + cached-free)."""
        return len(self._free) + len(self._cached)

    @property
    def cached_blocks(self) -> int:
        return len(self._cached)

    @property
    def reserved_blocks(self) -> int:
        return sum(self._reserved.values())

    @property
    def allocated_blocks(self) -> int:
        """Block-table entries across requests (shared blocks counted
        once per sharer — the logical footprint)."""
        return sum(len(b) for b in self._owned.values())

    @property
    def unique_allocated(self) -> int:
        """Distinct referenced blocks (the physical footprint)."""
        return len(self._ref)

    @property
    def slack(self) -> int:
        """Free blocks not yet promised to any admitted request."""
        undrawn = sum(self._reserved[r] - self._drawn[r]
                      for r in self._reserved)
        return self.free_blocks - undrawn

    def blocks_for(self, n_tokens: int) -> int:
        return blocks_for_tokens(n_tokens, self.block_size)

    def ref(self, block: int) -> int:
        """Current refcount of ``block`` (0 = free or cached-free)."""
        return self._ref.get(int(block), 0)

    # -- lifecycle -----------------------------------------------------
    def can_reserve(self, n_blocks: int) -> bool:
        """Admission check: does a further ``n_blocks`` fresh-block
        reservation fit?  Equivalent to the legacy ``reserved + n <=
        capacity`` gate when nothing is shared or cached."""
        return int(n_blocks) <= self.slack

    def reserve(self, rid: int, n_blocks: int) -> None:
        """Book worst-case fresh-block demand for ``rid`` at admission
        (also the swap-in re-admission path: clears the swapped mark)."""
        if rid in self._reserved:
            raise ValueError(f"request {rid} already reserved")
        if not self.can_reserve(n_blocks):
            raise ValueError(
                f"pool over-committed: reserve({n_blocks}) with "
                f"slack {self.slack}")
        self._reserved[rid] = int(n_blocks)
        self._drawn[rid] = 0
        self._owned.setdefault(rid, [])
        self._swapped.discard(rid)

    def owned(self, rid: int) -> List[int]:
        return list(self._owned.get(rid, []))

    def _draw(self) -> int:
        """Pop one free block, evicting a cached-free block (LRU, index
        entries dropped) when the plain free list is dry."""
        if self._free:
            return self._free.pop()
        if self._cached:
            block, _ = self._cached.popitem(last=False)
            if self.prefix is not None:
                self.prefix.evict_block(block)
            self.counters["cached_evicted"] += 1
            return block
        raise RuntimeError(      # unreachable if reservations are honoured
            "free list exhausted (reservation accounting broken)")

    def _note_peak(self) -> None:
        if len(self._ref) > self.peak_allocated:
            self.peak_allocated = len(self._ref)

    def alloc(self, rid: int, n_blocks: int) -> List[int]:
        """Draw ``n_blocks`` fresh blocks for ``rid`` (<= its
        reservation; admission control makes this infallible)."""
        if rid not in self._reserved:
            raise ValueError(f"request {rid} has no reservation")
        have = self._drawn[rid]
        if have + n_blocks > self._reserved[rid]:
            raise ValueError(
                f"request {rid} alloc beyond reservation: "
                f"{have}+{n_blocks} > {self._reserved[rid]}")
        if self.fault_hook is not None and n_blocks:
            self.fault_hook(int(n_blocks))    # may raise InjectedFault
        ids = [self._draw() for _ in range(int(n_blocks))]
        for b in ids:
            self._ref[b] = 1
        self._owned[rid].extend(ids)
        self._drawn[rid] += int(n_blocks)
        self.counters["alloc_blocks"] += int(n_blocks)
        self._note_peak()
        return ids

    def share(self, rid: int, block_ids: Sequence[int]) -> None:
        """Append already-stored prefix blocks to ``rid``'s table.

        Referenced blocks just gain a reference; cached-free blocks are
        resurrected (costing one slack unit each — the admission gate
        must have accounted for them).  Never draws a fresh block, so it
        does not count against ``rid``'s reservation.
        """
        if rid not in self._reserved:
            raise ValueError(f"request {rid} has no reservation")
        for b in block_ids:
            b = int(b)
            if b == SCRATCH_BLOCK:
                raise ValueError("scratch block can never be shared")
            if b in self._ref:
                self._ref[b] += 1
            elif b in self._cached:
                if self.slack < 1:
                    raise RuntimeError(
                        f"resurrecting cached block {b} would break a "
                        "running request's append guarantee (admission "
                        "gate under-counted)")
                del self._cached[b]
                self._ref[b] = 1
                self.counters["resurrections"] += 1
            else:
                raise ValueError(f"block {b} is not shareable "
                                 "(free or unknown)")
            self._owned[rid].append(b)
        self._note_peak()

    def cow(self, rid: int, block: int) -> int:
        """Copy-on-write fork: make ``rid``'s table entry for ``block``
        privately writable.

        Sole owner → the block itself (write in place).  Shared → one
        reference is moved to a freshly drawn block (counted against
        ``rid``'s reservation) and the new id returned; the caller must
        copy the device content (:func:`clone_block`) and patch its
        block table.  Other sharers keep the original untouched.
        """
        block = int(block)
        if self._ref.get(block, 0) < 1:
            raise ValueError(f"block {block} is not allocated")
        if block not in self._owned.get(rid, ()):
            raise ValueError(f"request {rid} does not own block {block}")
        if self._ref[block] == 1:
            return block
        if self._drawn[rid] + 1 > self._reserved[rid]:
            raise ValueError(
                f"request {rid} COW fork beyond reservation "
                f"({self._reserved[rid]} blocks)")
        new = self._draw()
        self._ref[new] = 1
        self._ref[block] -= 1
        self._drawn[rid] += 1
        self.counters["alloc_blocks"] += 1
        self.counters["cow_forks"] += 1
        owned = self._owned[rid]
        owned[owned.index(block)] = new
        self._note_peak()
        return new

    def _unref(self, block: int) -> None:
        self._ref[block] -= 1
        if self._ref[block] == 0:
            del self._ref[block]
            self.counters["freed_blocks"] += 1
            if self.prefix is not None and self.prefix.has_block(block):
                self._cached[block] = None      # resurrectable, LRU order
            else:
                self._free.append(block)

    def swap_out(self, rid: int) -> List[int]:
        """Evacuate ``rid``: drop every table reference and the whole
        reservation, freeing its capacity *now*; mark the request
        swapped so a racing :meth:`release` is a no-op.  Returns the
        table (the engine snapshots the block content to host memory
        *before* calling this).  Resume = :meth:`reserve` +
        :meth:`alloc` + copy-back."""
        if rid not in self._reserved:
            raise ValueError(f"request {rid} has no reservation")
        ids = self._owned.pop(rid, [])
        for b in ids:
            self._unref(b)
        self._reserved.pop(rid, None)
        self._drawn.pop(rid, None)
        self._swapped.add(rid)
        self.counters["swap_out_blocks"] += len(ids)
        return ids

    def release(self, rid: int) -> List[int]:
        """Drop every reference ``rid`` holds and its reservation.

        Exactly-once guarantee: a request that was swapped out already
        returned its blocks in :meth:`swap_out`, so releasing it (a
        finish or shed racing the eviction) frees nothing and returns
        ``[]`` — the double-free this used to cause is regression-tested
        in ``tests/test_prefix_sharing.py``.
        """
        if rid in self._swapped:
            self._swapped.discard(rid)
            self._owned.pop(rid, None)
            self._reserved.pop(rid, None)
            self._drawn.pop(rid, None)
            return []
        ids = self._owned.pop(rid, [])
        self._reserved.pop(rid, None)
        self._drawn.pop(rid, None)
        for b in reversed(ids):
            self._unref(b)
        return ids

    def check_invariants(self) -> None:
        """Raise if conservation, refcounting or exclusivity breaks."""
        owned_all = [b for ids in self._owned.values() for b in ids]
        counts: Dict[int, int] = {}
        for b in owned_all:
            counts[b] = counts.get(b, 0) + 1
        assert counts == self._ref, (
            f"refcounts drifted from ownership: {self._ref} != {counts}")
        assert SCRATCH_BLOCK not in counts, "scratch block allocated"
        assert SCRATCH_BLOCK not in self._free, "scratch block on free list"
        assert SCRATCH_BLOCK not in self._cached, "scratch block cached"
        assert not (set(self._free) & set(self._cached)), (
            "block both free and cached")
        assert len(self._free) + len(self._cached) + len(self._ref) \
            == self.capacity, (
                f"pool not conserved: {len(self._free)} free + "
                f"{len(self._cached)} cached + {len(self._ref)} allocated "
                f"!= {self.capacity}")
        assert self.slack >= 0, "append guarantee broken (negative slack)"
        for rid in self._reserved:
            assert self._drawn[rid] <= self._reserved[rid], (
                f"request {rid} drew beyond reservation")
        for rid in self._swapped:
            assert not self._owned.get(rid) and rid not in self._reserved, (
                f"swapped request {rid} still owns blocks")


# ---------------------------------------------------------------------------
# Device-side layout helpers
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, batch_slots: int, max_blocks: int,
                     num_blocks: int, block_size: int = DEFAULT_BLOCK_SIZE,
                     num_layers: Optional[int] = None) -> dict:
    """Allocate the paged serving-cache pytree.

    Returns ``{"layers": [per-layer pools], "bt": (B, max_blocks) int32}``
    where each layer pool is ``{"k", "v": (num_blocks, block_size, Hkv,
    dh)}`` (+ ``k_scale``/``v_scale`` ``(num_blocks, block_size, Hkv)``
    f32 when ``cfg.kv_cache_dtype == "int8"``).  The block table starts
    all-scratch (0).  Attention-family (dense/moe) decoder stacks only —
    the engine gates other families off before building one.
    """
    int8 = getattr(cfg, "kv_cache_dtype", "bf16") == "int8"
    dt = jnp.int8 if int8 else cfg.dtype
    n_layers = num_layers or cfg.num_layers
    layers = []
    for _ in range(n_layers):
        pool = {
            "k": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
            "v": jnp.zeros((num_blocks, block_size, cfg.num_kv_heads,
                            cfg.head_dim), dt),
        }
        if int8:
            pool["k_scale"] = jnp.zeros(
                (num_blocks, block_size, cfg.num_kv_heads), jnp.float32)
            pool["v_scale"] = jnp.zeros(
                (num_blocks, block_size, cfg.num_kv_heads), jnp.float32)
        layers.append(pool)
    return {
        "layers": layers,
        "bt": jnp.zeros((batch_slots, max_blocks), jnp.int32),
    }


def physical_slots(bt: jnp.ndarray, slots: jnp.ndarray,
                   block_size: int) -> jnp.ndarray:
    """Map logical cache slots to physical pool rows.

    ``bt`` is ``(B, max_blocks)`` int32, ``slots`` is ``(B, T)`` logical
    slot indices; returns ``(B, T)`` int32 rows into the pool viewed as
    ``(num_blocks * block_size, ...)``.  Out-of-range logical blocks
    clip onto the scratch block's final row — junk that position masking
    already discards.
    """
    nb = bt.shape[1]
    blk_idx = jnp.clip(slots // block_size, 0, nb - 1)
    blk = jnp.take_along_axis(bt, blk_idx, axis=1)
    in_range = (slots // block_size) < nb
    blk = jnp.where(in_range, blk, SCRATCH_BLOCK)
    return blk * block_size + slots % block_size


def gather_block_rows(pool_buf: jnp.ndarray, bt: jnp.ndarray) -> jnp.ndarray:
    """Reconstruct the logical contiguous view of one pool buffer.

    ``pool_buf`` is ``(num_blocks, block_size, ...)``; returns
    ``(B, max_blocks * block_size, ...)`` where logical slot ``s`` of
    row ``b`` holds ``pool_buf[bt[b, s // bs], s % bs]``.  This is the
    jnp read path's gather and the oracle for the paged Pallas kernel.
    """
    B, nb = bt.shape
    bs = pool_buf.shape[1]
    g = jnp.take(pool_buf, bt.reshape(-1), axis=0)          # (B*nb, bs, ...)
    return g.reshape((B, nb * bs) + pool_buf.shape[2:])


def scatter_prefill_rows(pool: dict, block_ids: Sequence[int],
                         row_cache: dict, block_size: int,
                         first_block: int = 0) -> dict:
    """Scatter a single-row *contiguous* prefill cache into pool blocks.

    ``row_cache`` leaves are ``(1, S_row, ...)``; contiguous rows
    starting at logical block ``first_block`` (zero-padded if the
    contiguous row is shorter) land in the listed physical blocks, i.e.
    ``block_ids[i]`` receives rows ``[(first_block + i) * bs, ...)``.
    With prefix sharing the leading cached full blocks are skipped by
    passing the boundary's logical index as ``first_block``.  Writing
    the fresh-init-plus-prefill content into every *owned* (non-shared)
    block is what keeps admission retrace-free and slot-recycling
    leak-free, exactly like the contiguous ``prefill_into_slot`` row
    reset.
    """
    n = len(block_ids)
    if n == 0:
        return pool
    idx = jnp.asarray(np.asarray(block_ids, np.int32))
    lo = int(first_block) * block_size
    new = dict(pool)
    for name, buf in pool.items():
        row = row_cache[name][0]                             # (S_row, ...)
        need = lo + n * block_size
        if row.shape[0] < need:
            pad = [(0, need - row.shape[0])] + [(0, 0)] * (row.ndim - 1)
            row = jnp.pad(row, pad)
        vals = row[lo:need].reshape((n, block_size) + row.shape[1:])
        new[name] = buf.at[idx].set(vals.astype(buf.dtype))
    return new


def clone_block(layers: Sequence[dict], src: int, dst: int) -> List[dict]:
    """Copy every pool tensor's ``src`` block into ``dst`` (the device
    half of a COW fork; the `BlockPool.cow` bookkeeping is the host
    half)."""
    out = []
    for pool in layers:
        out.append({name: buf.at[dst].set(buf[src])
                    for name, buf in pool.items()})
    return out


def swap_out_blocks(layers: Sequence[dict],
                    block_ids: Sequence[int]) -> List[Dict[str, np.ndarray]]:
    """Snapshot the listed physical blocks of every layer pool to host
    ``numpy`` arrays (the swap pool).  Bit-exact for every pool dtype —
    int8 KV swaps the quantized codes *and* the f32 scale pools, so the
    round-trip reproduces the device state exactly."""
    if not block_ids:
        return [{name: np.empty((0,) + tuple(buf.shape[1:]),
                                 dtype=np.asarray(buf[:0]).dtype)
                 for name, buf in pool.items()} for pool in layers]
    idx = np.asarray(block_ids, np.int32)
    return [{name: np.asarray(jnp.take(buf, jnp.asarray(idx), axis=0))
             for name, buf in pool.items()} for pool in layers]


def swap_in_blocks(layers: Sequence[dict], block_ids: Sequence[int],
                   host: Sequence[Dict[str, np.ndarray]]) -> List[dict]:
    """Copy a `swap_out_blocks` snapshot back into (possibly different)
    physical blocks.  Pure data movement — resume never retraces."""
    if not block_ids:
        return list(layers)
    idx = jnp.asarray(np.asarray(block_ids, np.int32))
    out = []
    for pool, snap in zip(layers, host):
        out.append({name: buf.at[idx].set(
                        jnp.asarray(snap[name]).astype(buf.dtype))
                    for name, buf in pool.items()})
    return out


# ---------------------------------------------------------------------------
# Modeled footprint (used by launch/roofline.py and benchmarks/ablation_kv.py)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PagedPlan:
    """Static sizing decisions for one paged serving group."""

    block_size: int
    max_blocks: int          # block-table width (= ceil(buf / block_size))
    num_blocks: int          # physical pool size, incl. the scratch block
    slots: int               # decode rows (dynamic batch_slots)
    demands: tuple           # per-request block demand, request order


def plan_group(prompt_lens: Sequence[int], budgets: Sequence[int],
               gamma: int, buf: int, *, block_size: int,
               pool_blocks: Optional[int] = None,
               batch_slots: Optional[int] = None,
               default_slots: int = 8, max_slots: int = 64) -> PagedPlan:
    """Size the pool and pick the slot count for one serving group.

    * per-request demand = worst-case rows / ``block_size`` (ceil);
    * ``pool_blocks`` defaults to scratch + the ``min(len, default_slots)``
      *largest* demands — capacity comparable to the contiguous layout's
      default slot count, so paged never regresses admission;
    * ``slots`` (when not forced via ``batch_slots``) is **occupancy-
      derived**: the largest number of queued requests whose demands
      can actually be co-reserved (greedy, cheapest-first) — short-
      request mixes get more concurrent rows out of the same HBM than
      the contiguous layout's fixed worst-case sizing (the ROADMAP's
      admission-aware slot sizing), capped at ``max_slots``, and never
      inflated by rows the admission control could never co-house.
    """
    demands = tuple(
        blocks_for_tokens(request_demand_tokens(p, b, gamma), block_size)
        for p, b in zip(prompt_lens, budgets))
    n = len(demands)
    if pool_blocks is None:
        cap = default_slots if batch_slots is None else batch_slots
        top = sorted(demands, reverse=True)[: min(n, cap)]
        pool_blocks = 1 + sum(top)
    if max(demands) > pool_blocks - 1:
        raise ValueError(
            f"request demand {max(demands)} blocks exceeds pool capacity "
            f"{pool_blocks - 1}; raise kv_pool_blocks or shrink the request")
    if batch_slots is not None:
        slots = min(n, batch_slots)
    else:
        # greedy cheapest-first fill: how many queued requests could the
        # pool co-reserve at once?  (an upper bound on live rows — using
        # min-demand alone would allocate decode rows that admission
        # control can never co-house)
        fit, room = 0, pool_blocks - 1
        for d in sorted(demands):
            if d > room:
                break
            fit, room = fit + 1, room - d
        slots = min(n, max_slots, max(1, fit))
    return PagedPlan(block_size=block_size,
                     max_blocks=blocks_for_tokens(buf, block_size),
                     num_blocks=pool_blocks, slots=slots, demands=demands)
