"""Per-row PRNG streams for batch-composition-invariant sampling.

The decode step threads a PRNG key through drafting and verification.
With a *single* key, sampling noise is shared across the batch: the
random bits a row consumes depend on which other rows it was co-batched
with, so T>0 generations were only reproducible for a fixed batch
composition.

Continuous batching makes that unacceptable — a request may be admitted
into any slot at any step — so the engine state's ``key`` slot now also
accepts a *per-row* key array of shape ``(B, 2)`` (one legacy uint32
PRNGKey per row).  Each row's key is derived purely from the request's
``seed`` (:func:`request_key`) and split once per decode step, making a
row's sample stream a function of (seed, own token history) only:
invariant to co-batching, admission order, slot index and batch size.

The helpers below dispatch on key rank so the same traced decode step
serves both layouts:

* ``key.ndim == 1`` — single shared key ``(2,)``: legacy behaviour,
  bit-for-bit identical to the pre-scheduler code path.
* ``key.ndim == 2`` — per-row keys ``(B, 2)``: every split / uniform /
  categorical is vmapped over rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Salt for deriving request streams; any fixed constant works — it only
# decouples request streams from other PRNGKey(0) uses in the codebase.
REQUEST_STREAM_SALT = 0x5EED


def request_key(seed: int) -> jax.Array:
    """The per-request root key: a pure function of ``seed``.

    Independent of batch composition, admission order and slot index, so
    a request's sample stream is reproducible across any co-batching.
    """
    return jax.random.fold_in(jax.random.PRNGKey(REQUEST_STREAM_SALT), seed)


def is_per_row(key: jax.Array) -> bool:
    """True for a ``(B, 2)`` per-row key array, False for a single key."""
    return key.ndim == 2


def next_key(key: jax.Array):
    """Split into ``(carry, sub)`` — per-row keys split row-wise."""
    if is_per_row(key):
        ks = jax.vmap(jax.random.split)(key)          # (B, 2, 2)
        return ks[:, 0], ks[:, 1]
    ks = jax.random.split(key)
    return ks[0], ks[1]


def split3(key: jax.Array):
    """Three-way split matching :func:`next_key` semantics."""
    if is_per_row(key):
        ks = jax.vmap(lambda k: jax.random.split(k, 3))(key)   # (B, 3, 2)
        return ks[:, 0], ks[:, 1], ks[:, 2]
    k0, k1, k2 = jax.random.split(key, 3)
    return k0, k1, k2


def uniform_rows(key: jax.Array, n: int) -> jax.Array:
    """(B, 2) per-row keys → (B, n) uniforms, one lane per row."""
    return jax.vmap(lambda k: jax.random.uniform(k, (n,)))(key)


def categorical_rows(key: jax.Array, logits: jax.Array) -> jax.Array:
    """(B, 2) per-row keys + (B, V) logits → (B,) per-row samples."""
    return jax.vmap(jax.random.categorical)(key, logits)


def fill_row(keys: jax.Array, row: int, seed: int) -> jax.Array:
    """Return ``keys`` with ``row`` reset to the request stream for ``seed``
    (outside jit — used by slot admission)."""
    return keys.at[row].set(request_key(seed))
