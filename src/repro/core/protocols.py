"""Pluggable decoding protocols: ``Drafter`` and ``Verifier``.

Quasar treats drafting and verification as orthogonal, composable legs of
speculative execution (paper §3.1): *any* drafting strategy from the SD
taxonomy (prompt-lookup, pruned self-draft, model-based, tree, …) can feed
*any* verifier (BF16, W8A8, …) because the contract between them is just a
fixed-shape token window plus optional draft probabilities.  This module
is that contract.

Protocol contracts
------------------
``Drafter`` — three methods, all shape-static so the decode step jits:

* ``init_state(model, params, prompts, buf_len, ...)`` → drafter-state
  pytree (runs **outside** jit, once per generation; may prefill a draft
  cache).  Return ``{}`` for stateless drafters.
* ``propose(model, params, tokens, length, dstate, key)`` →
  ``(DraftProposal, dstate, key)`` (traced **inside** jit every step).
  ``DraftProposal.tokens`` must be ``(B, gamma)`` int32; ``probs`` is
  ``None`` for deterministic drafters (one-hot q) or ``(B, gamma, V)``
  f32 for stochastic ones so the verifier can apply the full Eq. 2 ratio.
  The PRNG key is threaded through so stochastic drafters stay
  reproducible; deterministic drafters return it unchanged.
* ``advance(model, dstate, proposal, n_accept)`` → drafter-state (traced,
  after verification; reconcile draft-side caches with the accepted
  prefix).  Default: identity.

Continuous batching adds two *slot-level* lifecycle hooks (both outside
jit; defaults work for any drafter whose state pytree is batch-leading):

* ``alloc_state(model, params, batch, buf_len, ...)`` → an **empty**
  drafter-state pytree with ``batch`` rows.  The scheduler allocates this
  once per serving loop; rows are populated on admission.  Default ``{}``.
* ``prefill_row(model, params, dstate, row, prompt, buf_len, ...)`` →
  drafter-state with slot ``row`` reset for a newly admitted request: the
  default re-runs ``init_state`` on the single-row prompt and scatters the
  result into ``dstate``, guaranteeing a recycled slot carries no state
  from its previous occupant.

``Verifier`` — two methods:

* ``prepare(model, params, act_stats=None)`` → params (runs outside jit,
  once per weight set): offline weight preparation.  ``W8A8Verifier``
  applies SmoothQuant + INT8 here so ``SpecConfig.verifier="w8a8"`` alone
  produces quantized verification — no manual ``quantize_params`` at call
  sites.  Must be idempotent.
* ``verify(logits, proposal, temperature, key)`` → ``VerifyResult``
  (traced): the lossless accept/reject rule (Eq. 2-3).
* ``verify_tree(logits, proposal, template, temperature, key)`` →
  ``TreeVerifyResult`` (traced): the tree-scoring override — lossless
  rejection sampling down a token tree, longest accepted root-to-leaf
  path commits.  Inherited by every registered verifier, so tree
  topology composes with any weight preparation.  Drafters opt into the
  tree route by exposing a ``template``
  (:class:`~repro.core.tree.TreeTemplate`) and attaching its
  ``parents``/``tree_mask`` to each proposal.

Registries
----------
Implementations self-register by name via ``@register_drafter("name")`` /
``@register_verifier("name")`` and are instantiated from a ``SpecConfig``
with ``get_drafter(name, scfg)`` / ``get_verifier(name, scfg)``.  Passing
an already-constructed instance through the getters is a no-op, so custom
(unregistered) components plug in the same way.  See
``docs/decoding_api.md`` for a worked custom-drafter example.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Type

import jax

from repro.core.config import SpecConfig
from repro.core.verification import (
    TreeVerifyResult,
    VerifyResult,
    verify,
    verify_tree,
)


class DraftProposal(NamedTuple):
    """Fixed-shape drafting output: the drafter→verifier contract.

    ``parents``/``tree_mask`` extend the contract to *token-tree*
    proposals (SpecInfer-style): both are static per-template constants
    over the N-node verify window ``[last_committed, tokens...]``
    (``N = gamma + 1``).  ``None`` ⇒ chain — the degenerate single-branch
    tree — which keeps every pre-tree drafter valid unchanged.
    """

    tokens: jax.Array                  # (B, gamma) int32 drafted tokens
    probs: Optional[jax.Array] = None  # (B, gamma, V) f32 draft dist q, or
    #                                    None for deterministic drafters
    parents: Optional[jax.Array] = None    # (N,) int32 window-parent
    #                                        pointers, -1 at the root
    tree_mask: Optional[jax.Array] = None  # (N, N) bool ancestor-or-self
    #                                        mask over the packed window


class Drafter:
    """Base drafting strategy.  Subclass + register; see module docstring."""

    name: str = "base"
    gamma: int = 0

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "Drafter":
        """Build from a SpecConfig — override when fields differ."""
        return cls()

    def with_temperature(self, temperature: float) -> "Drafter":
        """Return a drafter suited to a different sampling temperature.
        Most drafters are temperature-independent (default: self);
        stochastic drafters that sample during proposal override this so
        per-request temperature overrides keep their instance config."""
        return self

    # -- lifecycle ------------------------------------------------------
    def init_state(self, model, params, prompts, buf_len: int, *,
                   aux_embeds=None, draft_params=None) -> Any:
        """Build the per-generation drafter-state pytree (outside jit,
        once per generation).

        Args:
          prompts      ``(B, P)`` int32 — the *unpadded* prompts;
          buf_len      token-buffer length (draft-side caches size to it);
          aux_embeds   ``(B, Sa, D)`` modality embeddings or ``None``;
          draft_params separate draft-model weights or ``None``.

        Returns: an opaque pytree stored in the engine state's
        ``drafter_state`` slot (``{}`` for stateless drafters; a pruned-
        model KV cache for ``pruned``).  May run forward passes (e.g. a
        draft-cache prefill).
        """
        return {}

    def propose(self, model, params, tokens, length, dstate, key):
        """Draft ``gamma`` tokens per row (traced inside jit, every step).

        Args:
          tokens  ``(B, S_buf)`` int32 — committed token buffer;
          length  ``(B,)`` int32       — committed counts per row;
          dstate  the drafter-state pytree;
          key     PRNGKey ``(2,)`` or per-row ``(B, 2)`` streams
                  (dispatch with ``repro.core.prng``; return unchanged
                  if unused).

        Returns ``(DraftProposal, dstate, key)``.  ``proposal.tokens``
        must be ``(B, gamma)`` int32 with ``gamma`` static;
        ``proposal.probs`` is ``None`` (deterministic ⇒ one-hot q in
        Eq. 2) or ``(B, gamma, V)`` f32.  Tree drafters also attach the
        template's ``parents (N,)`` / ``tree_mask (N, N)`` constants.
        """
        raise NotImplementedError

    def advance(self, model, dstate, proposal: DraftProposal, n_accept):
        """Reconcile drafter state with the accepted prefix (traced,
        after verification).

        Args:
          proposal  the step's :class:`DraftProposal`;
          n_accept  ``(B,)`` int32 — accepted draft tokens per row.

        Returns the updated drafter-state pytree (default: identity —
        correct for stateless drafters).
        """
        return dstate

    # -- continuous batching (slot-level lifecycle, outside jit) --------
    def alloc_state(self, model, params, batch: int, buf_len: int, *,
                    draft_params=None) -> Any:
        """Allocate an **empty** ``batch``-row drafter-state pytree for a
        scheduler loop (outside jit, once per serving group); rows are
        filled by :meth:`prefill_row` on admission.

        Every leaf must be batch-leading so per-row scatters work.
        Returns ``{}`` by default (stateless drafters); stateful
        drafters allocate zeroed buffers (never prefilled — recycled
        rows must not inherit anything).
        """
        return {}

    def prefill_row(self, model, params, dstate, row: int, prompt,
                    buf_len: int, *, aux_embeds=None, draft_params=None):
        """Reset slot ``row`` of ``dstate`` for a newly admitted request
        (outside jit, once per admission).

        Args:
          dstate   the live batch drafter-state pytree;
          row      the slot index being recycled;
          prompt   ``(1, P)`` int32 — the **unpadded** prompt (draft-side
                   caches may have never-rewritten slots where pad junk
                   would be live state; solo runs have zeros there, and
                   bit-identity demands admitted rows do too);
          buf_len  the group's token-buffer length.

        Returns ``dstate`` with slot ``row`` reset.  The default builds
        a fresh single-row state via :meth:`init_state` and scatters it
        into the batch pytree (``.at[row].set`` per leaf — shape-stable,
        so the jitted decode step never retraces), guaranteeing a
        recycled slot leaks nothing from its previous occupant.
        Stateless drafters are a no-op.
        """
        fresh = self.init_state(model, params, prompt, buf_len,
                                aux_embeds=aux_embeds,
                                draft_params=draft_params)
        if not fresh:
            return dstate
        return jax.tree.map(lambda full, one: full.at[row].set(one[0]),
                            dstate, fresh)


class Verifier:
    """Base verification strategy: lossless rejection sampling over the
    target model's logits, plus offline weight preparation."""

    name: str = "base"

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "Verifier":
        return cls()

    def prepare(self, model, params, act_stats=None):
        """Offline weight preparation (outside jit, once per weight set).

        Args:
          params     the BF16 parameter pytree;
          act_stats  SmoothQuant calibration statistics or ``None``
                     (quantizing verifiers fall back to weight-only
                     smoothing).

        Returns the params the jitted step will stream — identity for
        BF16, SmoothQuant + INT8 for ``w8a8``, packed int4 for ``w4a8``.
        **Must be idempotent** (prepared params pass through unchanged).
        """
        return params

    def verify(self, logits, proposal: DraftProposal, temperature: float,
               key) -> VerifyResult:
        """Lossless accept/reject over a chain window (traced).

        Args:
          logits       ``(B, gamma+1, V)`` f32 — the target model's
                       logits over ``[last_committed, draft_1..gamma]``;
          proposal     the drafter's :class:`DraftProposal`;
          temperature  sampling temperature (0 ⇒ greedy exact-match);
          key          PRNGKey ``(2,)`` or per-row ``(B, 2)`` streams.

        Returns a ``VerifyResult`` with ``n_accept (B,)`` accepted draft
        tokens, the corrective/bonus ``next_token (B,)`` (sampled from
        the Eq. 3 residual on rejection) and ``n_commit = n_accept + 1``.
        The base rule covers deterministic (``probs=None`` ⇒ one-hot q)
        and stochastic drafters (full Eq. 2 ratio); override only for
        different acceptance semantics (e.g. typical acceptance).
        """
        return verify(logits, proposal.tokens, temperature, key,
                      draft_probs=proposal.probs)

    def verify_tree(self, logits, proposal: DraftProposal, template,
                    temperature: float, key) -> TreeVerifyResult:
        """Tree-scoring override: lossless rejection sampling *down* the
        token tree (SpecInfer-style sibling round-robin with Eq. 3
        residual updates), committing the longest accepted root-to-leaf
        path (traced).

        Args:
          logits    ``(B, N, V)`` f32 over the packed N-node window;
          proposal  tree proposal (``tokens (B, N-1)`` in packed BFS
                    order, plus the template constants);
          template  the drafter's :class:`~repro.core.tree.TreeTemplate`.

        Returns a ``TreeVerifyResult``: ``n_accept (B,)`` accepted
        *depth*, ``path_nodes (B, depth+1)`` the committed node ids,
        ``path_tokens`` the accepted tokens in chain order, and the
        corrective ``next_token (B,)``.  Every registered verifier
        inherits this, so tree drafting composes with any weight
        preparation (BF16 / W8A8 / W4A8) — the paper's orthogonality
        claim extended to tree topology.
        """
        return verify_tree(logits, proposal.tokens, template, temperature,
                           key, draft_probs=proposal.probs)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

_DRAFTERS: Dict[str, Type[Drafter]] = {}
_VERIFIERS: Dict[str, Type[Verifier]] = {}


def register_drafter(name: str):
    def deco(cls: Type[Drafter]):
        cls.name = name
        _DRAFTERS[name] = cls
        return cls
    return deco


def register_verifier(name: str):
    def deco(cls: Type[Verifier]):
        cls.name = name
        _VERIFIERS[name] = cls
        return cls
    return deco


def available_drafters() -> tuple:
    return tuple(sorted(_DRAFTERS))


def available_verifiers() -> tuple:
    return tuple(sorted(_VERIFIERS))


def get_drafter(spec, scfg: Optional[SpecConfig] = None) -> Drafter:
    """Resolve a drafter: instance passthrough, or registry name lookup."""
    if isinstance(spec, Drafter):
        return spec
    try:
        cls = _DRAFTERS[spec]
    except KeyError:
        raise ValueError(
            f"unknown drafter {spec!r}; registered: {available_drafters()}"
        ) from None
    return cls.from_config(scfg if scfg is not None else SpecConfig())


def get_verifier(spec, scfg: Optional[SpecConfig] = None) -> Verifier:
    """Resolve a verifier: instance passthrough, or registry name lookup."""
    if isinstance(spec, Verifier):
        return spec
    try:
        cls = _VERIFIERS[spec]
    except KeyError:
        raise ValueError(
            f"unknown verifier {spec!r}; registered: {available_verifiers()}"
        ) from None
    return cls.from_config(scfg if scfg is not None else SpecConfig())
