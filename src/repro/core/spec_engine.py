"""Unified speculative decode step: draft → verify → accept → commit.

One step builder, :func:`make_decode_step`, parameterised by a
:class:`~repro.core.protocols.Drafter` and a
:class:`~repro.core.protocols.Verifier` (see ``repro.core.protocols`` for
the contracts and registries).  The three legacy modes are registry pairs:

  ``spec``     → (``ngram``,   any verifier)   Quasar / PLD drafting
  ``vanilla``  → (``vanilla``, any verifier)   gamma=0 autoregressive
  ``pruned``   → (``pruned``,  any verifier)   Table-5 layer-drop drafting

plus the token-tree route (``ngram-tree`` or any drafter exposing a
``template``): one verifier pass scores a packed candidate tree and the
longest accepted root-to-leaf path commits (``docs/decoding_api.md``,
*Tree speculation*).

The step is jit-able and fixed-shape (it is what ``dryrun.py`` lowers for
the production mesh).  Engine state is a pytree dict:

  tokens         (B, S_buf) int32   committed text buffer
  length         (B,)       int32   committed token counts
  target         (B,)       int32   per-request stop lengths (optional slot;
                                    commits are masked so ``length`` never
                                    exceeds it — early-exit for finished
                                    requests in a heterogeneous batch)
  cache          pytree             verifier KV/SSM cache (covers
                                    [0, length-1)); contiguous per-row
                                    buffers by default, or block-pool
                                    paged (``repro.core.paged_cache``:
                                    per-layer pools + a ``"bt"`` block
                                    table) on the paged serving path —
                                    the step body is layout-agnostic,
                                    attention dispatches on ``"bt"``
  drafter_state  pytree             opaque drafter-owned state ({} for
                                    stateless drafters, a pruned-model KV
                                    cache for ``pruned``, …)
  key            PRNGKey or (B, 2)  single shared key, or per-row request
                                    streams (``repro.core.prng``) so each
                                    row samples independently of its
                                    co-batched neighbours — the layout the
                                    continuous-batching scheduler uses
  stats          {"commits": (B,), "steps": (), "row_steps": (B,),
                  "bad": (B,) bool}  acceptance bookkeeping + the per-row
                                    non-finite-logits tripwire the serving
                                    guardrails read (docs/robustness.md)

``make_serve_step`` / ``make_vanilla_step`` / ``make_pruned_step`` remain
as thin deprecated shims over ``make_decode_step``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import prng


def init_state(model, batch: int, buf_len: int, key,
               num_layers: Optional[int] = None,
               drafter_state=None, target=None, scan: bool = False,
               cache=None) -> dict:
    """Canonical engine-state pytree — the single source of truth for the
    decode-step schema (``launch/shapes.py`` eval_shapes this for the
    production mesh specs).

    ``cache`` overrides the default contiguous allocation — the paged
    serving path passes a block-pool cache
    (``repro.core.paged_cache.init_paged_cache``: per-layer physical
    pools + a ``"bt"`` block table) so the worst-case contiguous buffers
    are never materialised.  Every other slot keeps the same schema
    either way.
    """
    state = {
        "tokens": jnp.zeros((batch, buf_len), jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
        "cache": cache if cache is not None
        else model.init_cache(batch, buf_len, num_layers, scan=scan),
        "drafter_state": drafter_state if drafter_state is not None else {},
        "key": key,
        "stats": {
            "commits": jnp.zeros((batch,), jnp.int32),
            "steps": jnp.zeros((), jnp.int32),
            # steps during which the row was still below its target —
            # the honest denominator for per-row acceptance length
            "row_steps": jnp.zeros((batch,), jnp.int32),
            # sticky per-row flag: the verifier produced non-finite
            # logits for this (active) row — the serving lane's NaN
            # guardrail reads it host-side and routes the row through
            # the full-precision fallback step (docs/robustness.md)
            "bad": jnp.zeros((batch,), jnp.bool_),
        },
    }
    if target is not None:
        state["target"] = jnp.asarray(target, jnp.int32)
    return state


def _commit_tokens(tokens, length, drafts, next_token, n_accept, n_write=None):
    """Write [drafts[:n_accept], next_token] at per-row offsets.

    ``n_write`` (default ``n_accept + 1``) caps how many of those tokens
    are actually written — used to freeze rows that reached their target.
    """
    B, S = tokens.shape
    gamma = drafts.shape[1]
    if n_write is None:
        n_write = n_accept + 1
    i = jnp.arange(gamma + 1)[None, :]                                # (1, γ+1)
    vals = jnp.concatenate([drafts, next_token[:, None]], axis=1)     # (B, γ+1)
    vals = jnp.where(i == n_accept[:, None],
                     next_token[:, None], vals)                       # corrective at slot n
    pos = jnp.clip(length[:, None] + i, 0, S - 1)
    keep = i < n_write[:, None]
    cur = jnp.take_along_axis(tokens, pos, axis=1)
    vals = jnp.where(keep, vals, cur)
    bidx = jnp.arange(B)[:, None]
    return tokens.at[bidx, pos].set(vals)


def make_decode_step(model, drafter, verifier, scfg,
                     num_layers: Optional[int] = None):
    """Build the unified decode step: ``decode_step(params, state)``.

    ``drafter`` / ``verifier`` are protocol instances (or registry names —
    resolved here for convenience).  ``params`` must already be prepared
    (``verifier.prepare``); the step itself is pure and fixed-shape.

    A drafter exposing a non-chain ``template``
    (:class:`~repro.core.tree.TreeTemplate`) switches the step onto the
    **token-tree** route: the verify window becomes the packed node tree
    (depth positions + ancestor mask), verification walks the tree
    (``Verifier.verify_tree``) and the cache commit compacts the accepted
    root-to-leaf path.  The chain route is exactly the degenerate
    single-branch tree, and the two are asserted bit-identical in
    ``tests/test_tree.py``.
    """
    from repro.core.protocols import get_drafter, get_verifier

    drafter = get_drafter(drafter, scfg)
    verifier = get_verifier(verifier, scfg)
    template = getattr(drafter, "template", None)
    if template is not None:
        if model.cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(
                f"tree speculation needs attention-family caches; "
                f"{model.cfg.arch_type!r} caches are recurrent (per-node "
                "state branching is a ROADMAP follow-up)")
        if model.cfg.sliding_window:
            raise ValueError(
                "tree speculation requires a contiguous KV cache; "
                "sliding-window (ring) caches cannot hold sibling nodes "
                "at one position")

    def decode_step(params, state):
        # jax.named_scope annotates the HLO with draft/verify/commit
        # phase names — zero runtime cost, but XLA device profiles (and
        # Tracer(annotate_device=True) host spans) segment the fused
        # step without splitting its jit (splitting would perturb
        # fusion and break the tracing-on/off bit-identity guarantee)
        tokens, length = state["tokens"], state["length"]
        with jax.named_scope("draft"):
            proposal, dstate, key = drafter.propose(
                model, params, tokens, length, state["drafter_state"],
                state["key"])

        last = jnp.take_along_axis(
            tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        window = jnp.concatenate([last, proposal.tokens], axis=1)  # (B, N)
        start = jnp.maximum(length - 1, 0)

        if "target" in state:
            active_mask = length < state["target"]
        else:
            active_mask = jnp.ones(length.shape, jnp.bool_)

        key, sub = prng.next_key(key)
        with jax.named_scope("verify"):
            if template is None:
                logits, cand = model.verify_step(
                    params, state["cache"], window,
                    start, num_layers=num_layers)
                res = verifier.verify(logits, proposal, scfg.temperature,
                                      sub)
            else:
                logits, cand = model.verify_step(
                    params, state["cache"], window, start,
                    num_layers=num_layers,
                    tree_depths=template.depths_dev,
                    tree_mask=template.mask_dev)
                res = verifier.verify_tree(logits, proposal, template,
                                           scfg.temperature, sub)
            # per-row losslessness tripwire: non-finite verifier logits
            # on an *active* row (idle rows attend junk by design — the
            # scratch block / stale cache — and must not trip it).
            # Folded into the fused step so it costs one reduction and
            # zero extra device syncs; the host reads it alongside
            # `length` after the step.
            row_bad = jnp.any(
                ~jnp.isfinite(logits),
                axis=tuple(range(1, logits.ndim))) & active_mask
        with jax.named_scope("commit"):
            if template is None:
                cache = model.commit(cand, res.n_accept,
                                     num_layers=num_layers)
                drafts = proposal.tokens
            else:
                cache = model.commit_tree(cand, start, res.path_nodes,
                                          res.n_accept,
                                          num_layers=num_layers)
                drafts = res.path_tokens       # accepted path, chain order
            dstate = drafter.advance(model, dstate, proposal, res.n_accept)

            n_commit = res.n_commit
            if "target" in state:
                # freeze rows that reached their per-request target length
                n_commit = jnp.clip(n_commit, 0, state["target"] - length)
            active = active_mask.astype(jnp.int32)
            tokens = _commit_tokens(tokens, length, drafts,
                                    res.next_token, res.n_accept,
                                    n_write=n_commit)
        out = {
            "tokens": tokens,
            "length": length + n_commit,
            "cache": cache,
            "drafter_state": dstate,
            "key": key,
            "stats": {
                "commits": state["stats"]["commits"] + n_commit,
                "steps": state["stats"]["steps"] + 1,
                "row_steps": state["stats"]["row_steps"] + active,
                "bad": state["stats"].get(
                    "bad", jnp.zeros(length.shape, jnp.bool_)) | row_bad,
            },
        }
        if "target" in state:
            out["target"] = state["target"]
        return out

    return decode_step


# ---------------------------------------------------------------------------
# Deprecated shims (legacy mode-string API)
# ---------------------------------------------------------------------------

def make_serve_step(model, scfg, num_layers: Optional[int] = None):
    """Deprecated: ``make_decode_step(model, "ngram", "bf16", scfg)``."""
    return make_decode_step(model, "ngram", "bf16", scfg,
                            num_layers=num_layers)


def make_vanilla_step(model, temperature: float = 0.0):
    """Deprecated: ``make_decode_step(model, "vanilla", "bf16", scfg)``."""
    from repro.core.config import SpecConfig
    return make_decode_step(model, "vanilla", "bf16",
                            SpecConfig(gamma=0, temperature=temperature))


def make_pruned_step(model, scfg, retention: float):
    """Deprecated: ``make_decode_step(model, "pruned", "bf16", scfg)``."""
    import dataclasses

    scfg = dataclasses.replace(scfg, pruned_retention=retention)
    return make_decode_step(model, "pruned", "bf16", scfg)
