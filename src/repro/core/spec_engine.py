"""Speculative-decoding step builders: draft → verify → accept → commit.

Three step kinds, all jit-able and fixed-shape (they are what ``dryrun.py``
lowers for the production mesh):

* ``make_serve_step``    — Quasar / Ngram: prompt-lookup drafting + parallel
  verification by the supplied verifier params (W8A8 or BF16);
* ``make_vanilla_step``  — autoregressive baseline (one token / forward);
* ``make_pruned_step``   — Table-5 baseline: γ sequential decode steps of a
  layer-dropped (structurally pruned) model draft, full-model verification.

Engine state is a pytree dict:
  tokens  (B, S_buf) int32   committed text buffer
  length  (B,)       int32   committed token counts
  cache   pytree             verifier KV/SSM cache (covers [0, length-1))
  key     PRNGKey
  stats   {"commits": (B,), "steps": ()}  acceptance-length bookkeeping
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.drafting import draft_tokens
from repro.core.verification import verify


def init_state(model, batch: int, buf_len: int, key, num_layers: Optional[int] = None) -> dict:
    return {
        "tokens": jnp.zeros((batch, buf_len), jnp.int32),
        "length": jnp.zeros((batch,), jnp.int32),
        "cache": model.init_cache(batch, buf_len, num_layers),
        "key": key,
        "stats": {
            "commits": jnp.zeros((batch,), jnp.int32),
            "steps": jnp.zeros((), jnp.int32),
        },
    }


def _commit_tokens(tokens, length, drafts, next_token, n_accept):
    """Write [drafts[:n_accept], next_token] at per-row offsets."""
    B, S = tokens.shape
    gamma = drafts.shape[1]
    i = jnp.arange(gamma + 1)[None, :]                                # (1, γ+1)
    vals = jnp.concatenate([drafts, next_token[:, None]], axis=1)     # (B, γ+1)
    vals = jnp.where(i == n_accept[:, None],
                     next_token[:, None], vals)                       # corrective at slot n
    pos = jnp.clip(length[:, None] + i, 0, S - 1)
    keep = i <= n_accept[:, None]
    cur = jnp.take_along_axis(tokens, pos, axis=1)
    vals = jnp.where(keep, vals, cur)
    bidx = jnp.arange(B)[:, None]
    return tokens.at[bidx, pos].set(vals)


def make_serve_step(model, scfg, num_layers: Optional[int] = None):
    """Quasar/Ngram speculative step.  ``serve_step(verifier_params, state)``."""
    gamma = scfg.gamma

    def serve_step(params, state):
        tokens, length = state["tokens"], state["length"]
        drafts = draft_tokens(tokens, length, gamma=gamma,
                              k_min=scfg.k_min, k_max=scfg.k_max)     # (B, γ)
        last = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        window = jnp.concatenate([last, drafts], axis=1)              # (B, γ+1)
        start = jnp.maximum(length - 1, 0)

        logits, cand = model.verify_step(params, state["cache"], window, start,
                                         num_layers=num_layers)
        key, sub = jax.random.split(state["key"])
        res = verify(logits, drafts, scfg.temperature, sub)

        cache = model.commit(cand, res.n_accept, num_layers=num_layers)
        tokens = _commit_tokens(tokens, length, drafts, res.next_token, res.n_accept)
        return {
            "tokens": tokens,
            "length": length + res.n_commit,
            "cache": cache,
            "key": key,
            "stats": {
                "commits": state["stats"]["commits"] + res.n_commit,
                "steps": state["stats"]["steps"] + 1,
            },
        }

    return serve_step


def make_vanilla_step(model, temperature: float = 0.0):
    """Autoregressive baseline: one token per full forward."""

    def vanilla_step(params, state):
        tokens, length = state["tokens"], state["length"]
        last = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        start = jnp.maximum(length - 1, 0)
        logits, cache = model.decode_step(params, state["cache"], last, start)
        key, sub = jax.random.split(state["key"])
        if temperature > 0.0:
            nxt = jax.random.categorical(
                sub, logits[:, -1].astype(jnp.float32) / temperature
            ).astype(jnp.int32)
        else:
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        B, S = tokens.shape
        bidx = jnp.arange(B)
        pos = jnp.clip(length, 0, S - 1)
        tokens = tokens.at[bidx, pos].set(nxt)
        return {
            "tokens": tokens,
            "length": length + 1,
            "cache": cache,
            "key": key,
            "stats": {
                "commits": state["stats"]["commits"] + 1,
                "steps": state["stats"]["steps"] + 1,
            },
        }

    return vanilla_step


def make_pruned_step(model, scfg, retention: float):
    """Table-5 baseline: structurally pruned (first ``retention·L`` layers)
    model drafts γ tokens autoregressively; the full model verifies.

    State carries an extra ``pruned_cache``.  Only attention-family archs
    are supported (SSM rollback for the drafter would need per-step states
    inside a scan; the paper's Table 5 uses a dense model).
    """
    gamma = scfg.gamma
    n_keep = max(1, int(round(model.cfg.num_layers * retention)))

    def pruned_step(params, state):
        tokens, length = state["tokens"], state["length"]
        B, S = tokens.shape
        key = state["key"]
        pcache = state["pruned_cache"]

        tok = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        pos = jnp.maximum(length - 1, 0)
        drafts, qprobs = [], []
        for i in range(gamma):  # unrolled: γ is small and static
            logits, pcache = model.decode_step(params, pcache, tok, pos + i,
                                               num_layers=n_keep)
            lf = logits[:, -1].astype(jnp.float32)
            if scfg.temperature == 0.0:
                nxt = jnp.argmax(lf, axis=-1).astype(jnp.int32)
                qprobs.append(jax.nn.one_hot(nxt, lf.shape[-1], dtype=jnp.float32))
            else:
                key, sub = jax.random.split(key)
                q = jax.nn.softmax(lf / scfg.temperature, axis=-1)
                nxt = jax.random.categorical(sub, jnp.log(jnp.maximum(q, 1e-30))).astype(jnp.int32)
                qprobs.append(q)
            drafts.append(nxt)
            tok = nxt[:, None]
        drafts = jnp.stack(drafts, axis=1)                            # (B, γ)
        draft_probs = jnp.stack(qprobs, axis=1)                       # (B, γ, V)

        last = jnp.take_along_axis(tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        window = jnp.concatenate([last, drafts], axis=1)
        logits, cand = model.verify_step(params, state["cache"], window,
                                         jnp.maximum(length - 1, 0))
        key, sub = jax.random.split(key)
        res = verify(logits, drafts, scfg.temperature, sub, draft_probs=draft_probs)

        cache = model.commit(cand, res.n_accept)
        tokens = _commit_tokens(tokens, length, drafts, res.next_token, res.n_accept)
        return {
            "tokens": tokens,
            "length": length + res.n_commit,
            "cache": cache,
            "pruned_cache": pcache,
            "key": key,
            "stats": {
                "commits": state["stats"]["commits"] + res.n_commit,
                "steps": state["stats"]["steps"] + 1,
            },
        }

    return pruned_step
