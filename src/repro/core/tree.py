"""Static token-tree templates for tree-style speculative decoding.

Tree drafting (SpecInfer / Sequoia / Medusa-style) amortizes one
memory-bound verifier forward over *many* candidate continuations: the
verify window is a packed token tree instead of a single chain, an
ancestor mask keeps every node conditioned on exactly its root-to-node
path, and verification commits the longest accepted root-to-leaf path.
The chain window the rest of the codebase uses is the degenerate
single-branch tree, so everything here reduces bit-exactly to the
existing path when ``branches == (1, 1, ..., 1)``.

A :class:`TreeTemplate` is **shape-static**: the topology is fixed per
drafter instance (per-depth branch factors), so the decode step jits
once and every derived table below is a plain numpy constant baked into
the trace.

Packed node layout (BFS / level order)
--------------------------------------
Node 0 is the *root* — the last committed token, never re-scored.  Level
``d`` (1-indexed) holds ``prod(branches[:d])`` nodes, children of one
parent adjacent, subtrees left-to-right.  The verify window is therefore
``[last_committed, draft_1, ..., draft_{N-1}]`` with ``N = num_nodes``,
exactly the chain window when every branch factor is 1.

Derived tables (all numpy, shape-static):

* ``parents``   (N,)  int32 — parent node index, ``-1`` for the root.
* ``depths``    (N,)  int32 — root depth 0; node positions are
  ``length - 1 + depth`` (siblings share a RoPE position).
* ``mask``      (N, N) bool — ancestor-*or-self* mask:
  ``mask[i, j]`` ⇔ node ``j`` lies on the root→``i`` path.  This is the
  attention mask applied over the packed query window; for a chain it is
  the lower-triangular causal mask.
* ``children``  (N, max_branch) int32 — child node ids, ``-1`` padded.
  Sibling order is *verification order*: child 0 of the root carries the
  chain drafter's proposal, so tree acceptance dominates chain acceptance
  step-for-step at T=0.
* ``paths``     (num_leaves, max_depth + 1) int32 — root→leaf node ids
  (column 0 is always the root).
* ``src_leaf``  (N,) int32 — representative leaf *ordinal* under each
  node (smallest leaf index); tree drafters fill node tokens from the
  representative leaf's candidate continuation.

Cache layout note
-----------------
Window node ``i`` writes its K/V at cache slot ``start + i`` (packed
order) while its RoPE position is ``start + depth[i]``.  After
verification, :func:`repro.models.transformer.commit_cache_tree`
compacts the accepted path's rows into chain slots
``start .. start + n_accept``; an accepted node at depth ``d`` was
rotated at position ``start + d``, which *is* its final committed
position, so compaction is an exact gather — no recompute.
"""
from __future__ import annotations

import functools
from typing import Tuple

import numpy as np


class TreeTemplate:
    """Immutable static token-tree topology (see module docstring)."""

    def __init__(self, branches: Tuple[int, ...]):
        branches = tuple(int(b) for b in branches)
        if any(b < 1 for b in branches):
            raise ValueError(f"branch factors must be >= 1, got {branches}")
        if int(np.prod([b for b in branches] or [1])) > 64:
            raise ValueError(f"template too wide: {branches} "
                             "(> 64 leaves)")
        self.branches = branches
        self._build()
        self._build_dev()

    @classmethod
    def chain(cls, gamma: int) -> "TreeTemplate":
        """The degenerate single-branch template: a γ-token chain."""
        if gamma < 0:
            raise ValueError(f"gamma must be >= 0, got {gamma}")
        return cls((1,) * gamma)

    # ------------------------------------------------------------------
    def _build(self) -> None:
        parents = [-1]
        depths = [0]
        frontier = [0]                       # node ids of the previous level
        for d, b in enumerate(self.branches, start=1):
            nxt = []
            for p in frontier:
                for _ in range(b):
                    nxt.append(len(parents))
                    parents.append(p)
                    depths.append(d)
            frontier = nxt
        N = len(parents)
        self.num_nodes = N
        self.max_depth = len(self.branches)
        self.max_branch = max(self.branches) if self.branches else 1
        self.parents = np.asarray(parents, np.int32)
        self.depths = np.asarray(depths, np.int32)

        # ancestor-or-self mask
        mask = np.zeros((N, N), bool)
        for i in range(N):
            j = i
            while j >= 0:
                mask[i, j] = True
                j = int(self.parents[j])
        self.mask = mask

        # children table, verification order == packed order
        children = np.full((N, self.max_branch), -1, np.int32)
        counts = np.zeros(N, np.int64)
        for i in range(1, N):
            p = int(self.parents[i])
            children[p, counts[p]] = i
            counts[p] += 1
        self.children = children

        # leaves (depth == max_depth) in packed order; root→leaf paths
        leaves = [i for i in range(N) if depths[i] == self.max_depth]
        self.num_leaves = len(leaves)
        self.leaves = np.asarray(leaves, np.int32)
        paths = np.zeros((self.num_leaves, self.max_depth + 1), np.int32)
        for li, leaf in enumerate(leaves):
            j = leaf
            for d in range(self.max_depth, -1, -1):
                paths[li, d] = j
                j = int(self.parents[j])
        self.paths = paths

        # representative leaf ordinal per node (smallest leaf under it)
        src_leaf = np.zeros(N, np.int32)
        for li in range(self.num_leaves - 1, -1, -1):
            for j in paths[li]:
                src_leaf[j] = li
        self.src_leaf = src_leaf

    # ------------------------------------------------------------------
    @property
    def gamma(self) -> int:
        """Draft tokens per window (everything but the root)."""
        return self.num_nodes - 1

    @property
    def is_chain(self) -> bool:
        return all(b == 1 for b in self.branches)

    def __repr__(self) -> str:
        return (f"TreeTemplate(branches={self.branches}, "
                f"nodes={self.num_nodes}, leaves={self.num_leaves})")

    def __eq__(self, other) -> bool:
        return (isinstance(other, TreeTemplate)
                and self.branches == other.branches)

    def __hash__(self) -> int:
        return hash(self.branches)

    # -- device constants ----------------------------------------------
    # Materialized *eagerly* at construction (templates are built outside
    # jit): a lazily-cached jnp.asarray would capture a tracer if first
    # touched inside a traced decode step, then leak it across traces.
    def _build_dev(self) -> None:
        import jax.numpy as jnp
        self.depths_dev = jnp.asarray(self.depths)
        self.mask_dev = jnp.asarray(self.mask)
        self.parents_dev = jnp.asarray(self.parents)
        self.children_dev = jnp.asarray(self.children)
