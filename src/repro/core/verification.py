"""Lossless rejection-sampling verification (paper Eq. 2-3).

The verifier's (dequantized, BF16) logits define the target distribution
p(·).  The prompt-lookup drafter is deterministic, i.e. q(·) is a one-hot
at the drafted token, so Eq. 2 reduces to

    accept x̃_i  ⇔  r < p(x̃_i),     r ~ U[0,1]

and the residual distribution (Eq. 3) is norm(max(0, p - onehot(x̃_i))) —
p with the rejected token zeroed out.  At T=0 both reduce to exact-match
against argmax p.  The committed output is therefore distributed exactly
as standalone sampling from the verifier — quantization noise moves the
*distribution* (Table 4 fidelity), never breaks the *guarantee*.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prng


class VerifyResult(NamedTuple):
    n_accept: jax.Array      # (B,) int32 — accepted draft tokens ∈ [0, γ]
    next_token: jax.Array    # (B,) int32 — corrective / bonus token
    n_commit: jax.Array      # (B,) int32 — tokens committed = n_accept + 1


def _probs(logits: jax.Array, temperature: float) -> jax.Array:
    """(..., V) f32 target probabilities; T=0 → one-hot argmax."""
    if temperature == 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=jnp.float32)
    return jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)


def _sample(k, probs, per_row: bool):
    """Categorical draw dispatching on the per-row key layout."""
    logp = jnp.log(jnp.maximum(probs, 1e-30))
    if per_row:
        return prng.categorical_rows(k, logp).astype(jnp.int32)
    return jax.random.categorical(k, logp).astype(jnp.int32)


def _residual(p, q):
    """Eq. 3: norm(max(0, p - q)) with the numerically-empty fallback to
    p.  Single definition shared by chain and tree verification — the
    degenerate-tree bit-equality contract depends on the two paths using
    the exact same thresholds."""
    r = jnp.maximum(p - q, 0.0)
    rsum = jnp.sum(r, axis=-1, keepdims=True)
    return jnp.where(rsum > 1e-9, r / jnp.maximum(rsum, 1e-20), p)


def _sample_single(p_at, temperature: float, k_bonus, per_row: bool):
    """Degenerate window (no drafts): argmax / sample the one position."""
    if temperature == 0.0:
        return jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    return _sample(k_bonus, p_at, per_row)


def verify(
    logits: jax.Array,       # (B, γ+1, V) — logits[i] is p(· | window[:i+1])
    drafts: jax.Array,       # (B, γ) drafted tokens (window[1:])
    temperature: float,
    key: jax.Array,
    draft_probs: jax.Array | None = None,   # (B, γ, V) for model-based drafters
) -> VerifyResult:
    """Vectorized prefix rejection sampling.

    ``draft_probs=None`` means a deterministic drafter (one-hot q).  With a
    stochastic drafter (the Table-5 pruned-model baseline), the full Eq. 2
    ratio p/q and Eq. 3 residual are used.

    ``key`` is either a single PRNGKey (sampling noise shared across the
    batch — legacy) or a ``(B, 2)`` per-row key array (``repro.core.prng``):
    every row then consumes its own stream, making the committed tokens
    invariant to batch composition (continuous batching relies on this).
    """
    B, g1, V = logits.shape
    gamma = g1 - 1
    per_row = prng.is_per_row(key)
    p = _probs(logits, temperature)                                   # (B, γ+1, V)
    k_acc, k_res, k_bonus = prng.split3(key)

    if gamma == 0:
        # degenerate vanilla window (VanillaDrafter): nothing to accept —
        # sample/argmax the single position directly
        next_token = _sample_single(p[:, 0], temperature, k_bonus, per_row)
        zero = jnp.zeros((B,), jnp.int32)
        return VerifyResult(n_accept=zero, next_token=next_token,
                            n_commit=zero + 1)

    p_draft = jnp.take_along_axis(p[:, :gamma], drafts[..., None], axis=-1)[..., 0]  # (B, γ)
    if draft_probs is None:
        ratio = p_draft                                               # q = 1 at draft
    else:
        q_draft = jnp.take_along_axis(draft_probs, drafts[..., None], axis=-1)[..., 0]
        ratio = p_draft / jnp.maximum(q_draft, 1e-20)

    r = (prng.uniform_rows(k_acc, gamma) if per_row
         else jax.random.uniform(k_acc, (B, gamma)))
    accept = r < jnp.minimum(ratio, 1.0)                              # (B, γ)
    # prefix acceptance: position i counts only if 0..i-1 all accepted
    prefix_ok = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    n_accept = jnp.sum(prefix_ok, axis=1).astype(jnp.int32)           # (B,)

    # distribution at the first rejected position (or bonus at γ)
    p_at = jnp.take_along_axis(p, n_accept[:, None, None], axis=1)[:, 0]      # (B, V)
    all_accepted = n_accept == gamma

    if temperature == 0.0:
        next_token = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    else:
        # residual norm(max(0, p - q)) at the rejected position
        if draft_probs is None:
            rej_tok = jnp.take_along_axis(
                drafts, jnp.minimum(n_accept, gamma - 1)[:, None], axis=1)[:, 0]
            q_at = jax.nn.one_hot(rej_tok, V, dtype=jnp.float32)
        else:
            q_at = jnp.take_along_axis(
                draft_probs, jnp.minimum(n_accept, gamma - 1)[:, None, None], axis=1)[:, 0]
        corrective = _sample(k_res, _residual(p_at, q_at), per_row)
        bonus = _sample(k_bonus, p_at, per_row)
        next_token = jnp.where(all_accepted, bonus, corrective).astype(jnp.int32)

    return VerifyResult(n_accept=n_accept, next_token=next_token, n_commit=n_accept + 1)


# ---------------------------------------------------------------------------
# Tree verification: longest accepted root-to-leaf path (SpecInfer-style)
# ---------------------------------------------------------------------------

class TreeVerifyResult(NamedTuple):
    n_accept: jax.Array      # (B,) int32 — accepted path depth ∈ [0, D]
    next_token: jax.Array    # (B,) int32 — corrective / bonus token
    n_commit: jax.Array      # (B,) int32 — tokens committed = n_accept + 1
    path_nodes: jax.Array    # (B, D+1) int32 — window-node ids of the
    #                          accepted path (col 0 = root); cols beyond
    #                          n_accept are 0-filled and must be masked
    path_tokens: jax.Array   # (B, D) int32 — tokens along the accepted
    #                          path in chain order (commit-ready drafts)


def verify_tree(
    logits: jax.Array,       # (B, N, V) — logits[i] = p(· | root→i path)
    drafts: jax.Array,       # (B, N-1) drafted tokens, packed node order
    template,                # TreeTemplate (static topology)
    temperature: float,
    key: jax.Array,
    draft_probs: jax.Array | None = None,   # (B, N-1, V) stochastic q
) -> TreeVerifyResult:
    """Lossless rejection sampling down a token tree (Eq. 2-3 per branch).

    Walks the template level by level; at each level the current node's
    children are tested *in packed order* against the running target
    distribution ``p_cur`` (Eq. 2 ratio p/q).  A rejection folds the
    rejected child's q out of ``p_cur`` (Eq. 3 residual) before the next
    sibling is tested — the multi-draft recursive rejection rule, which
    keeps the committed stream distributed exactly as standalone sampling
    from the verifier for *any* tree.  If no child at a level is
    accepted, the corrective token is sampled from the final residual;
    a fully accepted path earns the leaf's bonus token.

    At T=0 this reduces to exact-match down the tree: a child is
    accepted iff its token equals the argmax at its parent, and the
    corrective token is that argmax.

    **Chain parity**: for the degenerate single-branch template this
    consumes PRNG bit-identically to :func:`verify` — same
    ``split3`` layout, same uniform shapes, same categorical draws —
    so a chain-as-tree decode step reproduces the chain step exactly
    (asserted per drafter × verifier in ``tests/test_tree.py``).
    """
    B, N, V = logits.shape
    D, mb = template.max_depth, template.max_branch
    per_row = prng.is_per_row(key)
    p_all = _probs(logits, temperature)                              # (B, N, V)
    k_acc, k_res, k_bonus = prng.split3(key)

    if N == 1:
        # root-only template (vanilla drafter as a tree): identical to
        # the chain gamma == 0 branch
        next_token = _sample_single(p_all[:, 0], temperature, k_bonus,
                                    per_row)
        zero = jnp.zeros((B,), jnp.int32)
        return TreeVerifyResult(
            n_accept=zero, next_token=next_token, n_commit=zero + 1,
            path_nodes=jnp.zeros((B, 1), jnp.int32),
            path_tokens=jnp.zeros((B, 0), jnp.int32))

    children = template.children_dev                                 # (N, mb)
    u = (prng.uniform_rows(k_acc, D * mb) if per_row
         else jax.random.uniform(k_acc, (B, D * mb)))
    u = u.reshape(B, D, mb)

    cur = jnp.zeros((B,), jnp.int32)          # node the walk sits on
    p_cur = p_all[:, 0]                       # target dist at `cur`
    done = jnp.zeros((B,), bool)              # a level rejected everything
    n_accept = jnp.zeros((B,), jnp.int32)
    node_cols = []
    tok_cols = []

    for d in range(1, D + 1):                 # static unroll: D is small
        ch_row = jnp.take(children, cur, axis=0)                     # (B, mb)
        accepted = jnp.zeros((B,), bool)
        new_cur = cur
        for s in range(mb):
            child = ch_row[:, s]
            has = child >= 0
            cidx = jnp.clip(child, 1, N - 1)
            tok = jnp.take_along_axis(drafts, cidx[:, None] - 1,
                                      axis=1)[:, 0]
            p_tok = jnp.take_along_axis(p_cur, tok[:, None], axis=1)[:, 0]
            if draft_probs is None:
                ratio = p_tok                 # q is one-hot at the draft
                q_dist = None
            else:
                q_dist = jnp.take_along_axis(
                    draft_probs, (cidx - 1)[:, None, None], axis=1)[:, 0]
                q_tok = jnp.take_along_axis(q_dist, tok[:, None],
                                            axis=1)[:, 0]
                ratio = p_tok / jnp.maximum(q_tok, 1e-20)
            ok = ((~done) & (~accepted) & has
                  & (u[:, d - 1, s] < jnp.minimum(ratio, 1.0)))
            if temperature != 0.0:
                # fold the rejected sibling's q out of the running target
                # (Eq. 3) so the next sibling / corrective sample sees
                # the proper residual.  At T=0 p is one-hot and the
                # update is a no-op, so it is skipped (chain parity).
                tested = (~done) & (~accepted) & has
                q_at = (jax.nn.one_hot(tok, V, dtype=jnp.float32)
                        if q_dist is None else q_dist)
                p_cur = jnp.where((tested & ~ok)[:, None],
                                  _residual(p_cur, q_at), p_cur)
            new_cur = jnp.where(ok, cidx, new_cur)
            accepted = accepted | ok
        # rows that accepted a child descend: p_cur ← p(· | path to child)
        p_next = jnp.take_along_axis(p_all, new_cur[:, None, None],
                                     axis=1)[:, 0]
        p_cur = jnp.where(accepted[:, None], p_next, p_cur)
        n_accept = n_accept + accepted.astype(jnp.int32)
        done = done | ~accepted
        cur = new_cur
        node_cols.append(jnp.where(accepted, new_cur, 0))
        tok_new = jnp.take_along_axis(drafts,
                                      jnp.clip(new_cur - 1, 0, N - 2)[:, None],
                                      axis=1)[:, 0]
        tok_cols.append(jnp.where(accepted, tok_new, 0))

    all_accepted = n_accept == D
    if temperature == 0.0:
        next_token = jnp.argmax(p_cur, axis=-1).astype(jnp.int32)
    else:
        # p_cur is the residual for rejected rows
        corrective = _sample(k_res, p_cur, per_row)
        p_bonus = jnp.take_along_axis(p_all, cur[:, None, None],
                                      axis=1)[:, 0]
        bonus = _sample(k_bonus, p_bonus, per_row)
        next_token = jnp.where(all_accepted, bonus, corrective).astype(jnp.int32)

    path_nodes = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32)] + [c[:, None] for c in node_cols],
        axis=1)
    path_tokens = jnp.stack(tok_cols, axis=1)
    return TreeVerifyResult(n_accept=n_accept, next_token=next_token,
                            n_commit=n_accept + 1, path_nodes=path_nodes,
                            path_tokens=path_tokens)
