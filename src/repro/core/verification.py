"""Lossless rejection-sampling verification (paper Eq. 2-3).

The verifier's (dequantized, BF16) logits define the target distribution
p(·).  The prompt-lookup drafter is deterministic, i.e. q(·) is a one-hot
at the drafted token, so Eq. 2 reduces to

    accept x̃_i  ⇔  r < p(x̃_i),     r ~ U[0,1]

and the residual distribution (Eq. 3) is norm(max(0, p - onehot(x̃_i))) —
p with the rejected token zeroed out.  At T=0 both reduce to exact-match
against argmax p.  The committed output is therefore distributed exactly
as standalone sampling from the verifier — quantization noise moves the
*distribution* (Table 4 fidelity), never breaks the *guarantee*.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import prng


class VerifyResult(NamedTuple):
    n_accept: jax.Array      # (B,) int32 — accepted draft tokens ∈ [0, γ]
    next_token: jax.Array    # (B,) int32 — corrective / bonus token
    n_commit: jax.Array      # (B,) int32 — tokens committed = n_accept + 1


def _probs(logits: jax.Array, temperature: float) -> jax.Array:
    """(..., V) f32 target probabilities; T=0 → one-hot argmax."""
    if temperature == 0.0:
        return jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1], dtype=jnp.float32)
    return jax.nn.softmax(logits.astype(jnp.float32) / temperature, axis=-1)


def verify(
    logits: jax.Array,       # (B, γ+1, V) — logits[i] is p(· | window[:i+1])
    drafts: jax.Array,       # (B, γ) drafted tokens (window[1:])
    temperature: float,
    key: jax.Array,
    draft_probs: jax.Array | None = None,   # (B, γ, V) for model-based drafters
) -> VerifyResult:
    """Vectorized prefix rejection sampling.

    ``draft_probs=None`` means a deterministic drafter (one-hot q).  With a
    stochastic drafter (the Table-5 pruned-model baseline), the full Eq. 2
    ratio p/q and Eq. 3 residual are used.

    ``key`` is either a single PRNGKey (sampling noise shared across the
    batch — legacy) or a ``(B, 2)`` per-row key array (``repro.core.prng``):
    every row then consumes its own stream, making the committed tokens
    invariant to batch composition (continuous batching relies on this).
    """
    B, g1, V = logits.shape
    gamma = g1 - 1
    per_row = prng.is_per_row(key)
    p = _probs(logits, temperature)                                   # (B, γ+1, V)
    k_acc, k_res, k_bonus = prng.split3(key)

    def _sample(k, probs):
        logp = jnp.log(jnp.maximum(probs, 1e-30))
        if per_row:
            return prng.categorical_rows(k, logp).astype(jnp.int32)
        return jax.random.categorical(k, logp).astype(jnp.int32)

    if gamma == 0:
        # degenerate vanilla window (VanillaDrafter): nothing to accept —
        # sample/argmax the single position directly
        p_at = p[:, 0]
        if temperature == 0.0:
            next_token = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
        else:
            next_token = _sample(k_bonus, p_at)
        zero = jnp.zeros((B,), jnp.int32)
        return VerifyResult(n_accept=zero, next_token=next_token,
                            n_commit=zero + 1)

    p_draft = jnp.take_along_axis(p[:, :gamma], drafts[..., None], axis=-1)[..., 0]  # (B, γ)
    if draft_probs is None:
        ratio = p_draft                                               # q = 1 at draft
    else:
        q_draft = jnp.take_along_axis(draft_probs, drafts[..., None], axis=-1)[..., 0]
        ratio = p_draft / jnp.maximum(q_draft, 1e-20)

    r = (prng.uniform_rows(k_acc, gamma) if per_row
         else jax.random.uniform(k_acc, (B, gamma)))
    accept = r < jnp.minimum(ratio, 1.0)                              # (B, γ)
    # prefix acceptance: position i counts only if 0..i-1 all accepted
    prefix_ok = jnp.cumprod(accept.astype(jnp.int32), axis=1)
    n_accept = jnp.sum(prefix_ok, axis=1).astype(jnp.int32)           # (B,)

    # distribution at the first rejected position (or bonus at γ)
    p_at = jnp.take_along_axis(p, n_accept[:, None, None], axis=1)[:, 0]      # (B, V)
    all_accepted = n_accept == gamma

    if temperature == 0.0:
        next_token = jnp.argmax(p_at, axis=-1).astype(jnp.int32)
    else:
        # residual norm(max(0, p - q)) at the rejected position
        if draft_probs is None:
            rej_tok = jnp.take_along_axis(
                drafts, jnp.minimum(n_accept, gamma - 1)[:, None], axis=1)[:, 0]
            q_at = jax.nn.one_hot(rej_tok, V, dtype=jnp.float32)
        else:
            q_at = jnp.take_along_axis(
                draft_probs, jnp.minimum(n_accept, gamma - 1)[:, None, None], axis=1)[:, 0]
        residual = jnp.maximum(p_at - q_at, 0.0)
        # fall back to p when the residual is numerically empty
        rsum = jnp.sum(residual, axis=-1, keepdims=True)
        residual = jnp.where(rsum > 1e-9, residual / jnp.maximum(rsum, 1e-20), p_at)
        corrective = _sample(k_res, residual)
        bonus = _sample(k_bonus, p_at)
        next_token = jnp.where(all_accepted, bonus, corrective).astype(jnp.int32)

    return VerifyResult(n_accept=n_accept, next_token=next_token, n_commit=n_accept + 1)
