"""Registered :class:`~repro.core.protocols.Verifier` implementations.

All verifiers share the lossless rejection-sampling accept rule
(``repro.core.verification.verify``); they differ in *offline weight
preparation* — what ``prepare`` does to the target params before they are
streamed every verify step.  This is where the paper's W8A8 claim lives:
``W8A8Verifier.prepare`` applies SmoothQuant + symmetric INT8 so the
memory-bound verification pass streams half (or a quarter, ``w4a8``) the
bytes of BF16.
"""
from __future__ import annotations

from typing import Optional

from repro.core.config import QuantConfig, SpecConfig
from repro.core.protocols import Verifier, register_verifier


@register_verifier("bf16")
class BF16Verifier(Verifier):
    """Full-precision verification: params pass through untouched."""


@register_verifier("w8a8")
class W8A8Verifier(Verifier):
    """Quantized verification (paper §3.2-3.3): ``prepare`` walks the
    param pytree and replaces every quantizable linear with its smoothed
    W8A8 layout.  Idempotent — already-quantized trees pass through.

    ``act_stats`` (per-input-channel activation maxima from a calibration
    pass) sharpens the SmoothQuant migration; without them smoothing is
    weight-only (s=1), which is still lossless w.r.t. the *quantized*
    verifier's own distribution (Eq. 2-3 hold for whatever p the verifier
    defines).
    """

    def __init__(self, qcfg: Optional[QuantConfig] = None):
        self.qcfg = qcfg if qcfg is not None else QuantConfig()

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "W8A8Verifier":
        return cls(QuantConfig())

    def prepare(self, model, params, act_stats=None):
        from repro.quant.apply import quantize_params
        return quantize_params(params, act_stats, self.qcfg)


@register_verifier("w4a8")
class W4A8Verifier(W8A8Verifier):
    """Ultra-low-bit variant (paper §5 future work): INT4 weights where
    shapes allow, INT8 activations."""

    @classmethod
    def from_config(cls, scfg: SpecConfig) -> "W4A8Verifier":
        return cls(QuantConfig(w_bits=4))
