from repro.data.synthetic import (  # noqa: F401
    ambiguous_prompts,
    lm_batches,
    synthetic_corpus,
    task_prompts,
)
