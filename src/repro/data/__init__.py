from repro.data.synthetic import (  # noqa: F401
    lm_batches,
    synthetic_corpus,
    task_prompts,
)
