"""Synthetic data pipeline.

Two needs:

* **training** — a learnable token stream (Zipf marginals + first-order
  Markov structure) so the end-to-end train example shows a falling loss;
* **serving / paper tables** — corpora with controllable *repetition*,
  because prompt-lookup drafting lives off n-gram reuse.  Each paper task
  gets a repetition preset chosen to mirror its qualitative behaviour
  (code/math >> open-ended chat), so Table-1-style orderings reproduce.

Serve prompts share the training Markov chain (same ``data_seed`` →
same successor table), so a trained stand-in model assigns realistic
probability to in-distribution continuations — that is what makes T>0
acceptance behave like the paper's real-LLM setting.
"""
from __future__ import annotations

import zlib
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

# copy-probability presets per paper benchmark task (§4.1)
TASK_REPETITION: Dict[str, float] = {
    "mtbench": 0.30,
    "humaneval": 0.55,
    "gsm8k": 0.60,
    "alpaca": 0.25,
    "cnndm": 0.35,
}

N_SUCC = 4  # likely successors per token in the Markov chain


def succ_table(vocab: int, data_seed: int = 0) -> np.ndarray:
    """The Markov-chain successor table — FIRST draw from the seeded rng so
    ``lm_batches`` and ``task_prompts`` agree on the chain."""
    return np.random.default_rng(data_seed).integers(0, vocab, size=(vocab, N_SUCC))


def synthetic_corpus(
    rng: np.random.Generator,
    length: int,
    vocab: int,
    repeat_prob: float = 0.3,
    mean_copy_len: int = 8,
    markov: Optional[Tuple[np.ndarray, float]] = None,  # (succ, alpha)
) -> np.ndarray:
    """Token stream where, with probability ``repeat_prob`` per position, a
    segment copied from earlier in the stream continues (geometric length)
    — exactly the structure prompt-lookup decoding exploits.  Fresh tokens
    follow the Markov chain when given, else a Zipf marginal."""
    out = np.empty(length, np.int32)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = (1.0 / ranks) / (1.0 / ranks).sum()
    i = 0
    while i < length:
        if i > 16 and rng.random() < repeat_prob:
            src = int(rng.integers(0, i - 8))
            n = min(1 + rng.geometric(1.0 / mean_copy_len), length - i, i - src)
            out[i : i + n] = out[src : src + n]
            i += n
        else:
            if markov is not None and i > 0 and rng.random() < markov[1]:
                out[i] = markov[0][out[i - 1], rng.integers(0, N_SUCC)]
            else:
                out[i] = rng.choice(vocab, p=probs)
            i += 1
    return out


def task_prompts(
    task: str,
    batch: int,
    prompt_len: int,
    vocab: int,
    seed: int = 0,
    data_seed: int = 0,
    markov_alpha: float = 0.97,
) -> np.ndarray:
    """(B, P) int32 prompts with the task's repetition preset, drawn from
    the same Markov chain the stand-in models train on."""
    rep = TASK_REPETITION.get(task, 0.3)
    # crc32, NOT hash(): str hashing is salted per process, which made
    # "identical" benchmark prompts differ run to run
    rng = np.random.default_rng(seed + zlib.crc32(task.encode()) % 2**31)
    succ = succ_table(vocab, data_seed)
    return np.stack([
        synthetic_corpus(rng, prompt_len, vocab, rep,
                         markov=(succ, markov_alpha))
        for _ in range(batch)
    ])


def ambiguous_prompts(
    batch: int,
    prompt_len: int,
    vocab: int,
    depth: int = 4,
    seed: int = 0,
    data_seed: int = 0,
) -> np.ndarray:
    """Repetition workload with *ambiguous* trailing-gram continuations —
    the case tree drafting exists for.

    Each row ends in an anchor bigram ``(a, b)`` whose earlier
    occurrences continue differently: the older copies each follow one of
    the Markov chain's likely successors of ``b`` (the distribution the
    stand-in models are trained on), while the **most recent** copy
    continues with junk.  Chain prompt-lookup must propose the junk
    continuation (most-recent-match rule) and get rejected; a tree
    drafter's sibling branches cover the successor continuations, one of
    which is the trained model's greedy pick — so sibling rescue is
    exercised at the very first verify step of every row.  Tokens < 2·len
    are filler drawn from the same chain.
    """
    succ = succ_table(vocab, data_seed)
    out = np.empty((batch, prompt_len), np.int32)
    for r in range(batch):
        rng = np.random.default_rng(seed * 1009 + r)
        a, b = rng.integers(0, vocab, 2)
        branches = list(dict.fromkeys(succ[b].tolist()))[:3]
        blocks = []
        for s in branches:                 # older copies: successor walks
            walk, t = [s], s
            for _ in range(depth - 1):
                t = succ[t, 0]
                walk.append(t)
            blocks.append([a, b] + walk + [int(rng.integers(0, vocab))])
        junk = [t for t in rng.permutation(vocab)[: depth + 2]
                if t not in set(succ[b].tolist())][:depth]
        blocks.append([a, b] + junk)       # most recent copy: junk
        tail = sum(blocks, []) + [a, b]
        fill_len = prompt_len - len(tail)
        if fill_len < 0:
            raise ValueError(f"prompt_len {prompt_len} too short for "
                             f"{len(tail)} structured tokens")
        fill = synthetic_corpus(rng, fill_len, vocab, 0.0,
                                markov=(succ, 0.97)) if fill_len else []
        out[r] = np.concatenate([np.asarray(fill, np.int32),
                                 np.asarray(tail, np.int32)])
    return out


def lm_batches(
    batch: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    markov_alpha: float = 0.9,
) -> Iterator[dict]:
    """Infinite iterator of {"tokens", "labels"} with learnable structure:
    a random sparse first-order Markov chain over the vocab."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(0, vocab, size=(vocab, N_SUCC))  # == succ_table(vocab, seed)
    while True:
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, vocab, batch)
        for t in range(seq_len):
            follow = rng.random(batch) < markov_alpha
            pick = succ[toks[:, t], rng.integers(0, N_SUCC, batch)]
            rand = rng.integers(0, vocab, batch)
            toks[:, t + 1] = np.where(follow, pick, rand)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}
