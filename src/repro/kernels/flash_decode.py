"""Pallas TPU kernel: flash attention for the speculative *verify/decode*
step — a short query window (T = 1…γ+1) attending a long contiguous KV
cache with online softmax over cache blocks.

This is the attention hot-spot of Quasar's verification pass at long
context (EXPERIMENTS §Roofline: decode_32k memory term is cache-read
dominated).  Design:

* grid = (B, Hkv, S/block_s); the S dimension is innermost/"arbitrary" so
  the (m, l, acc) running-softmax state lives in VMEM scratch across cache
  blocks and the output is written exactly once;
* all G = Hq/Hkv grouped query heads of one kv head are processed together
  (rows = G·T ≤ a few dozen — one VREG tile);
* causality against the cache: slot index == absolute position
  (contiguous cache layout), masked against the per-(row, t) query
  positions streamed in as an int32 block;
* **int8 KV cache** (``k_scale``/``v_scale``): K/V blocks stay int8 all
  the way into VMEM — the HBM cache-read traffic is halved — and the
  per-(token, head) symmetric scales are streamed as their own (1, bs)
  f32 blocks.  They are folded into the online softmax exactly as the
  jnp oracle does: scores are scaled per key *column* before masking,
  probabilities are scaled before the ``p·v`` product but **after** the
  running ``l`` sum (the softmax normaliser must see unscaled mass);
* **token-tree windows** (``tree_mask``/``win_start``): the T window
  tokens occupy cache slots ``[win_start, win_start + T)`` in packed node
  order while ``qpos`` carries ``win_start + depth``.  Inside that slot
  range the template's ancestor-or-self mask replaces position causality.
  The per-column ancestor bit is gathered MXU-style — a (GT, T) mask
  matmul against a (T, block_s) relative-slot one-hot — so the kernel
  needs no dynamic gathers.  Tree windows compose with int8 KV: the
  quantized verify path is the tree path with scales folded in.

* **paged KV cache** (:func:`flash_decode_paged`): K/V live in physical
  block pools ``(num_blocks, block_size, Hkv, dh)`` shared by all rows,
  and a per-row block table maps logical block ``s`` to its physical
  block.  The table rides in as a **scalar-prefetch** operand
  (``pltpu.PrefetchScalarGridSpec``), so the K/V/scale BlockSpec
  index_maps dereference it — ``(bt[b, s], h, 0, 0)`` — and the blocks
  stream straight from their pool homes with *no gather materialisation*.
  The grid's S dimension walks logical blocks, so the softmax body is
  byte-for-byte the contiguous ``_flash_body`` (logical position =
  ``s * block_size + lane``); int8 scales and tree masks compose
  unchanged.

The pure-jnp oracle is the ``attend`` path in models/attention.py (which
accepts the same ``k_scale``/``v_scale``/``tree_mask``/``win_start``);
the paged oracle gathers the logical view first
(``repro.core.paged_cache.gather_block_rows``).  Tests sweep shapes and
templates and assert allclose in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401
from repro.kernels.pallas_compat import CompilerParams

MASK_VAL = -1e30


def _flash_body(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref, tm_ref, ws_ref,
                o_ref, m_ref, l_ref, acc_ref,
                *, ns: int, block_s: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, MASK_VAL)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # (GT, dh)
    k = k_ref[0, 0].astype(jnp.float32)           # (bs, dh) — int8 upcast in VMEM
    v = v_ref[0, 0].astype(jnp.float32)           # (bs, dh)
    qpos = qpos_ref[0]                            # (GT, 1) int32

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (GT, bs)
    if ks_ref is not None:
        # int8 KV: per-(token, head) key scale folded into the score columns
        s = s * ks_ref[0, 0]                      # (1, bs) broadcast over rows
    kpos = s_idx * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos <= qpos                          # slot==position causality
    if tm_ref is not None:
        T = tm_ref.shape[-1]
        ws = ws_ref[0]                            # scalar window start
        rel = kpos - ws                           # (GT, bs) row-invariant
        in_win = (rel >= 0) & (rel < T)
        # ancestor gather as a matmul: onehot[j, c] = (slot_c - ws == j)
        onehot = (jax.lax.broadcasted_iota(jnp.int32, (T, block_s), 0)
                  == (jax.lax.broadcasted_iota(jnp.int32, (T, block_s), 1)
                      + s_idx * block_s - ws)).astype(jnp.float32)
        anc = jnp.dot(tm_ref[0, 0], onehot,
                      preferred_element_type=jnp.float32) > 0.5  # (GT, bs)
        valid = jnp.where(in_win, anc, valid)
    s = jnp.where(valid, s, MASK_VAL)

    m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    if vs_ref is not None:
        # value scale folds into the probabilities *after* the l sum — the
        # normaliser must accumulate unscaled probability mass
        p = p * vs_ref[0, 0]                      # (1, bs)
    acc_new = acc_prev * alpha + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc_new

    @pl.when(s_idx == ns - 1)
    def _epilogue():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _kernel(q_ref, k_ref, v_ref, qpos_ref, o_ref, m_ref, l_ref, acc_ref,
            *, ns: int, block_s: int, scale: float):
    """Plain chain window over a bf16/f32 contiguous cache."""
    _flash_body(q_ref, k_ref, v_ref, qpos_ref, None, None, None, None,
                o_ref, m_ref, l_ref, acc_ref,
                ns=ns, block_s=block_s, scale=scale)


def _kernel_tree(q_ref, k_ref, v_ref, qpos_ref, tm_ref, ws_ref,
                 o_ref, m_ref, l_ref, acc_ref,
                 *, ns: int, block_s: int, scale: float):
    """Tree-masked window (ancestor mask + window start) over bf16/f32."""
    _flash_body(q_ref, k_ref, v_ref, qpos_ref, None, None, tm_ref, ws_ref,
                o_ref, m_ref, l_ref, acc_ref,
                ns=ns, block_s=block_s, scale=scale)


def _kernel_int8(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref,
                 o_ref, m_ref, l_ref, acc_ref,
                 *, ns: int, block_s: int, scale: float):
    """Chain window over an int8 cache (per-(token, head) scale refs)."""
    _flash_body(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref, None, None,
                o_ref, m_ref, l_ref, acc_ref,
                ns=ns, block_s=block_s, scale=scale)


def _kernel_tree_int8(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref,
                      tm_ref, ws_ref, o_ref, m_ref, l_ref, acc_ref,
                      *, ns: int, block_s: int, scale: float):
    """Tree-masked window over an int8 cache — the fully-loaded variant."""
    _flash_body(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref, tm_ref, ws_ref,
                o_ref, m_ref, l_ref, acc_ref,
                ns=ns, block_s=block_s, scale=scale)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def flash_decode(
    q: jax.Array,        # (B, T, Hq, dh) query window
    k: jax.Array,        # (B, S, Hkv, dh) contiguous KV cache (bf16/f32 or int8)
    v: jax.Array,        # (B, S, Hkv, dh)
    qpos: jax.Array,     # (B, T) int32 absolute query positions
    *,
    k_scale: jax.Array | None = None,     # (B, S, Hkv) f32 int8-KV scales
    v_scale: jax.Array | None = None,     # (B, S, Hkv)
    tree_mask: jax.Array | None = None,   # (T, T) bool ancestor-or-self
    win_start: jax.Array | None = None,   # (B,) int32 first window slot
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Flash verification attention over a *contiguous* KV cache.

    Args / shapes:
      q          ``(B, T, Hq, dh)``   verify/decode query window
                 (T = 1…γ+1; Hq must be a multiple of Hkv — GQA groups
                 are processed together per kv head);
      k, v       ``(B, S, Hkv, dh)``  the cache buffers, slot index ==
                 absolute position; bf16/f32, or int8 with scales;
      qpos       ``(B, T)`` int32     absolute query positions (per-row
                 ``start + arange`` for chains, ``start + depth`` for
                 tree windows);
      k_scale, v_scale  ``(B, S, Hkv)`` f32 — per-(token, head) int8-KV
                 scales; pass both or neither;
      tree_mask  ``(T, T)`` bool      ancestor-or-self mask of a packed
                 tree window (requires ``win_start (B,) int32``);
      block_s    cache-block tile size (S is zero-padded to a multiple;
                 pad slots sit at positions ≥ S, masked by causality);
      interpret  run the kernel in Pallas interpret mode (CPU parity).

    Returns ``(B, T, Hq, dh)`` in ``q.dtype`` — numerically equal
    (≤1e-5, f32 accumulation) to the jnp oracle
    ``models.attention.attend``.
    """
    B, T, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    GT = G * T
    scale = dh ** -0.5
    tree = tree_mask is not None
    if tree and win_start is None:
        raise ValueError("tree_mask requires win_start")
    int8 = k_scale is not None
    if int8 != (v_scale is not None):
        raise ValueError("k_scale and v_scale must be passed together")

    bs = min(block_s, S)
    Sp = (-S) % bs + S
    if Sp != S:  # pad slots sit at positions >= S and are masked by qpos
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        if int8:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, Sp - S), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, Sp - S), (0, 0)))
    ns = Sp // bs

    # (B, Hkv, GT, dh): group the G query heads of each kv head
    qg = q.reshape(B, T, Hkv, G, dh).transpose(0, 2, 3, 1, 4).reshape(B, Hkv, GT, dh)
    kk = k.transpose(0, 2, 1, 3)                  # (B, Hkv, Sp, dh)
    vv = v.transpose(0, 2, 1, 3)
    # per-row query positions, broadcast over G
    qp = jnp.repeat(qpos[:, None, :], G, axis=1).reshape(B, GT, 1)

    in_specs = [
        pl.BlockSpec((1, 1, GT, dh), lambda b, h, s: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, dh), lambda b, h, s: (b, h, s, 0)),
        pl.BlockSpec((1, 1, bs, dh), lambda b, h, s: (b, h, s, 0)),
        pl.BlockSpec((1, GT, 1), lambda b, h, s: (b, 0, 0)),
    ]
    operands = [qg, kk, vv, qp]
    if int8:
        # (B, Hkv, 1, Sp): one scale row per cache block, broadcast over
        # the GT score rows inside the kernel
        ksc = k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        vsc = v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        spec = pl.BlockSpec((1, 1, 1, bs), lambda b, h, s: (b, h, 0, s))
        in_specs += [spec, spec]
        operands += [ksc, vsc]
    if tree:
        # ancestor rows repeated per grouped head: GT index = g*T + t
        tm = jnp.tile(tree_mask.astype(jnp.float32), (G, 1))   # (GT, T)
        in_specs.append(
            pl.BlockSpec((1, 1, GT, T), lambda b, h, s: (0, 0, 0, 0)))
        in_specs.append(
            pl.BlockSpec((1,), lambda b, h, s: (b,),
                         memory_space=pltpu.SMEM))
        operands += [tm[None, None], win_start.astype(jnp.int32)]
        kernel_fn = _kernel_tree_int8 if int8 else _kernel_tree
    else:
        kernel_fn = _kernel_int8 if int8 else _kernel
    kernel = functools.partial(kernel_fn, ns=ns, block_s=bs, scale=scale)

    out_dtype = q.dtype
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, GT, dh), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, GT, dh), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((GT, 1), jnp.float32),
            pltpu.VMEM((GT, 1), jnp.float32),
            pltpu.VMEM((GT, dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)

    # (B, Hkv, GT, dh) → (B, T, Hq, dh)
    return out.reshape(B, Hkv, G, T, dh).transpose(0, 3, 1, 2, 4).reshape(B, T, Hq, dh)


# ---------------------------------------------------------------------------
# Paged (block-table) variant
# ---------------------------------------------------------------------------

def _make_paged_kernel(int8: bool, tree: bool):
    """Kernel shim: route the scalar-prefetched block table (consumed by
    the BlockSpec index_maps, unused in the body) and the optional
    int8-scale / tree-mask refs into the shared ``_flash_body``."""
    def kernel(bt_ref, *refs, ns, block_s, scale):
        del bt_ref                     # only the index_maps dereference it
        q_ref, k_ref, v_ref, qpos_ref = refs[:4]
        i = 4
        ks_ref = vs_ref = tm_ref = ws_ref = None
        if int8:
            ks_ref, vs_ref = refs[i: i + 2]
            i += 2
        if tree:
            tm_ref, ws_ref = refs[i: i + 2]
            i += 2
        o_ref, m_ref, l_ref, acc_ref = refs[i: i + 4]
        _flash_body(q_ref, k_ref, v_ref, qpos_ref, ks_ref, vs_ref,
                    tm_ref, ws_ref, o_ref, m_ref, l_ref, acc_ref,
                    ns=ns, block_s=block_s, scale=scale)
    return kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_paged(
    q: jax.Array,        # (B, T, Hq, dh) query window
    k: jax.Array,        # (N, bs, Hkv, dh) physical K block pool
    v: jax.Array,        # (N, bs, Hkv, dh) physical V block pool
    bt: jax.Array,       # (B, nb) int32 block table (logical → physical)
    qpos: jax.Array,     # (B, T) int32 absolute query positions
    *,
    k_scale: jax.Array | None = None,     # (N, bs, Hkv) f32 int8-KV scales
    v_scale: jax.Array | None = None,     # (N, bs, Hkv)
    tree_mask: jax.Array | None = None,   # (T, T) bool ancestor-or-self
    win_start: jax.Array | None = None,   # (B,) int32 first window slot
    interpret: bool = False,
) -> jax.Array:
    """Flash verification attention over a **paged** KV cache.

    Identical online-softmax math to :func:`flash_decode`; the only
    difference is *addressing*: the grid's innermost dimension walks the
    ``nb`` logical blocks of each row's sequence, and the K/V (+ scale)
    BlockSpec index_maps look the physical block up in the
    scalar-prefetched table — ``(bt[b, s], h, 0, 0)`` — so each block
    streams HBM→VMEM from its pool home.  Logical key positions are
    reconstructed in-kernel as ``s * block_size + lane``, which keeps
    slot==position causality, tree-window masking and int8 scale folding
    byte-identical to the contiguous kernel.  Returns ``(B, T, Hq, dh)``.
    """
    B, T, Hq, dh = q.shape
    N, bs, Hkv, _ = k.shape
    nb = bt.shape[1]
    G = Hq // Hkv
    GT = G * T
    scale = dh ** -0.5
    tree = tree_mask is not None
    if tree and win_start is None:
        raise ValueError("tree_mask requires win_start")
    int8 = k_scale is not None
    if int8 != (v_scale is not None):
        raise ValueError("k_scale and v_scale must be passed together")

    # (B, Hkv, GT, dh): group the G query heads of each kv head
    qg = q.reshape(B, T, Hkv, G, dh).transpose(0, 2, 3, 1, 4).reshape(B, Hkv, GT, dh)
    kk = k.transpose(0, 2, 1, 3)                  # (N, Hkv, bs, dh)
    vv = v.transpose(0, 2, 1, 3)
    qp = jnp.repeat(qpos[:, None, :], G, axis=1).reshape(B, GT, 1)

    in_specs = [
        pl.BlockSpec((1, 1, GT, dh), lambda b, h, s, bt_ref: (b, h, 0, 0)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b, h, s, bt_ref: (bt_ref[b, s], h, 0, 0)),
        pl.BlockSpec((1, 1, bs, dh),
                     lambda b, h, s, bt_ref: (bt_ref[b, s], h, 0, 0)),
        pl.BlockSpec((1, GT, 1), lambda b, h, s, bt_ref: (b, 0, 0)),
    ]
    operands = [qg, kk, vv, qp]
    if int8:
        ksc = k_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        vsc = v_scale.astype(jnp.float32).transpose(0, 2, 1)[:, :, None, :]
        spec = pl.BlockSpec((1, 1, 1, bs),
                            lambda b, h, s, bt_ref: (bt_ref[b, s], h, 0, 0))
        in_specs += [spec, spec]
        operands += [ksc, vsc]
    if tree:
        tm = jnp.tile(tree_mask.astype(jnp.float32), (G, 1))   # (GT, T)
        in_specs.append(pl.BlockSpec((1, 1, GT, T),
                                     lambda b, h, s, bt_ref: (0, 0, 0, 0)))
        in_specs.append(pl.BlockSpec((1,), lambda b, h, s, bt_ref: (b,),
                                     memory_space=pltpu.SMEM))
        operands += [tm[None, None], win_start.astype(jnp.int32)]
    kernel = functools.partial(_make_paged_kernel(int8, tree),
                               ns=nb, block_s=bs, scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, GT, dh),
                               lambda b, h, s, bt_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((GT, 1), jnp.float32),
            pltpu.VMEM((GT, 1), jnp.float32),
            pltpu.VMEM((GT, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, GT, dh), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(bt.astype(jnp.int32), *operands)

    # (B, Hkv, GT, dh) → (B, T, Hq, dh)
    return out.reshape(B, Hkv, G, T, dh).transpose(0, 3, 1, 2, 4).reshape(B, T, Hq, dh)
