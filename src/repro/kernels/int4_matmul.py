"""Pallas TPU kernel: W4A8 GEMM — int4 weights unpacked from their packed
int8 representation *inside the kernel* (VMEM), so HBM only ever streams
0.5 bytes/weight.  Activations are int8 (the smooth_quant path); int32 MXU
accumulation; fused per-token × per-channel dequant epilogue.

Packing layout matches ``repro.quant.int4.pack_int4``: byte b at packed
row r holds weight rows (2r, 2r+1) as (low nibble, high nibble), both
sign-extended 4-bit two's complement.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401
from repro.kernels.pallas_compat import CompilerParams


def _unpack(packed):
    """(bk/2, bn) int8 → (bk, bn) int8 in [-8, 7] via arithmetic shifts."""
    lo = jnp.right_shift(jnp.left_shift(packed, 4), 4)
    hi = jnp.right_shift(packed, 4)
    k2, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(k2 * 2, n)


def _kernel(x_ref, wp_ref, dx_ref, dw_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = _unpack(wp_ref[...])
    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        dx = dx_ref[...].astype(jnp.float32)
        dw = dw_ref[...].astype(jnp.float32)
        o_ref[...] = (acc * dx * dw).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def int4_matmul(
    x_int8: jax.Array,     # (M, K) int8 activations
    w_packed: jax.Array,   # (K/2, N) int8 — two int4 weights per byte
    dx: jax.Array,         # (M,) f32 per-token scale
    dw: jax.Array,         # (N,) f32 per-channel scale
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,    # must be even (pairs stay in one block)
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_int8.shape
    K2, N = w_packed.shape
    assert K == 2 * K2, (x_int8.shape, w_packed.shape)

    bm, bn = min(block_m, M), min(block_n, N)
    bk = min(block_k, K)
    bk += bk % 2
    Mp, Np, Kp = (-M) % bm + M, (-N) % bn + N, (-K) % bk + K
    if (Mp, Kp) != (M, K):
        x_int8 = jnp.pad(x_int8, ((0, Mp - M), (0, Kp - K)))
        dx = jnp.pad(dx, (0, Mp - M))
    if (Kp // 2, Np) != (K2, N):
        w_packed = jnp.pad(w_packed, ((0, Kp // 2 - K2), (0, Np - N)))
        dw = jnp.pad(dw, (0, Np - N))

    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int8, w_packed, dx[:, None], dw[None, :])
    return out[:M, :N]
