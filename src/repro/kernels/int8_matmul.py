"""Pallas TPU kernel: W8A8 INT8 GEMM with INT32 MXU accumulation and a fused
per-token × per-channel dequantization epilogue (paper Eq. 8 / Eq. 10).

TPU adaptation notes (vs. the paper's Ascend NPU kernel):
  * the MXU natively consumes int8×int8→int32 via
    ``jnp.dot(..., preferred_element_type=jnp.int32)``;
  * blocks are 128-aligned to match the MXU systolic array and VMEM tiling;
  * the int32 accumulator lives in a VMEM scratch tile that is reused across
    the K grid dimension (innermost, "arbitrary" semantics), so partial sums
    never round-trip to HBM;
  * dequant scales (Δx row-block, Δw col-block) are streamed into VMEM with
    their own BlockSpecs and applied in the epilogue on the last K step —
    the FP output is written to HBM exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401
from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, w_ref, dx_ref, dw_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        dx = dx_ref[...].astype(jnp.float32)   # (bm, 1)
        dw = dw_ref[...].astype(jnp.float32)   # (1, bn)
        o_ref[...] = (acc * dx * dw).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def int8_matmul(
    x_int8: jax.Array,    # (M, K) int8
    w_int8: jax.Array,    # (K, N) int8
    dx: jax.Array,        # (M,) f32
    dw: jax.Array,        # (N,) f32
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    M, K = x_int8.shape
    K2, N = w_int8.shape
    assert K == K2, (x_int8.shape, w_int8.shape)

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    # pad to block multiples (zero int8 rows/cols contribute 0 to the int32 acc)
    Mp, Np, Kp = (-M) % bm + M, (-N) % bn + N, (-K) % bk + K
    if (Mp, Kp) != (M, K):
        x_int8 = jnp.pad(x_int8, ((0, Mp - M), (0, Kp - K)))
        dx = jnp.pad(dx, (0, Mp - M))
    if (Kp, Np) != (K, N):
        w_int8 = jnp.pad(w_int8, ((0, Kp - K), (0, Np - N)))
        dw = jnp.pad(dw, (0, Np - N))

    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)

    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x_int8, w_int8, dx[:, None], dw[None, :])
    return out[:M, :N]
