"""Public jit'd wrappers around the Pallas kernels, with an XLA fallback.

Dispatch policy (shared by :func:`w8a8_matmul` and :func:`flash_attend`):
  * on TPU backends the Pallas kernels run compiled;
  * on CPU (this container) the default is the XLA path, which is
    numerically identical (same int8 quantize semantics, exact int32 GEMM via
    ``dot_general(..., preferred_element_type=int32)``; same mask/online-
    softmax semantics for attention) and keeps the quantized operand int8
    in the HLO — so ``cost_analysis()`` sees the halved weight / KV-cache
    bytes exactly as the TPU kernels would;
  * ``REPRO_USE_PALLAS=1`` (or ``set_use_pallas(True)``) forces the Pallas
    kernels in ``interpret=True`` mode for validation.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode, flash_decode_paged
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.smooth_quant import smooth_quant

_FORCE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def set_use_pallas(flag: bool) -> None:
    global _FORCE_PALLAS
    _FORCE_PALLAS = flag


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attn_backend() -> str:
    """Resolved flash-attention backend under the module dispatch policy:
    ``"pallas"`` (TPU, compiled), ``"pallas-interpret"`` (forced
    validation mode) or ``"jnp"`` (CPU default)."""
    if _on_tpu():
        return "pallas"
    if _FORCE_PALLAS:
        return "pallas-interpret"
    return "jnp"


def flash_attend(
    q: jax.Array,        # (B, T, Hq, dh) decode/verify query window
    k: jax.Array,        # (B, S, Hkv, dh) contiguous KV cache (bf16/f32/int8)
    v: jax.Array,        # (B, S, Hkv, dh)
    qpos: jax.Array,     # (B, T) int32 absolute query positions
    *,
    k_scale: jax.Array | None = None,     # (B, S, Hkv) int8-KV scales
    v_scale: jax.Array | None = None,
    tree_mask: jax.Array | None = None,   # (T, T) ancestor-or-self window mask
    win_start: jax.Array | None = None,   # (B,) first window slot
    block_s: int = 512,
    force: bool = False,
) -> jax.Array:
    """Verification attention over a *contiguous* cache (slot == position).

    Same policy as :func:`w8a8_matmul`: on TPU the Pallas ``flash_decode``
    kernel runs compiled (int8 K/V stream at 1 B/elem with the scales
    folded in-kernel); ``REPRO_USE_PALLAS=1`` / ``force=True`` runs the
    kernel in interpret mode for CPU validation; the CPU default is the
    pure-jnp ``attend`` path, which is numerically identical.
    """
    if _on_tpu():
        return flash_decode(q, k, v, qpos, k_scale=k_scale, v_scale=v_scale,
                            tree_mask=tree_mask, win_start=win_start,
                            block_s=block_s)
    if _FORCE_PALLAS or force:
        return flash_decode(q, k, v, qpos, k_scale=k_scale, v_scale=v_scale,
                            tree_mask=tree_mask, win_start=win_start,
                            block_s=block_s, interpret=True)
    from repro.models.attention import attend  # lazy: avoids import cycle

    return attend(q, k, v, qpos, jnp.arange(k.shape[1], dtype=jnp.int32),
                  k_scale=k_scale, v_scale=v_scale,
                  tree_mask=tree_mask, win_start=win_start, impl="jnp")


def flash_attend_paged(
    q: jax.Array,        # (B, T, Hq, dh) decode/verify query window
    k: jax.Array,        # (N, bs, Hkv, dh) physical K block pool
    v: jax.Array,        # (N, bs, Hkv, dh) physical V block pool
    bt: jax.Array,       # (B, nb) int32 block table (logical → physical)
    qpos: jax.Array,     # (B, T) int32 absolute query positions
    *,
    k_scale: jax.Array | None = None,     # (N, bs, Hkv) int8-KV scales
    v_scale: jax.Array | None = None,
    tree_mask: jax.Array | None = None,   # (T, T) ancestor-or-self mask
    win_start: jax.Array | None = None,   # (B,) first window slot
    force: bool = False,
) -> jax.Array:
    """Verification attention over a **paged** cache (block-table
    addressed; see ``repro.core.paged_cache``).

    Same dispatch policy as :func:`flash_attend`: TPU runs the Pallas
    ``flash_decode_paged`` kernel compiled (blocks stream from their
    pool homes via scalar-prefetched table lookups — no gather
    materialisation); ``REPRO_USE_PALLAS=1`` / ``force=True`` runs it in
    interpret mode; the CPU default gathers the logical view and runs
    the numerically identical jnp ``attend``.
    """
    if _on_tpu() or _FORCE_PALLAS or force:
        return flash_decode_paged(q, k, v, bt, qpos,
                                  k_scale=k_scale, v_scale=v_scale,
                                  tree_mask=tree_mask, win_start=win_start,
                                  interpret=not _on_tpu())
    from repro.models.attention import attend_paged  # lazy: avoids cycle

    # forced jnp: attend_paged's gather-the-logical-view oracle — the
    # single implementation of the paged fallback (no second copy that
    # could drift from the bit-equality guarantee)
    cache = {"k": k, "v": v}
    if k_scale is not None:
        cache["k_scale"], cache["v_scale"] = k_scale, v_scale
    return attend_paged(q, cache, bt, qpos, tree_mask=tree_mask,
                        win_start=win_start, impl="jnp")


def w8a8_matmul(
    x: jax.Array,         # (..., K) activations (bf16/f32)
    w_int8: jax.Array,    # (K, N) int8
    w_scale: jax.Array,   # (N,) f32
    smooth: jax.Array,    # (K,) f32
) -> jax.Array:
    """Quantized-verification linear (paper §3.3): smooth→quant→int8 GEMM→dequant."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w_int8.shape[1]
    x2 = x.reshape(-1, K)
    if _on_tpu():
        xq, dx = smooth_quant(x2, smooth)
        y = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=x.dtype)
    elif _FORCE_PALLAS:
        xq, dx = smooth_quant(x2, smooth, interpret=True)
        y = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=x.dtype, interpret=True)
    else:
        y = ref.w8a8_matmul_ref(x2, w_int8, w_scale, smooth, out_dtype=x.dtype)
    return y.reshape(*batch_shape, N)
