"""Public jit'd wrappers around the Pallas kernels, with an XLA fallback.

Dispatch policy:
  * on TPU backends the Pallas kernels run compiled;
  * on CPU (this container) the default is the XLA path, which is
    numerically identical (same int8 quantize semantics, exact int32 GEMM via
    ``dot_general(..., preferred_element_type=int32)``) and keeps the weight
    operand int8 in the HLO — so ``cost_analysis()`` sees the halved weight
    bytes exactly as the TPU kernel would;
  * ``REPRO_USE_PALLAS=1`` (or ``set_use_pallas(True)``) forces the Pallas
    kernels in ``interpret=True`` mode for validation.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.smooth_quant import smooth_quant

_FORCE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def set_use_pallas(flag: bool) -> None:
    global _FORCE_PALLAS
    _FORCE_PALLAS = flag


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def w8a8_matmul(
    x: jax.Array,         # (..., K) activations (bf16/f32)
    w_int8: jax.Array,    # (K, N) int8
    w_scale: jax.Array,   # (N,) f32
    smooth: jax.Array,    # (K,) f32
) -> jax.Array:
    """Quantized-verification linear (paper §3.3): smooth→quant→int8 GEMM→dequant."""
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    N = w_int8.shape[1]
    x2 = x.reshape(-1, K)
    if _on_tpu():
        xq, dx = smooth_quant(x2, smooth)
        y = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=x.dtype)
    elif _FORCE_PALLAS:
        xq, dx = smooth_quant(x2, smooth, interpret=True)
        y = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=x.dtype, interpret=True)
    else:
        y = ref.w8a8_matmul_ref(x2, w_int8, w_scale, smooth, out_dtype=x.dtype)
    return y.reshape(*batch_shape, N)
