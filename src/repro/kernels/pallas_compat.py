"""Version compatibility for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
kernels target the new name and fall back here on older releases.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
