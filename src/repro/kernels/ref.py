"""Pure-jnp oracles for the Pallas kernels.

These define the numerical semantics; the Pallas kernels (and the XLA
"simulated" fast path used on CPU) must match them bit-for-bit where
possible (integer GEMM is exact; only the final bf16 cast rounds).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

INT8_MAX = 127.0
EPS = 1e-8


def quantize_symmetric(x: jax.Array, axis: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric uniform quantization Q(x, Δ) (paper Eq. 6-7).

    Returns (int8 values, per-slice scale Δ) where Δ is reduced over ``axis``
    (kept as a squeezed array over the remaining dims).
    """
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=axis)
    scale = jnp.maximum(amax, EPS) / INT8_MAX
    q = jnp.clip(jnp.round(x32 / jnp.expand_dims(scale, axis)), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), scale


def smooth_quant_ref(x: jax.Array, smooth: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused online smoothing + per-token dynamic quantization (paper Eq. 9).

    x: (M, K) activations, smooth: (K,) per-channel factors s.
    Returns (x̂ int8 (M, K), Δx f32 (M,)).
    """
    xs = x.astype(jnp.float32) * smooth.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xs), axis=-1)
    dx = jnp.maximum(amax, EPS) / INT8_MAX
    q = jnp.clip(jnp.round(xs / dx[:, None]), -INT8_MAX, INT8_MAX)
    return q.astype(jnp.int8), dx


def int8_matmul_ref(
    x_int8: jax.Array,    # (M, K) int8
    w_int8: jax.Array,    # (K, N) int8
    dx: jax.Array,        # (M,) f32 per-token scale
    dw: jax.Array,        # (N,) f32 per-channel scale
    out_dtype=jnp.bfloat16,
) -> jax.Array:
    """INT8 GEMM with INT32 accumulation + fused dequant epilogue (Eq. 8/10)."""
    acc = jax.lax.dot_general(
        x_int8, w_int8,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * dx[:, None] * dw[None, :]
    return y.astype(out_dtype)


def w8a8_matmul_ref(
    x: jax.Array,         # (..., K) bf16/f32 activations
    w_int8: jax.Array,    # (K, N) int8 smoothed+quantized weights
    w_scale: jax.Array,   # (N,) f32 Δw
    smooth: jax.Array,    # (K,) f32 s
    out_dtype=None,
) -> jax.Array:
    """Full W8A8 verification linear: smooth+quantize x, int8 GEMM, dequant."""
    out_dtype = out_dtype or x.dtype
    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, dx = smooth_quant_ref(x2, smooth)
    y = int8_matmul_ref(xq, w_int8, dx, w_scale, out_dtype)
    return y.reshape(*batch_shape, w_int8.shape[1])
