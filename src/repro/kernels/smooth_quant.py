"""Pallas TPU kernel: fused online activation smoothing + dynamic per-token
INT8 quantization (paper Eq. 9, "Online Activation Smoothing and Quantization").

One HBM read of X (bf16) and one HBM write of X̂ (int8) + Δx (f32) — the
naive XLA composition (multiply, rowmax, divide, round, cast) otherwise
costs three round-trips.  Rows are tiled into VMEM blocks of ``block_m``;
the full K dimension of a row block is kept resident so the row-max and the
quantize happen in a single pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu  # noqa: F401
from repro.kernels.pallas_compat import CompilerParams

INT8_MAX = 127.0
EPS = 1e-8


def _kernel(x_ref, s_ref, q_ref, dx_ref):
    x = x_ref[...].astype(jnp.float32)          # (bm, K)
    s = s_ref[...].astype(jnp.float32)          # (1, K)
    xs = x * s
    amax = jnp.max(jnp.abs(xs), axis=-1, keepdims=True)      # (bm, 1)
    dx = jnp.maximum(amax, EPS) / INT8_MAX
    q = jnp.clip(jnp.round(xs / dx), -INT8_MAX, INT8_MAX)
    q_ref[...] = q.astype(jnp.int8)
    dx_ref[...] = dx


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def smooth_quant(
    x: jax.Array,       # (M, K) bf16/f32 activations
    smooth: jax.Array,  # (K,) f32 smoothing factors s
    *,
    block_m: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    M, K = x.shape
    bm = min(block_m, M)
    Mp = (-M) % bm + M
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    grid = (Mp // bm,)
    q, dx = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((1, K), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, K), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, K), jnp.int8),
            jax.ShapeDtypeStruct((Mp, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x, smooth[None, :])
    return q[:M], dx[:M, 0]
