import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS",
                     "--xla_backend_optimization_level=0")
)
# ^ MUST run before any jax import: jax locks the device count on first init.
#   The 512 placeholder host devices exist ONLY in this process; smoke tests
#   and benchmarks see the single real CPU device.

"""Multi-pod dry-run: lower + compile every (arch × input-shape) combination
for the production mesh and report roofline terms.

For each combination this lowers the *real* step function the framework
serves/trains with (scan layout — one HLO block per layer kind):

  train_4k     → ``train_step``   (CE + AdamW, remat, FSDP+TP sharding)
  prefill_32k  → ``prefill_step`` (prompt → KV/SSM cache, verifier params)
  decode_32k   → ``serve_step``   (one full speculative iteration: n-gram
  long_500k      draft + γ+1-token quantized verification + commit)

Usage:
  python -m repro.launch.dryrun --arch stablelm-12b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--verifier w8a8|bf16]
  python -m repro.launch.dryrun --all --both-meshes --out results/dryrun.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.core.spec_engine import make_decode_step
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    analyze,
    kv_cache_capacity_bytes,
    kv_cache_read_bytes,
    model_flops_decode,
    model_flops_train,
)
from repro.launch.sharding import (
    batch_shardings,
    param_shardings,
    replicated,
    state_shardings,
)
from repro.models import Model
from repro.quant import quantize_params
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def build_params(model, verifier: str, scan: bool):
    """Abstract (ShapeDtypeStruct) params — no allocation."""
    def make():
        p = model.init_params(jax.random.PRNGKey(0))
        if verifier == "w8a8":
            p = quantize_params(p, None, QuantConfig())
        elif verifier == "w4a8":
            p = quantize_params(p, None, QuantConfig(w_bits=4))
        return model.to_scan(p) if scan else p
    return jax.eval_shape(make)


def _build(cfg, model, kind, shape_name, mesh, verifier, scfg, scan: bool):
    """(jitted fn, args, model_flops) for one combo in one layout."""
    gamma = scfg.gamma
    if kind == "train":
        params = build_params(model, "bf16", scan)      # training is BF16
        opt = jax.eval_shape(adamw_init, params)
        batch = shp.train_specs(cfg, shape_name)
        psh = param_shardings(params, mesh, fsdp=("data",))
        osh = param_shardings(opt, mesh, fsdp=("data",))
        bsh = batch_shardings(batch, mesh)
        step = make_train_step(cfg, AdamWConfig(), remat=True, scan=scan)
        fn = jax.jit(step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, None))
        args = (params, opt, batch)
        tokens = batch["tokens"].shape[0] * batch["tokens"].shape[1]
        mflops = model_flops_train(cfg, tokens)
    elif kind == "prefill":
        params = build_params(model, verifier, scan)
        spec = shp.prefill_specs(cfg, shape_name, model, scan=scan)
        psh = param_shardings(params, mesh)
        csh = state_shardings({"cache": spec["cache"]}, mesh)["cache"]
        tsh = batch_shardings({"t": spec["tokens"]}, mesh)["t"]
        in_sh = [psh, csh, tsh]
        args = [params, spec["cache"], spec["tokens"]]
        if "aux_embeds" in spec:
            in_sh.append(batch_shardings({"a": spec["aux_embeds"]}, mesh)["a"])
            args.append(spec["aux_embeds"])

            def step(p, c, t, a):
                return model.prefill(p, c, t, aux_embeds=a)
        else:
            def step(p, c, t):
                return model.prefill(p, c, t)
        fn = jax.jit(step, in_shardings=tuple(in_sh), out_shardings=csh)
        args = tuple(args)
        tokens = spec["tokens"].shape[0] * spec["tokens"].shape[1]
        mflops = 2.0 * cfg.active_param_count() * tokens  # 2·N·D (forward)
    else:  # decode
        params = build_params(model, verifier, scan)
        state = shp.serve_state_specs(cfg, shape_name, model, scfg, scan=scan)
        psh = param_shardings(params, mesh)
        ssh = state_shardings(state, mesh)
        step = make_decode_step(model, scfg.drafter, verifier, scfg)
        fn = jax.jit(step, in_shardings=(psh, ssh), out_shardings=ssh)
        args = (params, state)
        tokens = state["tokens"].shape[0] * (gamma + 1)
        mflops = model_flops_decode(cfg, tokens)
    return fn, args, mflops


def lower_combo(arch: str, shape_name: str, mesh, verifier: str = "w8a8",
                gamma: int = 5, skip_loop_costs: bool = False,
                moe_mode: str = "gspmd", kv_cache: str = "bf16"):
    import dataclasses as _dc
    import math as _math

    from jax.sharding import PartitionSpec as P
    from repro.models import scan as scan_mod
    from repro.models.scan import scan_pattern

    # constrain scan-carry activations: batch on the data axes (replicated
    # when not divisible, e.g. long_500k B=1)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    B0 = shp.SHAPES[shape_name]["global_batch"]
    dp_ok = dp if B0 % _math.prod(mesh.shape[a] for a in dp) == 0 else None
    scan_mod.set_activation_spec(P(dp_ok, None, None))

    # expert-parallel dispatch buffer: E on "model" (falls back to replicated
    # inside apply_moe when E is indivisible — GSPMD handles either way)
    from repro.models import moe as moe_mod
    moe_mod.set_dispatch_spec(P("model", None, None))
    if moe_mode == "shardmap":
        moe_mod.set_shard_map(mesh, dp_ok or (), fsdp=True)
    else:
        moe_mod.set_shard_map(None, (), False)

    cfg = shp.shape_cfg(get_config(arch), shape_name)
    if kv_cache != "bf16":
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_cache)
    model = Model(cfg)
    kind = shp.SHAPES[shape_name]["kind"]
    chips = mesh.devices.size
    scfg = SpecConfig(gamma=gamma, temperature=0.0)
    _, n_groups, _ = scan_pattern(cfg)

    # 1) scan layout (production executable): compile gate + memory +
    #    per-device HLO for collective parsing
    fn, args, mflops = _build(cfg, model, kind, shape_name, mesh, verifier,
                              scfg, scan=True)
    t0 = time.time()
    lowered_scan = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered_scan.compile()
    t_compile = time.time() - t0

    # 2) loop layout (unrolled): global FLOPs/bytes that count every layer
    lowered_loop = None
    if not skip_loop_costs:
        fn_l, args_l, _ = _build(cfg, model, kind, shape_name, mesh, verifier,
                                 scfg, scan=False)
        lowered_loop = fn_l.lower(*args_l)

    mem = compiled.memory_analysis()
    kv_bytes = 0.0
    kv_capacity = {}
    if kind == "decode":
        # cache-read roofline term: the verify window streams the whole
        # committed context's K/V rows (sliding-window caps it at R slots)
        s = shp.SHAPES[shape_name]
        ctx = s["seq_len"]
        if cfg.sliding_window:
            ctx = min(ctx, cfg.sliding_window)
        kv_bytes = kv_cache_read_bytes(cfg, s["global_batch"], ctx)
        # footprint term: contiguous worst-case rows vs block-granular
        # paged at the same context (the mixed-length win is swept in
        # benchmarks/ablation_kv.py; here paged shows the block-rounding
        # overhead is noise even at homogeneous full context)
        demands = [ctx] * s["global_batch"]
        kv_capacity = {
            "kv_capacity_gbytes": round(kv_cache_capacity_bytes(
                cfg, demands, ctx, layout="contiguous") / 1e9, 6),
            "kv_capacity_paged_gbytes": round(kv_cache_capacity_bytes(
                cfg, demands, ctx, layout="paged") / 1e9, 6),
        }
    rf = analyze(lowered_loop, compiled, chips, n_groups, mflops,
                 kv_bytes=kv_bytes)
    row = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips,
        "verifier": verifier if kind != "train" else "bf16",
        "kind": kind,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "arg_bytes_per_dev": int(mem.argument_size_in_bytes),
        "temp_bytes_per_dev": int(mem.temp_size_in_bytes),
        "out_bytes_per_dev": int(mem.output_size_in_bytes),
        **{k: (round(v, 6) if isinstance(v, float) else v)
           for k, v in rf.row().items()},
        **kv_capacity,
        "coll_breakdown_gb": {k: round(v / 1e9, 3)
                              for k, v in rf.coll_breakdown.items()},
    }
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(shp.SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--verifier", default="w8a8",
                    choices=["w8a8", "w4a8", "bf16"])
    ap.add_argument("--gamma", type=int, default=5)
    ap.add_argument("--moe", default="gspmd", choices=["gspmd", "shardmap"])
    ap.add_argument("--kv-cache", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [c.name for c in ASSIGNED] if (args.all or not args.arch) else [args.arch]
    shapes = list(shp.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    rows, failures = [], []
    for mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch} × {shape_name} × {'x'.join(map(str, mesh.devices.shape))}"
                try:
                    with mesh:
                        row = lower_combo(arch, shape_name, mesh,
                                          args.verifier, args.gamma,
                                          moe_mode=args.moe,
                                          kv_cache=args.kv_cache)
                    row["moe_mode"] = args.moe
                    row["kv_cache"] = args.kv_cache
                    rows.append(row)
                    print(f"[ok] {tag}: dominant={row['dominant']} "
                          f"t_mem={row['t_memory_s']:.3e}s "
                          f"t_comp={row['t_compute_s']:.3e}s "
                          f"t_coll={row['t_collective_s']:.3e}s "
                          f"compile={row['compile_s']}s", flush=True)
                except Exception as e:
                    failures.append({"combo": tag, "error": f"{type(e).__name__}: {e}"})
                    print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
                    traceback.print_exc()

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
        print(f"wrote {len(rows)} rows ({len(failures)} failures) -> {args.out}")
    print(f"\n{len(rows)} ok / {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
