"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the 512 placeholder host
devices exist only when ``dryrun.py`` set ``XLA_FLAGS`` before any jax
import.
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-gated ``jax.make_mesh``: older jax releases have no
    ``jax.sharding.AxisType`` (and default to auto axes anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e pod slice); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh on the real local device — used by tests to exercise the
    sharding-rule code paths without placeholder devices."""
    return make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes used for batch/data parallelism (pod folds into data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
