"""Roofline-term derivation from the dry-run artifacts.

    compute term    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory term     = HLO_bytes   / (chips × HBM_bw)
    collective term = coll_bytes  / (chips × link_bw)

Sources — the dry-run produces two artifacts per combination:

* **loop-layout ``jax.jit(...).lower()``** (unrolled layers, no compile):
  ``lowered.cost_analysis()`` on the unpartitioned module gives *global*
  FLOPs / bytes that include every layer (XLA's HloCostAnalysis counts
  while-loop bodies once, so scanned-layer modules undercount by ~L —
  measured and avoided here).  Bytes are pre-fusion and therefore an
  overcount of true HBM traffic; they are consistent across configs, which
  is what the relative hillclimb comparisons need.  Divided by chip count.

* **scan-layout ``.compile()``** (the production executable): proves the
  mesh/sharding lowers, provides ``memory_analysis()`` (per-device bytes)
  and the per-device HLO text for collective parsing.  Collectives inside
  while-loop *body* computations are multiplied by the layer-scan trip
  count; payload = result-shape bytes × ring factor (all-reduce 2×,
  others 1×).

Hardware model (TPU v5e): 197 TFLOP/s bf16 (394 TOP/s int8) per chip,
819 GB/s HBM, ~50 GB/s per ICI link.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS_BF16 = 197e12
PEAK_FLOPS_INT8 = 394e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1,
}

_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)[.\d]*\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*)?\{\s*$")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, str]:
    comps: Dict[str, list] = {}
    current = None
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line.strip()) if line and not line.startswith(" ") else None
        if m:
            current = m.group(2)
            comps[current] = []
        elif current is not None:
            if line.strip() == "}":
                current = None
            else:
                comps[current].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


def collective_bytes(hlo_text: str, loop_trips: int = 1) -> Dict[str, float]:
    """Per-op-kind payload bytes (per-device program).  Collectives inside
    while-body computations count ``loop_trips`` times."""
    bodies = set(_BODY_RE.findall(hlo_text))
    comps = _split_computations(hlo_text)
    out: Dict[str, float] = {k: 0.0 for k in _COLL_FACTOR}
    for name, text in comps.items():
        mult = loop_trips if name in bodies else 1
        for m in _COLL_RE.finditer(text):
            shapes, op = m.group(1), m.group(2)
            out[op] += _shape_bytes(shapes) * _COLL_FACTOR[op] * mult
    return out


def attn_layer_count(cfg) -> int:
    """Decoder layers that read a self-attention KV cache at decode time
    (hybrid archs count their shared-attention applications via the
    census; recurrent-only layers hold no KV rows)."""
    n_self, _, _, _, _ = cfg._layer_census()
    return n_self


def kv_cache_read_bytes(cfg, batch: int, context: int,
                        kv_cache_dtype: str = None) -> float:
    """Modeled HBM bytes to stream the KV cache once for a decode/verify
    step at ``context`` committed tokens — the cache-read half of the
    Eq. 11-12 memory term (the other half is the weight bytes, which at
    32k context it exceeds).  ``int8`` halves the K/V payload and adds
    the per-(token, head) f32 ``k_scale``/``v_scale`` rows."""
    dt = kv_cache_dtype or getattr(cfg, "kv_cache_dtype", "bf16")
    per_token = kv_bytes_per_token(cfg, dt)
    return float(batch) * float(context) * attn_layer_count(cfg) * per_token


def kv_bytes_per_token(cfg, kv_cache_dtype: str = None) -> float:
    """HBM bytes one committed token's K+V rows occupy in **one** layer
    (int8 halves the payload and adds the per-(token, head) f32 scales)."""
    dt = kv_cache_dtype or getattr(cfg, "kv_cache_dtype", "bf16")
    if dt not in ("bf16", "int8"):
        raise ValueError(f"unmodeled kv cache dtype {dt!r}")
    elem = 1.0 if dt == "int8" else 2.0
    per_token = 2.0 * cfg.kv_dim * elem             # K + V rows, one layer
    if dt == "int8":
        per_token += 2.0 * cfg.num_kv_heads * 4.0   # k_scale + v_scale f32
    return per_token


def kv_cache_capacity_bytes(cfg, request_tokens, max_len: int,
                            kv_cache_dtype: str = None,
                            layout: str = "contiguous",
                            block_size: int = None,
                            shared_prefix_tokens: int = 0) -> float:
    """Modeled HBM *footprint* of the serving-group KV cache — the term
    the paged layout shrinks (where :func:`kv_cache_read_bytes` is the
    per-step *streaming* term int8 halves).

    ``request_tokens`` is the per-request worst-case row count, one
    entry per concurrently-resident request.  ``layout="contiguous"``
    charges every slot the group's ``max_len`` buffer (worst-case
    sizing: ``slots × max_len``); ``layout="paged"`` charges each
    request its own demand rounded up to ``block_size`` plus one
    scratch block and the int32 block tables — block-granular
    fragmentation instead of max-length fragmentation.

    ``shared_prefix_tokens`` models the prefix cache
    (``core/paged_cache.PrefixIndex``): every request shares that long
    a common prompt prefix, so its *full* blocks are stored once for
    the whole group instead of once per request (paged layout only —
    the contiguous layout cannot share rows and still charges every
    slot its full buffer).  The partially-filled boundary block stays
    per-request (copy-on-write forking makes it private).
    """
    from repro.core.paged_cache import DEFAULT_BLOCK_SIZE, blocks_for_tokens

    per_token = kv_bytes_per_token(cfg, kv_cache_dtype)
    layers = attn_layer_count(cfg)
    n = len(request_tokens)
    if layout == "contiguous":
        return float(n) * float(max_len) * layers * per_token
    if layout != "paged":
        raise ValueError(f"unknown kv layout {layout!r}")
    bs = DEFAULT_BLOCK_SIZE if block_size is None else block_size
    shared_full = max(int(shared_prefix_tokens), 0) // bs
    blocks = shared_full + sum(
        blocks_for_tokens(t - shared_full * bs, bs)
        for t in request_tokens) + 1
    table = n * blocks_for_tokens(max_len, bs) * 4.0     # int32 entries
    return float(blocks) * bs * layers * per_token + table


@dataclasses.dataclass
class Roofline:
    flops: float                 # global HLO flops (loop-layout lowering)
    bytes_accessed: float        # global HLO bytes (loop-layout lowering)
    coll_bytes: float            # per-chip collective payload bytes
    coll_breakdown: Dict[str, float]
    chips: int
    model_flops: float = 0.0     # analytic global 6·N·D (or 2·N·D decode)
    kv_bytes: float = 0.0        # analytic global KV-cache read bytes
    #                              (kv_cache_read_bytes; 0 for train/prefill)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_kv_memory(self) -> float:
        """KV-cache share of the memory term — the piece int8 KV halves."""
        return self.kv_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """model_flops / HLO_flops — catches remat / masked-attention /
        dispatch / drafting waste."""
        return self.model_flops / self.flops if self.flops else 0.0

    def row(self) -> dict:
        out = {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "hlo_gflops": self.flops / 1e9,
            "hlo_gbytes": self.bytes_accessed / 1e9,
            "coll_gbytes_per_chip": self.coll_bytes / 1e9,
            "useful_flops_ratio": self.useful_flops_ratio,
        }
        if self.kv_bytes:
            out["kv_gbytes"] = self.kv_bytes / 1e9
            out["t_kv_memory_s"] = self.t_kv_memory
            out["kv_share_of_memory"] = (self.kv_bytes / self.bytes_accessed
                                         if self.bytes_accessed else 0.0)
        return out


def analyze(lowered_loop, compiled_scan, chips: int, loop_trips: int,
            model_flops: float = 0.0, kv_bytes: float = 0.0) -> Roofline:
    ca = lowered_loop.cost_analysis() if lowered_loop is not None else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    try:
        hlo = compiled_scan.as_text()
    except Exception:
        hlo = ""
    breakdown = collective_bytes(hlo, loop_trips)
    return Roofline(
        flops=flops,
        bytes_accessed=byts,
        coll_bytes=sum(breakdown.values()),
        coll_breakdown=breakdown,
        chips=chips,
        model_flops=model_flops,
        kv_bytes=kv_bytes,
    )


def model_flops_train(cfg, tokens: int) -> float:
    return 6.0 * cfg.active_param_count() * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    return 2.0 * cfg.active_param_count() * tokens
