"""Serving launcher.

On a TPU slice this builds the production mesh, shards the (quantized)
params and engine state with the same rules the dry-run validated, and
runs the speculative serving loop.  On CPU (this container) pass
``--reduced`` to demo the identical code path at smoke scale.

Drafting and verification are registry plugins: ``--drafter`` /
``--verifier`` name any registered implementation, and the engine applies
the verifier's offline weight preparation itself — ``--verifier w8a8``
alone serves quantized verification from a BF16 checkpoint.

  python -m repro.launch.serve --arch smollm-135m --reduced \
      --verifier w8a8 --gamma 5 --batch 4 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.core.protocols import available_drafters, available_verifiers
from repro.data import task_prompts
from repro.models import Model
from repro.serving.engine import LEGACY_MODES, SpecEngine
from repro.train.checkpoint import load_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--verifier", default="w8a8",
                    choices=list(available_verifiers()))
    ap.add_argument("--kv-cache", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--kv-layout", default="contiguous",
                    choices=["contiguous", "paged"],
                    help="serving-path cache layout; 'paged' routes the "
                         "batch through the continuous-batching scheduler "
                         "with block-granular KV allocation "
                         "(core/paged_cache.py); solo generate stays "
                         "contiguous")
    ap.add_argument("--kv-block-size", type=int, default=128,
                    help="tokens per paged KV block (--kv-layout paged)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="--kv-layout paged: disable the prefix cache "
                         "(refcounted block sharing of common prompt "
                         "prefixes + copy-on-write boundary forking)")
    ap.add_argument("--no-preempt", action="store_true",
                    help="--kv-layout paged: never evict running slots "
                         "to the host swap pool; denied admissions wait "
                         "for capacity instead")
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="decode/verify attention path: auto = Pallas "
                         "flash-decode kernel on TPU (interpret under "
                         "REPRO_USE_PALLAS=1) else jnp; pallas/jnp force "
                         "one side")
    ap.add_argument("--drafter", default=None,
                    choices=list(available_drafters()))
    ap.add_argument("--mode", default=None, choices=list(LEGACY_MODES),
                    help="deprecated alias: spec|vanilla|pruned -> --drafter")
    ap.add_argument("--gamma", type=int, default=None,
                    help="draft length (default 5); with --tree-branches "
                         "the template fixes the draft length instead")
    ap.add_argument("--tree-branches", default=None,
                    help="comma-separated per-depth branch factors for "
                         "tree drafters, e.g. '3,2,1,1' (--drafter "
                         "ngram-tree); default: the (1,)*gamma chain")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--task", default="gsm8k")
    ap.add_argument("--ckpt", default=None, help="checkpoint (.npz) to serve")
    ap.add_argument("--serve", action="store_true",
                    help="async front-end demo: run a StreamingServer on "
                         "the wall clock, submit a Poisson arrival stream, "
                         "stream tokens per request, print the metrics "
                         "summary (repro.serving.server)")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of requests in the --serve arrival stream")
    ap.add_argument("--arrival-rate", type=float, default=4.0,
                    help="--serve Poisson arrival rate (requests/s)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="--serve per-request SLO deadline in seconds "
                         "(default: no deadline)")
    ap.add_argument("--admission", default="edf", choices=["fifo", "edf"],
                    help="--serve admission policy within priority class")
    ap.add_argument("--no-shed", action="store_true",
                    help="--serve: keep past-deadline queued work instead "
                         "of shedding it")
    ap.add_argument("--request-timeout", type=float, default=None,
                    metavar="S",
                    help="--serve: fail any request older end-to-end than "
                         "S seconds (RequestTimeout) — turns a hung lane "
                         "into per-request failures, never blocked callers")
    ap.add_argument("--collapse-window", type=int, default=0,
                    metavar="N",
                    help="--serve: acceptance-collapse detector window (N "
                         "decode steps; 0 = off).  A quantized-verifier "
                         "lane whose mean acceptance sits below "
                         "--collapse-threshold for a full window is "
                         "re-prepared (re-quantized) — docs/robustness.md")
    ap.add_argument("--collapse-threshold", type=float, default=0.05,
                    metavar="T",
                    help="--serve: mean accepted tokens per row-step below "
                         "which the collapse detector trips")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="--serve: inject faults from a seeded FaultPlan "
                         "spec (seam@i / seam~p, comma-separated, e.g. "
                         "'step@3,alloc~0.05') to rehearse containment — "
                         "see repro.serving.faults")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for --fault-plan")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome trace-event / Perfetto JSON of "
                         "the run (request lifecycle, scheduler ticks, "
                         "decode/prefill/swap spans); open at "
                         "https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics summary (latency, acceptance, "
                         "kv_cache sections) as JSON")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="write the metrics in Prometheus text exposition "
                         "format (ServerMetrics.expose_text)")
    args = ap.parse_args()

    import dataclasses

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.kv_cache != "bf16":
        cfg = dataclasses.replace(cfg, kv_cache_dtype=args.kv_cache)
    if args.attn_impl != "auto":
        cfg = dataclasses.replace(cfg, attn_impl=args.attn_impl)
    model = Model(cfg)

    if args.ckpt:
        params = load_checkpoint(args.ckpt)
        params = params.get("params", params)
    else:
        print("no --ckpt: serving random-init weights (demo)")
        params = model.init_params(jax.random.PRNGKey(0))

    branches = (tuple(int(b) for b in args.tree_branches.split(","))
                if args.tree_branches else None)
    # --tree-branches implies the tree drafter; reject combinations that
    # would silently ignore the template
    default_drafter = "ngram-tree" if branches is not None else "ngram"
    drafter = args.drafter or LEGACY_MODES.get(args.mode) or default_drafter
    if branches is not None:
        if args.gamma is not None:
            ap.error("--gamma conflicts with --tree-branches: the template "
                     "fixes the draft length (nodes - 1)")
        if drafter != "ngram-tree":
            ap.error(f"--tree-branches is only read by tree drafters; "
                     f"drafter {drafter!r} would silently ignore it")
    scfg = SpecConfig(gamma=args.gamma if args.gamma is not None else 5,
                      temperature=args.temperature,
                      k_min=1, k_max=4, drafter=drafter,
                      verifier=args.verifier, tree_branches=branches,
                      kv_layout=args.kv_layout,
                      kv_block_size=args.kv_block_size,
                      kv_prefix_sharing=not args.no_prefix_sharing,
                      kv_preempt=not args.no_preempt)
    # the engine's verifier quantizes internally when scfg.verifier demands it
    engine = SpecEngine(model, scfg)
    prompts = jnp.asarray(task_prompts(
        args.task, args.batch, args.prompt_len, cfg.vocab_size))
    from repro.kernels.ops import attn_backend
    attn_path = cfg.attn_impl if cfg.attn_impl != "auto" else attn_backend()
    print(f"arch={cfg.name} verifier={engine.verifier.name} "
          f"drafter={engine.drafter.name} kv_cache={cfg.kv_cache_dtype} "
          f"kv_layout={args.kv_layout} attn={attn_path}")

    import json

    from repro.serving.trace import Tracer
    tracer = Tracer() if args.trace_out else None

    def dump_observability(metrics=None):
        """Write --trace-out / --metrics-out / --prom-out artifacts."""
        if tracer is not None:
            tracer.save(args.trace_out)
            print(f"trace: {args.trace_out} "
                  f"({len(tracer.events)} events)")
        if args.metrics_out:
            if metrics is not None:
                payload = metrics.summary()
            else:  # batch path: engine-level telemetry only
                payload = {"acceptance": engine.telemetry.summary()}
            with open(args.metrics_out, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"metrics: {args.metrics_out}")
        if args.prom_out:
            if metrics is None:
                print("--prom-out needs --serve (ServerMetrics); skipped")
            else:
                with open(args.prom_out, "w") as f:
                    f.write(metrics.expose_text())
                print(f"prometheus: {args.prom_out}")

    if args.serve:
        import numpy as np

        from repro.serving import FaultPlan, GenerationRequest, \
            ServerConfig, StreamingServer
        cfg_srv = ServerConfig(
            batch_slots=args.batch,
            max_prompt_len=args.prompt_len,
            max_new_tokens=args.new_tokens,
            admission=args.admission,
            shed_late=not args.no_shed,
            request_timeout_s=args.request_timeout,
            collapse_window=args.collapse_window,
            collapse_threshold=args.collapse_threshold,
        )
        faults = (FaultPlan.parse(args.fault_plan, seed=args.fault_seed)
                  if args.fault_plan else None)
        rng = np.random.default_rng(0)
        gaps = rng.exponential(1.0 / max(args.arrival_rate, 1e-9),
                               size=args.requests)
        pool = np.asarray(prompts)
        t0 = time.perf_counter()
        with StreamingServer(engine, params, cfg_srv, tracer=tracer,
                             faults=faults) as srv:
            handles = []
            for i in range(args.requests):
                time.sleep(gaps[i])
                h = srv.submit(GenerationRequest(
                    pool[i % len(pool)], args.new_tokens, seed=i,
                    deadline_s=args.deadline))
                handles.append(h)
            for h in handles:
                toks = list(h.tokens())       # blocking per-token stream
                try:
                    res = h.result(timeout=60.0)
                except Exception as exc:      # failed request: contained
                    res = None
                    print(f"req {h.rid}: failed "
                          f"({type(exc).__name__}: {exc})")
                else:
                    print(f"req {h.rid}: {h.status}, {len(toks)} chunks, "
                          f"{res.new_tokens if res else 0} tokens")
            summary = srv.loop.metrics.summary()
        wall = time.perf_counter() - t0
        srv.loop.metrics.check_conservation()
        dump_observability(metrics=srv.loop.metrics)
        c = summary["counters"]
        lat = summary["latency"]
        print(f"served {c['completed']}/{c['submitted']} "
              f"(shed {c['shed']}, failed {c['failed']}) "
              f"in {wall:.2f}s wall")
        rb = {k: v for k, v in summary["robustness"].items() if v}
        if rb:
            print("robustness: "
                  + ", ".join(f"{k}={v}" for k, v in sorted(rb.items())))
        ttft, itl = lat["ttft_s"], lat["itl_s"]
        if ttft.get("n"):
            print(f"ttft p50={ttft['p50']:.3f}s p99={ttft['p99']:.3f}s  "
                  f"itl p50={itl.get('p50', float('nan')):.4f}s")
        if summary["deadlines"]["with_deadline"]:
            print(f"deadline hit-rate: "
                  f"{summary['deadlines']['hit_rate']:.3f}")
        return
    if args.kv_layout == "paged":
        # paged is a serving-path layout: route the batch through the
        # continuous-batching scheduler as per-request generations
        import numpy as np

        from repro.serving import GenerationRequest
        reqs = [GenerationRequest(np.asarray(p), args.new_tokens, seed=i)
                for i, p in enumerate(np.asarray(prompts))]
        t0 = time.perf_counter()
        out = engine.generate_requests(params, reqs, tracer=tracer)
        wall = time.perf_counter() - t0
        new_tokens = sum(r.new_tokens for r in out)
        L = sum(r.accept_len for r in out) / len(out)
        steps = max(r.steps for r in out)
        print(f"generated {new_tokens} tokens in {wall:.2f}s "
              f"({new_tokens / max(wall, 1e-9):.1f} tok/s CPU)")
        print(f"verify steps={steps}  mean acceptance length L={L:.3f}")
        dump_observability()
        return
    r = engine.generate(params, prompts, args.new_tokens)
    print(f"generated {r.new_tokens} tokens in {r.wall_s:.2f}s "
          f"({r.tokens_per_s:.1f} tok/s CPU)")
    print(f"verify steps={r.steps}  mean acceptance length L={r.mean_accept_len:.3f}")
    dump_observability()


if __name__ == "__main__":
    main()
