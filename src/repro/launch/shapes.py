"""The four assigned input shapes and per-(arch × shape) input specs.

Decode shapes lower ``serve_step`` — one speculative iteration (γ+1-token
verify window) against a KV cache of ``seq_len`` — per the assignment.
``long_500k`` switches full-attention archs to the sliding-window variant
(window 4096), which is a first-class config flag; SSM archs need nothing.

Everything here returns ``jax.ShapeDtypeStruct`` stand-ins — no allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import ModelConfig, SpecConfig

LONG_WINDOW = 4096

SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}


def shape_cfg(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Arch config adapted to the input shape (sliding window for 500k)."""
    if shape_name == "long_500k" and cfg.arch_type != "ssm" and cfg.num_heads:
        if cfg.sliding_window is None or cfg.sliding_window > LONG_WINDOW:
            return dataclasses.replace(cfg, sliding_window=LONG_WINDOW)
    return cfg


def _aux_spec(cfg: ModelConfig, batch: int) -> Optional[jax.ShapeDtypeStruct]:
    """Modality-frontend stubs: precomputed patch/frame embeddings."""
    n = cfg.num_image_tokens or cfg.num_audio_frames
    if not n:
        return None
    return jax.ShapeDtypeStruct((batch, n, cfg.d_model), cfg.dtype)


def train_specs(cfg: ModelConfig, shape_name: str) -> dict:
    s = SHAPES[shape_name]
    B, T = s["global_batch"], s["seq_len"]
    batch = {
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, T), jnp.int32),
    }
    aux = _aux_spec(cfg, B)
    if aux is not None:
        batch["aux_embeds"] = aux
    return batch


def prefill_specs(cfg: ModelConfig, shape_name: str, model, scan: bool = True) -> dict:
    s = SHAPES[shape_name]
    B, T = s["global_batch"], s["seq_len"]
    cache = jax.eval_shape(lambda: model.init_cache(B, T + 256, scan=scan))
    out = {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((B, T), jnp.int32),
    }
    aux = _aux_spec(cfg, B)
    if aux is not None:
        out["aux_embeds"] = aux
    return out


def serve_state_specs(cfg: ModelConfig, shape_name: str, model, scfg: SpecConfig,
                      scan: bool = True) -> dict:
    """Engine state for one speculative serve step at this decode shape."""
    from repro.core.spec_engine import init_state

    s = SHAPES[shape_name]
    B, S = s["global_batch"], s["seq_len"]
    buf = S + scfg.gamma + 130  # committed context + speculative slack
    # eval_shape the engine's own init_state so the schema (drafter_state,
    # target, stats, …) has exactly one source of truth
    state = jax.eval_shape(
        lambda: init_state(model, B, buf, jax.random.PRNGKey(0), scan=scan,
                           target=jnp.zeros((B,), jnp.int32))
    )
    return state
