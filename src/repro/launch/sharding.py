"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every rule checks that the tensor dim divides the mesh-axis product before
sharding it; otherwise the dim is replicated.  This transparently handles
the awkward assigned shapes (smollm's 9 heads, whisper's 51865 vocab,
mamba2's 50280 vocab) without per-arch special cases.

Param layout conventions (see models/*):
  column-parallel (out-dim on "model"):  attn q/k/v, ffn up/gate, ssm in_proj
  row-parallel    (in-dim on "model"):   attn o, ffn down, ssm out_proj
  expert-parallel ("model" on E):        moe up/gate/down  (E, din, dout)
  vocab-parallel  ("model" on V):        embed, lm_head out-dim
  FSDP (optional, train):                remaining large dim over data axes

W8A8 tensors shard exactly like their BF16 counterparts: ``w_int8`` follows
``w``; ``w_scale`` follows the weight's out dim; ``smooth`` is replicated.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axsize(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return math.prod(mesh.shape[a] for a in axes)


def _fit(mesh: Mesh, dim: int, axes):
    """Return ``axes`` if dim divides their product, else None (replicate).
    Single-axis tuples are unwrapped to the bare axis name so specs
    compare equal regardless of how callers spell the axis."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    sz = _axsize(mesh, axes)
    if not (sz > 1 and dim % sz == 0):
        return None
    return axes[0] if len(axes) == 1 else axes


def _col(mesh, shape, fsdp):
    """(din, dout) column-parallel: out on model, din on fsdp."""
    return P(_fit(mesh, shape[0], fsdp), _fit(mesh, shape[1], "model"))


def _row(mesh, shape, fsdp):
    """(din, dout) row-parallel: din on model, out on fsdp."""
    return P(_fit(mesh, shape[0], "model"), _fit(mesh, shape[1], fsdp))


def _expert(mesh, shape, fsdp):
    """(E, din, dout): experts on model, din on fsdp."""
    e = _fit(mesh, shape[0], "model")
    if e is None:  # few experts: fall back to tensor-parallel inside experts
        return P(None, None, _fit(mesh, shape[2], "model"))
    return P(e, _fit(mesh, shape[1], fsdp), None)


# path fragments → rule; order matters (first match wins)
_COLUMN = ("/q/", "/k/", "/v/", "gate/", "up/", "in_proj", "router")
_ROW = ("/o/", "down/", "out_proj")


def _param_spec(path: str, shape, mesh: Mesh, fsdp) -> P:
    nd = len(shape)
    path = path + "/"
    if "scan/" in path and nd >= 1:
        # stacked layer-group leaf: leading L dim replicated, inner rule applies
        inner = _param_spec(path.replace("scan/", "layers/"), shape[1:], mesh, fsdp)
        return P(None, *inner)
    if nd == 0:
        return P()
    if nd == 1:
        # bias/scale vectors: shard only column-parallel outputs
        if any(t in path for t in _COLUMN) and ("/b/" in path or "w_scale" in path):
            return P(_fit(mesh, shape[0], "model"))
        return P()
    if "embed" in path:
        v = _fit(mesh, shape[0], "model")
        return P(v, _fit(mesh, shape[1], fsdp if v else "model"))
    if "lm_head" in path:
        return _col(mesh, shape, fsdp)
    if nd == 3 and ("moe" in path or shape[0] <= 256 and ("up/" in path or "gate/" in path or "down/" in path)):
        if "w_scale" in path:  # (E, dout)
            return P(_fit(mesh, shape[0], "model"), None)
        return _expert(mesh, shape, fsdp)
    if nd == 2 and "w_scale" in path:
        return P(_fit(mesh, shape[0], "model"), None)
    if "conv_w" in path:
        return P(None, _fit(mesh, shape[1], "model"))
    if any(t in path for t in _ROW):
        return _row(mesh, shape, fsdp)
    if any(t in path for t in _COLUMN):
        return _col(mesh, shape, fsdp)
    return P()


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for kp, leaf in flat:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        paths.append(("/".join(parts), leaf))
    return paths, treedef


def param_shardings(params, mesh: Mesh, fsdp: Optional[tuple] = None):
    """Pytree of NamedSharding matching ``params`` (arrays or structs)."""
    flat, treedef = _tree_paths(params)
    specs = [
        NamedSharding(mesh, _param_spec(path, np.shape(leaf), mesh, fsdp))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Activation / engine-state shardings
# ---------------------------------------------------------------------------

def _state_spec(path: str, shape, mesh: Mesh, dp) -> P:
    nd = len(shape)
    import re as _re
    stacked = "scan/" in path or (
        "shared/" in path and not _re.search(r"shared/\d+/", path + "/")
    )
    if stacked and nd >= 1:
        # stacked per-layer cache: leading L dim replicated
        inner = _state_spec(
            path.replace("scan/", "st/").replace("shared/", "st/"),
            shape[1:], mesh, dp,
        )
        return P(None, *inner)
    b = _fit(mesh, shape[0], dp) if nd >= 1 else None
    if nd == 0:
        return P()
    if "states_all" in path:                  # (B, T, H, P, N)
        return P(b, None, _fit(mesh, shape[2], "model"), None, None)
    # SSD state leaf only — "drafter_state/…" prefixes must not match
    if (path.endswith("/state") or path == "state") and nd == 4:
        return P(b, _fit(mesh, shape[1], "model"), None, None)
    if "conv" in path and nd == 3:            # (B, K-1, convdim)
        return P(b, None, _fit(mesh, shape[2], "model"))
    if nd == 4:                               # KV cache (B, S, Hkv, dh)
        h = _fit(mesh, shape[2], "model")
        d = None if h else _fit(mesh, shape[3], "model")
        return P(b, None, h, d)
    if nd == 3:                               # embeddings (B, S, D)
        return P(b, None, _fit(mesh, shape[2], "model"))
    if nd == 2:                               # tokens/kpos (B, S)
        return P(b, None)
    if nd == 1 and shape[0] > 2:              # length/commits (B,)
        return P(b)
    return P()


def state_shardings(state, mesh: Mesh):
    """Shardings for the serve-engine state pytree (tokens/length/cache/…)."""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    flat, treedef = _tree_paths(state)
    specs = [
        NamedSharding(mesh, _state_spec(path, np.shape(leaf), mesh, dp))
        for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_shardings(batch, mesh: Mesh):
    """{"tokens": (B,T), "labels": (B,T) [, "aux_embeds": (B,S,D)]}"""
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    flat, treedef = _tree_paths(batch)
    specs = []
    for path, leaf in flat:
        shape = np.shape(leaf)
        b = _fit(mesh, shape[0], dp)
        specs.append(NamedSharding(mesh, P(b, *([None] * (len(shape) - 1)))))
    return jax.tree_util.tree_unflatten(treedef, specs)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
