"""Training launcher.

On a TPU slice this builds the production mesh, shards params/optimizer
with the FSDP+TP rules the dry-run validated, and runs the training loop.
On CPU pass ``--reduced`` to run the identical code path at smoke scale
(single-device mesh).

  python -m repro.launch.train --arch smollm-135m --reduced --steps 100
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import Model
from repro.train.checkpoint import save_checkpoint
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--scan", action="store_true",
                    help="scanned-layer layout (production; default for >8 layers)")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    use_scan = args.scan or cfg.num_layers > 8
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"layout={'scan' if use_scan else 'loop'} devices={jax.device_count()}")

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)
    params = model.init_params(jax.random.PRNGKey(0))
    if use_scan:
        params = model.to_scan(params)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, opt_cfg, remat=True, scan=use_scan))

    data = lm_batches(args.batch, args.seq_len, cfg.vocab_size, seed=0)
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, opt, m = step(params, opt, batch)
        if (i + 1) % 10 == 0 or i == 0:
            print(f"step {i+1:5d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params, "step": args.steps})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
