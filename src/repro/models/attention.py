"""GQA self-attention and cross-attention with KV caches.

Supports the four execution modes the framework needs:

* **train / full forward** — no cache, causal (optionally sliding-window)
  mask, memory-efficient chunked online-softmax path for long sequences;
* **prefill** — same math, but K/V (+ absolute positions) are scattered
  into the cache buffers;
* **decode / verify** — a T-token window (T = 1 or γ+1) is written into the
  cache at per-row offsets and queries attend over the whole buffer with a
  position mask (so speculative rollback is free: uncommitted slots carry
  future positions and are masked until rewritten);
* **cross-attention** — K/V come from encoder / image embeddings (cached at
  prefill), no causal mask, no RoPE.

Cache layouts (per layer):
  contiguous: ``{"k","v": (B, S_max, Hkv, dh)}`` — slot index == absolute
  position.
  ring (sliding window): same buffers of size ``window + PAD`` plus a
  ``"kpos": (B, R)`` int32 buffer holding each slot's absolute position
  (init ``-2^30`` = invalid).  PAD > γ_max guarantees a speculative window
  never evicts keys that could still be needed after a partial rollback.
  paged (serving path): physical block pools ``{"k","v":
  (num_blocks, block_size, Hkv, dh)}`` shared by every batch row, plus a
  ``(B, max_blocks)`` int32 block table mapping logical block
  ``slot // block_size`` to its physical home (``repro.core.paged_cache``;
  ``self_attention(block_tables=...)`` selects it).  Logical semantics are
  identical to contiguous — reads gather (or kernel-stream) through the
  table, so paged attention is bit-identical to contiguous attention.

``kv_cache_dtype="int8"`` stores any layout's K/V int8 with
per-(token, head) f32 scales folded into scores/probs exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope
from repro.models.linear import apply_linear, init_linear
from repro.quant.smoothquant import record_act_stats

RING_PAD = 128          # > γ_max; also keeps buffer sizes 128-aligned
NEG_POS = -(2 ** 30)    # "invalid slot" position marker
MASK_VAL = -1e30
CHUNK_THRESHOLD = 4096  # use the online-softmax path beyond this many keys
KV_CHUNK = 1024


def init_attention(key, cfg, cross: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    D = cfg.d_model
    b = cfg.attn_bias or cfg.ffn_bias
    return {
        "q": init_linear(kq, D, cfg.q_dim, b, cfg.dtype),
        "k": init_linear(kk, D, cfg.kv_dim, b, cfg.dtype),
        "v": init_linear(kv, D, cfg.kv_dim, b, cfg.dtype),
        "o": init_linear(ko, cfg.q_dim, D, cfg.ffn_bias, cfg.dtype),
    }


def init_attn_cache(cfg, batch: int, max_len: int, window=None) -> dict:
    int8 = getattr(cfg, "kv_cache_dtype", "bf16") == "int8"
    dt = jnp.int8 if int8 else cfg.dtype
    S = min(window + RING_PAD, max_len + RING_PAD) if window is not None else max_len
    cache = {
        "k": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, S, cfg.num_kv_heads, cfg.head_dim), dt),
    }
    if int8:
        # per-(token, head) symmetric scales, folded into scores/probs
        cache["k_scale"] = jnp.zeros((batch, S, cfg.num_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, S, cfg.num_kv_heads), jnp.float32)
    if window is not None:
        cache["kpos"] = jnp.full((batch, S), NEG_POS, jnp.int32)
    return cache


def _lin(p, x, collect, path):
    if collect is not None:
        record_act_stats(collect, path, x)
    return apply_linear(p, x)


# ---------------------------------------------------------------------------
# Core attend: q (B,T,Hq,dh) over k/v (B,S,Hkv,dh) with position mask
# ---------------------------------------------------------------------------

def _mask(qpos, kpos, window, causal, tree_mask=None, win_start=None):
    # qpos (B,T) ; kpos (B,S) or (S,) -> (B,1,1,T,S) bool
    if kpos.ndim == 1:
        kpos = kpos[None, :]
    d = qpos[:, :, None] - kpos[:, None, :]
    if causal:
        valid = d >= 0
        if window is not None:
            valid &= d < window
    else:
        valid = kpos[:, None, :] >= 0  # cross-attn: all real slots valid
        valid = jnp.broadcast_to(valid, (qpos.shape[0], qpos.shape[1], kpos.shape[-1]))
    if tree_mask is not None:
        # Token-tree verify window: the T window tokens sit at cache
        # slots [win_start, win_start + T) in *packed node order* while
        # their positions are win_start + depth (siblings share one).
        # Within that slot range position causality is meaningless, so
        # those columns are overridden by the template's ancestor-or-self
        # mask; committed context (kpos < win_start) keeps the positional
        # rule, and junk slots beyond the window (kpos >= win_start + T >
        # max qpos) stay masked by it.
        T = tree_mask.shape[0]
        kpos_b = jnp.broadcast_to(kpos, (qpos.shape[0], kpos.shape[-1]))
        rel = kpos_b - win_start[:, None]                        # (B, S)
        in_win = (rel >= 0) & (rel < T)
        anc = jnp.moveaxis(
            jnp.take(tree_mask, jnp.clip(rel, 0, T - 1), axis=1), 0, 1)
        valid = jnp.where(in_win[:, None, :], anc, valid)        # (B, T, S)
    return valid[:, None, None, :, :]


def _attend_direct(q, k, v, valid, k_scale=None, v_scale=None):
    B, T, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, T, Hkv, G, dh)
    s = jnp.einsum("btkgh,bskh->bkgts", qg.astype(jnp.float32), k.astype(jnp.float32))
    if k_scale is not None:  # int8 KV: per-(token, head) scale folded into scores
        s = s * jnp.moveaxis(k_scale, 1, 2)[:, :, None, None, :]
    s = s * (dh ** -0.5)
    s = jnp.where(valid, s, MASK_VAL)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:  # fold value scale into the probabilities
        p = p * jnp.moveaxis(v_scale, 1, 2)[:, :, None, None, :]
    o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, dh).astype(q.dtype)


def _attend_chunked(q, k, v, valid, k_scale=None, v_scale=None):
    """Online-softmax (flash-style) over KV chunks via lax.scan.

    Keeps peak live memory at O(B·H·T·C) per step instead of O(B·H·T·S).
    This is the XLA-level flash attention for long sequences on the jnp
    path — flash-eligible decode/verify reads dispatch to the Pallas
    ``flash_decode`` kernel instead (see :func:`attend`), so this covers
    the ineligible shapes (ring buffers, train/prefill) and the CPU
    default backend.
    """
    B, T, Hq, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    C = KV_CHUNK
    nc = S // C
    assert S % C == 0, (S, C)
    qg = q.reshape(B, T, Hkv, G, dh).astype(jnp.float32)
    scale = dh ** -0.5

    kc = jnp.moveaxis(k.reshape(B, nc, C, Hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nc, C, Hkv, dh), 1, 0)
    validc = jnp.moveaxis(valid.reshape(B, 1, 1, T, nc, C), 4, 0)
    ksc = (jnp.moveaxis(k_scale.reshape(B, nc, C, Hkv), 1, 0)
           if k_scale is not None else jnp.zeros((nc, 0)))
    vsc = (jnp.moveaxis(v_scale.reshape(B, nc, C, Hkv), 1, 0)
           if v_scale is not None else jnp.zeros((nc, 0)))

    def step(carry, inp):
        m, l, acc = carry
        k_i, v_i, valid_i, ks_i, vs_i = inp
        s = jnp.einsum("btkgh,bskh->bkgts", qg, k_i.astype(jnp.float32)) * scale
        if k_scale is not None:
            s = s * jnp.moveaxis(ks_i, 1, 2)[:, :, None, None, :]
        s = jnp.where(valid_i.reshape(B, 1, 1, T, C), s, MASK_VAL)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid_i.reshape(B, 1, 1, T, C), p, 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        if v_scale is not None:
            p = p * jnp.moveaxis(vs_i, 1, 2)[:, :, None, None, :]
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p, v_i.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, T), MASK_VAL, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, T), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, T, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, validc, ksc, vsc))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.moveaxis(o, 3, 1).reshape(B, T, Hq, dh)
    return o.astype(q.dtype)


def attend_paged(q, cache, bt, qpos, *, tree_mask=None, win_start=None,
                 impl=None):
    """Position-masked attention over a **paged** cache layer.

    ``cache`` holds per-layer physical pools ``k``/``v`` of shape
    ``(num_blocks, block_size, Hkv, dh)`` (+ int8 ``k_scale``/``v_scale``
    pools) and ``bt`` is the ``(B, max_blocks)`` block table (see
    ``repro.core.paged_cache``).  Dispatch mirrors :func:`attend`: the
    flash-eligible shape (causal decode/verify, optional tree window)
    routes to the Pallas ``flash_decode_paged`` kernel, which streams
    physical blocks via the block table without materialising the
    logical view; the jnp path gathers the logical ``(B, S_log, ...)``
    cache and runs the exact contiguous ``attend`` math — paged reads
    are bit-identical to contiguous reads by construction.
    """
    mode = impl or "auto"
    if mode not in ("auto", "jnp", "pallas"):
        raise ValueError(f"unknown attn impl {mode!r}")
    k_scale, v_scale = cache.get("k_scale"), cache.get("v_scale")
    if mode != "jnp":
        from repro.kernels import ops  # lazy: kernels must not pull models

        if mode == "pallas" or ops.attn_backend() != "jnp":
            return ops.flash_attend_paged(
                q, cache["k"], cache["v"], bt, qpos,
                k_scale=k_scale, v_scale=v_scale,
                tree_mask=tree_mask, win_start=win_start,
                force=mode == "pallas")
    from repro.core.paged_cache import gather_block_rows

    k = gather_block_rows(cache["k"], bt)
    v = gather_block_rows(cache["v"], bt)
    ks = gather_block_rows(k_scale, bt) if k_scale is not None else None
    vs = gather_block_rows(v_scale, bt) if v_scale is not None else None
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    return attend(q, k, v, qpos, kpos, k_scale=ks, v_scale=vs,
                  tree_mask=tree_mask, win_start=win_start, impl="jnp")


def _flash_eligible(kpos, window, causal, tree_mask) -> bool:
    """The Pallas flash-decode kernel covers exactly the cache-read
    decode/verify shape: causal attention over a contiguous cache whose
    slot index equals the absolute position (``kpos`` is the 1-D
    ``arange`` the contiguous-cache path passes).  Ring buffers (2-D
    ``kpos``), sliding windows, cross-attention and the train/prefill
    self-window (2-D ``kpos = qpos``) stay on the jnp path."""
    del tree_mask  # tree windows compose with the kernel — no exclusion
    return causal and window is None and jnp.ndim(kpos) == 1


def attend(q, k, v, qpos, kpos, *, window=None, causal=True,
           k_scale=None, v_scale=None, tree_mask=None, win_start=None,
           impl=None):
    """Position-masked attention; ``impl`` picks the implementation for
    flash-eligible calls: ``"auto"`` (default) follows the backend policy
    (TPU → compiled Pallas kernel, ``REPRO_USE_PALLAS=1`` → interpret
    validation, CPU default → jnp), ``"pallas"`` forces the kernel
    (interpret off-TPU), ``"jnp"`` forces the pure-jnp path.  Ineligible
    calls always run jnp regardless of ``impl``."""
    mode = impl or "auto"
    if mode not in ("auto", "jnp", "pallas"):
        raise ValueError(f"unknown attn impl {mode!r}")
    if mode != "jnp" and _flash_eligible(kpos, window, causal, tree_mask):
        from repro.kernels import ops  # lazy: kernels must not pull models

        if mode == "pallas" or ops.attn_backend() != "jnp":
            return ops.flash_attend(q, k, v, qpos,
                                    k_scale=k_scale, v_scale=v_scale,
                                    tree_mask=tree_mask, win_start=win_start,
                                    force=mode == "pallas")
    valid = _mask(qpos, kpos, window, causal, tree_mask, win_start)
    S = k.shape[1]
    if S > CHUNK_THRESHOLD:
        pad = (-S) % KV_CHUNK
        if pad:  # keep the O(B·H·T·C) online-softmax path for non-aligned
            # caches: pad K/V (+ scales) with masked junk columns.  Serving
            # buffers are pre-aligned by transformer.init_cache, so this
            # per-call copy only hits direct attend() callers, never the
            # jitted decode hot loop.
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            valid = jnp.pad(valid, ((0, 0),) * (valid.ndim - 1) + ((0, pad),))
            if k_scale is not None:
                k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            if v_scale is not None:
                v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        return _attend_chunked(q, k, v, valid, k_scale, v_scale)
    return _attend_direct(q, k, v, valid, k_scale, v_scale)


# ---------------------------------------------------------------------------
# Cache write
# ---------------------------------------------------------------------------

def _quant_kv(x):
    """(B, T, H, dh) → (int8 values, (B, T, H) f32 scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def write_cache_paged(cache: dict, k, v, qpos, bt) -> dict:
    """Scatter T new K/V rows into a *paged* layer pool through the block
    table.

    ``qpos`` are logical slots; ``repro.core.paged_cache.physical_slots``
    maps them through ``bt`` onto rows of the pool viewed as
    ``(num_blocks * block_size, Hkv, dh)``.  Cross-row scatters never
    collide because the host guarantees *write exclusivity*: with prefix
    sharing a block may be referenced by several rows' tables, but only
    ever written through a table whose owner holds it at refcount 1 —
    admission forks a shared boundary block via copy-on-write
    (``BlockPool.cow`` + ``clone_block``) before the verify window can
    reach it, and ``PagedGroup.prepare_step``'s defensive COW sweep
    re-establishes exclusivity before every step.  Shared (registered)
    blocks hold only prefill rows strictly below every sharer's write
    frontier, so concurrent *reads* through multiple tables are safe.
    Idle rows (and logical slots past a row's allocation) land in the
    scratch block, whose content is never validly read.
    """
    from repro.core.paged_cache import physical_slots

    block_size = cache["k"].shape[1]
    int8 = cache["k"].dtype == jnp.int8
    if int8:
        k, ks = _quant_kv(k)
        v, vs = _quant_kv(v)
    phys = physical_slots(bt, qpos, block_size).reshape(-1)      # (B*T,)
    new = dict(cache)
    for name, vals in (("k", k), ("v", v)):
        buf = cache[name]
        flat = buf.reshape((-1,) + buf.shape[2:])
        flat = flat.at[phys].set(
            vals.reshape((-1,) + vals.shape[2:]).astype(buf.dtype))
        new[name] = flat.reshape(buf.shape)
    if int8:
        for name, vals in (("k_scale", ks), ("v_scale", vs)):
            buf = cache[name]
            flat = buf.reshape((-1,) + buf.shape[2:])
            new[name] = flat.at[phys].set(
                vals.reshape((-1,) + vals.shape[2:])).reshape(buf.shape)
    return new


def write_cache(cache: dict, k, v, qpos, window=None) -> dict:
    """Scatter T new K/V rows into the cache at per-row absolute positions."""
    B, T = qpos.shape
    bidx = jnp.arange(B)[:, None]
    int8 = cache["k"].dtype == jnp.int8
    if int8:
        k, ks = _quant_kv(k)
        v, vs = _quant_kv(v)
    if "kpos" in cache:  # ring buffer
        R = cache["k"].shape[1]
        if T >= R:  # long prefill wraps the ring: only the last R rows survive
            k, v, qpos = k[:, -R:], v[:, -R:], qpos[:, -R:]
            if int8:
                ks, vs = ks[:, -R:], vs[:, -R:]
        slots = qpos % R
    else:
        slots = qpos
    new = {
        "k": cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype)),
        "v": cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype)),
    }
    if int8:
        new["k_scale"] = cache["k_scale"].at[bidx, slots].set(ks)
        new["v_scale"] = cache["v_scale"].at[bidx, slots].set(vs)
    if "kpos" in cache:
        new["kpos"] = cache["kpos"].at[bidx, slots].set(qpos)
    return new


# ---------------------------------------------------------------------------
# Public layer apply
# ---------------------------------------------------------------------------

def self_attention(
    p: dict,
    cfg,
    x,                    # (B, T, D)
    qpos,                 # (B, T) absolute positions
    *,
    cache: dict | None = None,
    read_cache: bool = True,
    window: int | None = None,
    causal: bool = True,
    collect=None,
    path: str = "",
    slots=None,           # (B, T) cache-slot override (tree verify: the
    #                       packed window occupies start + arange(T) while
    #                       qpos carries start + depth)
    tree_mask=None,       # (T, T) ancestor-or-self mask over the window
    win_start=None,       # (B,) first window slot (= start)
    block_tables=None,    # (B, max_blocks) int32 — paged cache layout:
    #                       ``cache`` holds physical pools, logical slots
    #                       map through this table (core/paged_cache.py)
):
    """Returns (out (B,T,D), updated cache or None).

    ``read_cache=False`` (prefill): K/V are still written into the cache,
    but attention runs over the chunk's own keys — equivalent when the
    cache is empty, and it avoids scatter-ordering hazards when a long
    prompt wraps a ring buffer multiple times.  ``block_tables`` switches
    the cache write/read onto the paged layout (decode/verify only —
    paged prefill is handled by admission-time scatter, see
    ``SpecEngine.prefill_into_slot``).
    """
    B, T, _ = x.shape
    q = _lin(p["q"], x, collect, f"{path}/q").reshape(B, T, cfg.num_heads, cfg.head_dim)
    k = _lin(p["k"], x, collect, f"{path}/k").reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = _lin(p["v"], x, collect, f"{path}/v").reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    if cfg.use_rope:
        q = apply_rope(q, qpos, cfg.rope_theta)
        k = apply_rope(k, qpos, cfg.rope_theta)

    if cache is not None:
        if block_tables is not None:
            cache = write_cache_paged(cache, k, v,
                                      slots if slots is not None else qpos,
                                      block_tables)
        else:
            cache = write_cache(cache, k, v,
                                slots if slots is not None else qpos, window)
    if cache is not None and read_cache and block_tables is not None:
        o = attend_paged(q, cache, block_tables, qpos,
                         tree_mask=tree_mask, win_start=win_start,
                         impl=getattr(cfg, "attn_impl", None))
    elif cache is not None and read_cache:
        keys, values = cache["k"], cache["v"]
        kpos = cache.get("kpos", jnp.arange(keys.shape[1], dtype=jnp.int32))
        o = attend(q, keys, values, qpos, kpos, window=window, causal=causal,
                   k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
                   tree_mask=tree_mask, win_start=win_start,
                   impl=getattr(cfg, "attn_impl", None))
    else:
        o = attend(q, k, v, qpos, qpos, window=window, causal=causal,
                   impl=getattr(cfg, "attn_impl", None))

    out = _lin(p["o"], o.reshape(B, T, cfg.q_dim), collect, f"{path}/o")
    return out, cache


def cross_attention(
    p: dict,
    cfg,
    x,                    # (B, T, D)
    *,
    kv_embeds=None,       # (B, Sa, D) encoder / image embeddings (prefill)
    cache: dict | None = None,   # {"ck","cv": (B, Sa, Hkv, dh)} if precomputed
    collect=None,
    path: str = "",
):
    """Cross-attention over modality embeddings.  Returns (out, cache)."""
    B, T, _ = x.shape
    q = _lin(p["q"], x, collect, f"{path}/q").reshape(B, T, cfg.num_heads, cfg.head_dim)
    if cache is not None and "ck" in cache and kv_embeds is None:
        k, v = cache["ck"], cache["cv"]
    else:
        Sa = kv_embeds.shape[1]
        k = _lin(p["k"], kv_embeds, collect, f"{path}/k").reshape(B, Sa, cfg.num_kv_heads, cfg.head_dim)
        v = _lin(p["v"], kv_embeds, collect, f"{path}/v").reshape(B, Sa, cfg.num_kv_heads, cfg.head_dim)
        if cache is not None:
            cache = {"ck": k, "cv": v}
    qpos = jnp.zeros((B, T), jnp.int32)
    kpos = jnp.zeros((k.shape[1],), jnp.int32)
    o = attend(q, k, v, qpos, kpos, causal=False)
    out = _lin(p["o"], o.reshape(B, T, cfg.q_dim), collect, f"{path}/o")
    return out, cache
