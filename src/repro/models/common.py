"""Shared building blocks: norms, RoPE, activations, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def init_norm(cfg, d: int) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(cfg, p: dict, x: jax.Array) -> jax.Array:
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps)
    return rms_norm(x, p["scale"], cfg.norm_eps)


def activation(cfg, x: jax.Array) -> jax.Array:
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh), positions: (B, T) int32. Rotates pairs (even, odd halves)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (Dh/2,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (B, T, Dh/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key: jax.Array, shape, dtype=jnp.bfloat16, in_axis: int = -2) -> jax.Array:
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)
