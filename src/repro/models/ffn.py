"""Dense feed-forward network (gated or plain)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation
from repro.models.linear import apply_linear, init_linear
from repro.quant.smoothquant import record_act_stats


def init_ffn(key, cfg, d_ff=None) -> dict:
    d_ff = d_ff or cfg.d_ff
    kg, ku, kd = jax.random.split(key, 3)
    p = {
        "up": init_linear(ku, cfg.d_model, d_ff, cfg.ffn_bias, cfg.dtype),
        "down": init_linear(kd, d_ff, cfg.d_model, cfg.ffn_bias, cfg.dtype),
    }
    if cfg.glu:
        p["gate"] = init_linear(kg, cfg.d_model, d_ff, cfg.ffn_bias, cfg.dtype)
    return p


def _lin(p, x, collect, path):
    if collect is not None:
        record_act_stats(collect, path, x)
    return apply_linear(p, x)


def apply_ffn(p: dict, cfg, x, collect=None, path: str = "") -> jax.Array:
    up = _lin(p["up"], x, collect, f"{path}/up")
    if "gate" in p:
        h = activation(cfg, _lin(p["gate"], x, collect, f"{path}/gate")) * up
    else:
        h = activation(cfg, up)
    return _lin(p["down"], h, collect, f"{path}/down")
