"""DenseGeneral linear layer with BF16 and W8A8 (quantized-verification) paths.

Params are plain dicts (pytrees).  A linear is either:

* BF16:  ``{"w": (din, dout) bf16 [, "b": (dout,)]}``
* W8A8:  ``{"w_int8": (din, dout) int8, "w_scale": (dout,) f32,
            "smooth": (din,) f32 [, "b": (dout,)]}``

The W8A8 layout is what ``repro.quant.apply.quantize_params`` produces
offline (paper §3.3 "Offline Weight Preparation"): weights are smoothed by
``diag(s)^-1`` and symmetric-quantized per output channel.  At run time the
activations are smoothed and dynamically quantized per token (Eq. 9), the
GEMM runs in int8 and the result is dequantized by ``Δw·Δx`` (Eq. 10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.kernels import ops as kops


def init_linear(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.bfloat16) -> dict:
    p = {"w": dense_init(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def is_quantized(p: dict) -> bool:
    return "w_int8" in p or "w_int4" in p


def apply_linear(p: dict, x: jax.Array) -> jax.Array:
    """x: (..., d_in) -> (..., d_out). Dispatches on the param layout."""
    if "w_int4" in p:
        from repro.quant.int4 import w4a8_matmul
        y = w4a8_matmul(x, p["w_int4"], p["w_scale"], p["smooth"])
    elif "w_int8" in p:
        y = kops.w8a8_matmul(x, p["w_int8"], p["w_scale"], p["smooth"])
    else:
        y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y
