"""Model facade: the public API the engine / trainer / launcher use.

Two parameter layouts are supported and auto-detected by the ``"scan"``
key in the param/cache pytree:

* **canonical** (per-layer lists) — init, checkpointing, SmoothQuant
  calibration, quantization, smoke tests, benchmarks;
* **scan** (stacked layer groups, ``models/scan.py``) — production
  lowering: one HLO copy per block kind, used by the multi-pod dry-run and
  the launch drivers.  Convert with ``Model.to_scan(params)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import scan as S
from repro.models import transformer


class Model:
    """Thin functional facade over the transformer stack for one ModelConfig."""

    def __init__(self, cfg):
        self.cfg = cfg

    # -- params ----------------------------------------------------------
    def init_params(self, key) -> dict:
        return transformer.init_params(key, self.cfg)

    def to_scan(self, params_or_cache: dict) -> dict:
        if "layers" in params_or_cache:
            return S.stack_params(params_or_cache, self.cfg)
        return S.stack_cache(params_or_cache, self.cfg)

    def init_cache(self, batch: int, max_len: int,
                   num_layers: Optional[int] = None, scan: bool = False) -> dict:
        cache = transformer.init_cache(self.cfg, batch, max_len, num_layers)
        return S.stack_cache(cache, self.cfg) if scan else cache

    def _fwd(self, params, *args, **kw):
        if "scan" in params:
            kw.pop("collect", None)
            kw.pop("num_layers", None)
            return S.forward(params, self.cfg, *args, **kw)
        return transformer.forward(params, self.cfg, *args, **kw)

    # -- full forward (train / calibration / fidelity eval) ---------------
    def forward(self, params, tokens, *, aux_embeds=None, collect=None,
                num_layers=None, remat=False):
        B = tokens.shape[0]
        start = jnp.zeros((B,), jnp.int32)
        kw = dict(aux_embeds=aux_embeds)
        if "scan" in params:
            kw["remat"] = remat
        else:
            kw.update(collect=collect, num_layers=num_layers)
        logits, _, aux = self._fwd(params, tokens, start, **kw)
        return logits, aux

    # -- serving ----------------------------------------------------------
    def prefill(self, params, cache, tokens, *, aux_embeds=None, num_layers=None):
        """Process the prompt *except its last token* into the cache.

        The last prompt token becomes the first token of the first verify
        window.  Returns the updated cache.
        """
        B = tokens.shape[0]
        start = jnp.zeros((B,), jnp.int32)
        kw = dict(cache=cache, read_cache=False, aux_embeds=aux_embeds,
                  need_logits=False)
        if "scan" not in params:
            kw["num_layers"] = num_layers
        _, cache, _ = self._fwd(params, tokens, start, **kw)
        return cache

    def prefill_chunk(self, params, cache, tokens, start, *, num_layers=None):
        """Prefill a prompt *tail* against an already-warm cache.

        Rows ``[0, start)`` of ``cache`` hold earlier context (e.g. a
        shared prompt prefix gathered from the paged prefix cache);
        ``tokens`` (B, T) continue it at absolute position ``start``.
        Forces the ``jnp`` attention path so the chunk attends over the
        cached prefix exactly like a full-prompt prefill does over its
        own rows — full-row softmax with masked columns contributing
        exact zeros — which keeps chunked prefill bit-identical to the
        monolithic one (asserted in tests/test_prefix_sharing.py).
        """
        if "scan" in params:
            raise NotImplementedError(
                "chunked prefill is not lowered for the scan "
                "(stacked-layer) param layout")
        cfg = dataclasses.replace(self.cfg, attn_impl="jnp")
        B = tokens.shape[0]
        st = jnp.full((B,), int(start), jnp.int32)
        _, cache, _ = transformer.forward(
            params, cfg, tokens, st, cache=cache, read_cache=True,
            need_logits=False, num_layers=num_layers)
        return cache

    def verify_step(self, params, cache, window_tokens, start, num_layers=None,
                    tree_depths=None, tree_mask=None):
        """Forward a speculative window (B, T=γ+1) at per-row ``start``.

        ``tree_depths``/``tree_mask`` switch the window to a packed token
        tree (``repro.core.tree.TreeTemplate``): node positions follow
        depth, cache slots follow packed order, and the ancestor mask
        replaces position causality inside the window.  Returns
        (logits, candidate cache); resolve with ``commit`` (chain) or
        ``commit_tree`` once acceptance lengths are known.
        """
        kw = dict(cache=cache, collect_states=True)
        if "scan" in params:
            if tree_depths is not None:
                raise NotImplementedError(
                    "tree verification is not lowered for the scan "
                    "(stacked-layer) param layout")
        else:
            kw["num_layers"] = num_layers
            kw.update(tree_depths=tree_depths, tree_mask=tree_mask)
        logits, cache, _ = self._fwd(params, window_tokens, start, **kw)
        return logits, cache

    def decode_step(self, params, cache, token, start, num_layers=None):
        """Vanilla single-token decode: (B,1) → (logits (B,1,V), cache)."""
        kw = dict(cache=cache)
        if "scan" not in params:
            kw["num_layers"] = num_layers
        logits, cache, _ = self._fwd(params, token, start, **kw)
        return logits, cache

    def commit(self, cache, n_last, num_layers=None):
        if "scan" in cache:
            return S.commit_cache(self.cfg, cache, n_last)
        return transformer.commit_cache(self.cfg, cache, n_last, num_layers)

    def commit_tree(self, cache, start, path_nodes, n_accept, num_layers=None):
        """Tree-verify commit: compact the accepted root-to-leaf path's
        K/V rows into chain slots (see ``transformer.commit_cache_tree``)."""
        if "scan" in cache:
            raise NotImplementedError(
                "tree verification is not lowered for the scan cache layout")
        return transformer.commit_cache_tree(self.cfg, cache, start,
                                             path_nodes, n_accept, num_layers)
