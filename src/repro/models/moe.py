"""Mixture-of-Experts FFN with TPU-native capacity-based dispatch.

Top-k routing, capacity-factor dispatch via scatter/gather (the einsum/
all-to-all pattern GSPMD shards expert-parallel over the ``model`` mesh
axis), load-balance auxiliary loss, and an optional dense residual branch
(arctic-480b).  The router always stays BF16/f32 (see quant/apply.py);
expert weights are quantizable as batched ``(E, din, dout)`` tensors with
per-expert per-channel scales.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import activation
from repro.models.ffn import init_ffn, apply_ffn
from repro.models.linear import dense_init
from repro.quant.smoothquant import record_act_stats

# Sharding hint for the dispatch buffer (E, C, D): installed by the launch
# layer (expert-parallel "model" on E); None on single-device runs.
_DISPATCH_SPEC = None


def set_dispatch_spec(spec) -> None:
    global _DISPATCH_SPEC
    _DISPATCH_SPEC = spec


def _constrain(xe):
    if _DISPATCH_SPEC is not None:
        return jax.lax.with_sharding_constraint(xe, _DISPATCH_SPEC)
    return xe


# shard_map expert-parallel mode (§Perf iteration: "moe-shardmap").  When
# the launch layer installs (mesh, dp_axes, fsdp) here, apply_moe routes
# through an explicit per-data-shard dispatch:
#   * routing/capacity are computed locally per data shard (tokens never
#     cross the data axis for dispatch — experts are replicated over data
#     up to FSDP storage, which is un-gathered with one tiled all-gather);
#   * each model shard serves only its E/model_size experts and the
#     partial combine is a single psum over "model" — the same collective
#     a dense row-parallel FFN needs.
# GSPMD's auto-partitioned dispatch instead all-reduces the full f32
# (E_loc, C, D) buffer over the data axis (measured: the dominant term).
_SHARD_MAP = None  # (mesh, dp_axes: tuple, fsdp: bool)


def set_shard_map(mesh, dp_axes, fsdp: bool) -> None:
    global _SHARD_MAP
    _SHARD_MAP = (mesh, tuple(dp_axes), fsdp) if mesh is not None else None


def init_moe(key, cfg) -> dict:
    kr, kg, ku, kd, kres = jax.random.split(key, 5)
    E, D, F = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": {"w": dense_init(kr, (D, E), jnp.float32)},
        "up": {"w": dense_init(ku, (E, D, F), cfg.dtype)},
        "down": {"w": dense_init(kd, (E, F, D), cfg.dtype)},
    }
    if cfg.glu:
        p["gate"] = {"w": dense_init(kg, (E, D, F), cfg.dtype)}
    if cfg.dense_residual:
        p["dense"] = init_ffn(kres, cfg, cfg.d_ff)
    return p


def _expert_linear(p: dict, x: jax.Array, collect=None, path: str = "") -> jax.Array:
    """Batched expert GEMM: x (E, C, din) → (E, C, dout). BF16 or W8A8."""
    if collect is not None:
        record_act_stats(collect, path, x.reshape(-1, x.shape[-1]))
    if "w_int8" in p:
        xs = x.astype(jnp.float32) * p["smooth"]
        dx = jnp.maximum(jnp.max(jnp.abs(xs), axis=-1), 1e-8) / 127.0
        xq = jnp.clip(jnp.round(xs / dx[..., None]), -127, 127).astype(jnp.int8)
        acc = jax.lax.dot_general(
            xq, p["w_int8"],
            dimension_numbers=(((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32,
        )
        y = acc.astype(jnp.float32) * dx[..., None] * p["w_scale"][:, None, :]
        return y.astype(x.dtype)
    return jnp.einsum("ecd,edf->ecf", x, p["w"].astype(x.dtype))


def capacity(n_tokens: int, num_experts: int, k: int, factor: float) -> int:
    return max(1, min(n_tokens * k,
                      math.ceil(n_tokens * k * factor / num_experts)))


def _rank_positions(ids: jax.Array, n_bins: int) -> jax.Array:
    """Position of each element within its bin (sort-based, O(n log n)).
    ``ids`` may contain the sentinel value ``n_bins`` for masked slots."""
    nK = ids.shape[0]
    order = jnp.argsort(ids, stable=True)
    counts = jnp.zeros((n_bins + 1,), jnp.int32).at[ids].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_sorted = jnp.arange(nK, dtype=jnp.int32) - starts[ids[order]]
    return jnp.zeros((nK,), jnp.int32).at[order].set(pos_sorted)


def _apply_moe_shard_map(p: dict, cfg, x):
    """Explicit expert-parallel MoE (see _SHARD_MAP note above)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh, dp, fsdp = _SHARD_MAP
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    msize = mesh.shape["model"]
    dpsize = math.prod(mesh.shape[a] for a in dp) if dp else 1
    if E % msize or (dp and B % dpsize):
        return None  # fall back to the GSPMD path
    E_loc = E // msize
    dp_ok = dp if (dp and B % dpsize == 0) else None

    p_moe = {k: v for k, v in p.items() if k != "dense"}

    def leaf_spec(path_keys, leaf):
        path = "/".join(str(getattr(k, "key", k)) for k in path_keys)
        if "router" in path:
            return P(*([None] * leaf.ndim))
        if "w_scale" in path:
            return P("model", *([None] * (leaf.ndim - 1)))
        if "smooth" in path or leaf.ndim <= 1:
            return P(*([None] * leaf.ndim))
        # expert tensors (E, din, dout): E on model, din FSDP over the data
        # axis (dp[-1]; multi-pod keeps "pod" for pure DP, matching the
        # fsdp=("data",) rule in launch/sharding.py)
        shard1 = dp[-1] if (fsdp and dp and leaf.shape[1] % mesh.shape[dp[-1]] == 0) else None
        return P("model", shard1, None)

    pspecs = jax.tree_util.tree_map_with_path(leaf_spec, p_moe)
    gathered = jax.tree_util.tree_map_with_path(
        lambda kp, leaf: leaf_spec(kp, leaf) != P("model", None, None)
        and leaf.ndim == 3, p_moe)

    def body(pp, xl):
        Bl, T_, _ = xl.shape
        n = Bl * T_
        xf = xl.reshape(n, D)
        logits = xf.astype(jnp.float32) @ pp["router"]["w"]
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, K)
        gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

        e0 = jax.lax.axis_index("model").astype(jnp.int32) * E_loc
        C = capacity(n, E, K, cfg.moe_capacity_factor)
        e_flat = eidx.reshape(-1)
        local = (e_flat >= e0) & (e_flat < e0 + E_loc)
        ids = jnp.where(local, e_flat - e0, E_loc)
        pos = _rank_positions(ids, E_loc)
        keep = local & (pos < C)
        slot_ids = jnp.where(keep, ids * C + pos, E_loc * C)
        token_idx = jnp.arange(n * K, dtype=jnp.int32) // K
        slot_tok = jnp.full((E_loc * C,), n, jnp.int32).at[slot_ids].set(
            token_idx, mode="drop")
        x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
        xe = x_pad[slot_tok].reshape(E_loc, C, D)

        def weights(name):
            q = dict(pp[name])
            wk = "w_int8" if "w_int8" in q else "w"
            if gathered[name][wk] and dp:
                q[wk] = jax.lax.all_gather(q[wk], dp[-1], axis=1, tiled=True)
            return q

        up = _expert_linear(weights("up"), xe)
        if "gate" in pp:
            h = activation(cfg, _expert_linear(weights("gate"), xe)) * up
        else:
            h = activation(cfg, up)
        ye = _expert_linear(weights("down"), h)                   # (E_loc, C, D)

        ye_pad = jnp.concatenate(
            [ye.reshape(E_loc * C, D), jnp.zeros((1, D), ye.dtype)], axis=0)
        y_slots = ye_pad[jnp.minimum(slot_ids, E_loc * C)].reshape(n, K, D)
        w_gate = (gates * keep.reshape(n, K)).astype(y_slots.dtype)
        y = jnp.sum(y_slots * w_gate[..., None], axis=1)
        y = jax.lax.psum(y, "model")                              # combine

        f = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
        Pm = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f * Pm) * cfg.router_aux_coef
        if dp:
            aux = jax.lax.pmean(aux, dp)
        return y.reshape(Bl, T_, D).astype(xl.dtype), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(pspecs, P(dp_ok, None, None)),
        out_specs=(P(dp_ok, None, None), P()),
        check_rep=False,
    )(p_moe, x)

    if "dense" in p:
        y = y + apply_ffn(p["dense"], cfg, x)
    return y, aux


def apply_moe(p: dict, cfg, x, collect=None, path: str = ""):
    """x: (B, T, D) → (y (B,T,D), aux_loss scalar)."""
    if _SHARD_MAP is not None and collect is None:
        out = _apply_moe_shard_map(p, cfg, x)
        if out is not None:
            return out
    B, T, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    n = B * T
    xf = x.reshape(n, D)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)  # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, K)                                     # (n, K)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert queue — sort-based
    # ranking, O(nK log nK).  (The naive one-hot/cumsum ranking is O(nK·E)
    # with an (nK, E) cumsum intermediate; on moonshot train_4k it accounted
    # for >10× the model FLOPs — see EXPERIMENTS.md §Perf iteration 1.)
    e_flat = eidx.reshape(-1)                                                 # (nK,)
    order = jnp.argsort(e_flat, stable=True)
    counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_sorted = jnp.arange(n * K, dtype=jnp.int32) - starts[e_flat[order]]
    pos = jnp.zeros((n * K,), jnp.int32).at[order].set(pos_sorted)
    pos = pos.reshape(n, K)
    C = capacity(n, E, K, cfg.moe_capacity_factor)
    keep = pos < C

    # dispatch: gather-based.  Scatter only a tiny int32 slot→token map
    # (the (E·C, D) scatter-ADD of activations forced an all-reduce of the
    # full f32 dispatch buffer across the data axis — §Perf iteration 2);
    # the activations themselves move through a gather, which GSPMD lowers
    # to all-to-all-style traffic proportional to the tokens actually sent.
    keep_flat = keep.reshape(-1)
    p_flat = jnp.where(keep_flat, pos.reshape(-1), C - 1)
    slot_ids = jnp.where(keep_flat, e_flat * C + p_flat, E * C)       # OOB = drop
    token_idx = (jnp.arange(n * K, dtype=jnp.int32) // K)
    slot_tok = jnp.full((E * C,), n, jnp.int32).at[slot_ids].set(
        token_idx, mode="drop")                                       # (E·C,)
    x_pad = jnp.concatenate([xf, jnp.zeros((1, D), xf.dtype)], axis=0)
    xe = _constrain(x_pad[slot_tok].reshape(E, C, D))

    # expert FFN
    up = _expert_linear(p["up"], xe, collect, f"{path}/up")
    if "gate" in p:
        h = activation(cfg, _expert_linear(p["gate"], xe, collect, f"{path}/gate")) * up
    else:
        h = activation(cfg, up)
    ye = _expert_linear(p["down"], h, collect, f"{path}/down")                # (E, C, D)

    # combine: gather each (token, slot) result, weight by gate
    y_slots = ye[e_flat, p_flat].reshape(n, K, D)
    y = jnp.sum(y_slots * (gates * keep).astype(y_slots.dtype)[..., None], axis=1)
    y = y.reshape(B, T, D)

    if "dense" in p:  # arctic-style dense residual branch
        y = y + apply_ffn(p["dense"], cfg, x, collect, f"{path}/dense")

    # load-balance aux loss (Switch-style): E * Σ_e f_e · P_e
    f = jnp.mean(jax.nn.one_hot(eidx[:, 0], E, dtype=jnp.float32), axis=0)
    P = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f * P) * cfg.router_aux_coef
    return y, aux
