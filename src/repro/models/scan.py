"""Scanned-layer execution (MaxText-style) — same math as the python-loop
stack in ``transformer.py`` but with ``jax.lax.scan`` over layer groups, so
the HLO contains ONE copy of each distinct block kind.  This is what makes
the 80-combination production dry-run compile in seconds instead of
minutes, and is the layout a real deployment would use.

Layer stacks are grouped by their repeating *pattern*:

  dense/moe/ssm/audio : pattern [kind],            n = L
  vlm (llama-3.2)     : pattern [dense×4, cross],  n = L/5
  hybrid (zamba2)     : pattern [ssm×6] + shared,  n = L/6

``stack_params`` converts the canonical per-layer list layout (used by
init / checkpoint / calibration / quantization) into stacked pytrees with
a leading group dim; caches are stacked the same way.  Quantize first,
then stack — per-layer smoothing vectors stay exact.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm
from repro.models.linear import apply_linear
from repro.models.ssm import commit_ssm_cache
from repro.models import transformer as T
from repro.quant.smoothquant import record_act_stats


# ---------------------------------------------------------------------------
# Activation sharding hint: XLA's sharding propagation into while-loop
# bodies can drop the batch sharding of the layer-carry (measured: the
# 4k-train body all-gathered the FULL global batch per layer).  The launch
# layer installs a PartitionSpec here; the scan body re-constrains its
# carry every iteration.
# ---------------------------------------------------------------------------

_ACT_SPEC = None


def set_activation_spec(spec) -> None:
    """spec: jax.sharding.PartitionSpec for (B, T, D) activations, or None."""
    global _ACT_SPEC
    _ACT_SPEC = spec


def _constrain(x):
    if _ACT_SPEC is not None:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


def scan_pattern(cfg) -> Tuple[List[str], int, bool]:
    """(pattern kinds, n_groups, has_shared_block)."""
    kinds = T.layer_kinds(cfg)
    L = cfg.num_layers
    if cfg.arch_type == "hybrid" and cfg.attn_every:
        p = cfg.attn_every
        assert L % p == 0, (L, p)
        return kinds[:p], L // p, True
    if cfg.arch_type == "vlm" and cfg.cross_attn_every:
        p = cfg.cross_attn_every
        assert L % p == 0, (L, p)
        return kinds[:p], L // p, False
    return [kinds[0]], L, False


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_params(params: dict, cfg) -> dict:
    """Canonical (per-layer list) → scan layout."""
    pattern, n, _ = scan_pattern(cfg)
    P = len(pattern)
    out = {k: v for k, v in params.items() if k != "layers"}
    out["scan"] = [
        _stack([params["layers"][g * P + j] for g in range(n)]) for j in range(P)
    ]
    return out


def stack_cache(cache: dict, cfg) -> dict:
    pattern, n, shared = scan_pattern(cfg)
    P = len(pattern)
    out = {k: v for k, v in cache.items() if k not in ("layers", "shared")}
    out["scan"] = [
        _stack([cache["layers"][g * P + j] for g in range(n)]) for j in range(P)
    ]
    if shared and "shared" in cache:
        out["shared"] = _stack(cache["shared"])
    return out


def unstack_cache(cache: dict, cfg) -> dict:
    """Scan layout → canonical list layout (tests / debugging)."""
    pattern, n, shared = scan_pattern(cfg)
    P = len(pattern)
    layers: list = [None] * (n * P)
    for j, grp in enumerate(cache["scan"]):
        for g in range(n):
            layers[g * P + j] = jax.tree.map(lambda x: x[g], grp)
    out = {"layers": layers}
    if shared and "shared" in cache:
        sh = cache["shared"]
        n_apps = jax.tree.leaves(sh)[0].shape[0]
        out["shared"] = [jax.tree.map(lambda x: x[a], sh) for a in range(n_apps)]
    return out


def forward(
    params: dict,
    cfg,
    tokens: jax.Array,
    start: jax.Array,
    *,
    cache: Optional[dict] = None,
    read_cache: bool = True,
    collect_states: bool = False,
    aux_embeds: Optional[jax.Array] = None,
    remat: bool = False,
    need_logits: bool = True,
):
    """Scanned twin of ``transformer.forward`` (no calibration collector —
    calibrate in the canonical layout).  Returns (logits, new_cache, aux)."""
    B, T_ = tokens.shape
    qpos = start[:, None] + jnp.arange(T_, dtype=jnp.int32)[None, :]
    pattern, n, shared = scan_pattern(cfg)

    x = params["embed"]["w"][tokens].astype(cfg.dtype)

    enc_out = None
    if cfg.encoder_layers and aux_embeds is not None:
        enc_out = _encode_scan(params["encoder"], cfg, aux_embeds)
    elif aux_embeds is not None:
        enc_out = aux_embeds.astype(cfg.dtype)

    sp = params.get("shared_attn")

    def body(carry, xs):
        x, aux = carry
        x = _constrain(x)
        blocks, caches = xs["blocks"], xs["caches"]
        new_caches = []
        for j, kind in enumerate(pattern):
            x, lc, a = T._apply_block(
                kind, blocks[j], cfg, x, qpos, caches[j],
                read_cache=read_cache, collect_states=collect_states,
                enc_out=enc_out,
            )
            aux = aux + a
            new_caches.append(lc)
        scache = None
        if shared:
            x, scache, _ = T._apply_shared(sp, cfg, x, qpos, xs.get("shared"),
                                           read_cache=read_cache)
        ys = {"caches": new_caches}
        if shared:
            ys["shared"] = scache
        return (x, aux), ys

    if cache is None:
        def body_nc(carry, blocks):
            carry, _ = body(carry, {"blocks": blocks,
                                    "caches": [None] * len(pattern)})
            return carry, None

        if remat:
            body_nc = jax.checkpoint(body_nc)
        (x, aux_total), _ = jax.lax.scan(body_nc, (x, jnp.zeros((), jnp.float32)),
                                         params["scan"])
        new_cache = None
    else:
        if remat:
            body = jax.checkpoint(body)
        xs = {"blocks": params["scan"], "caches": cache["scan"]}
        if shared:
            xs["shared"] = cache["shared"]
        (x, aux_total), ys = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
        new_cache = {"scan": ys["caches"]}
        if shared:
            new_cache["shared"] = ys["shared"]

    logits = None
    if need_logits:
        x = apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x.astype(jnp.float32) @ params["embed"]["w"].astype(jnp.float32).T
        else:
            logits = apply_linear(params["lm_head"], x).astype(jnp.float32)
    return logits, new_cache, aux_total


def _encode_scan(enc: dict, cfg, embeds: jax.Array) -> jax.Array:
    B, S, _ = embeds.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = embeds.astype(cfg.dtype)
    stacked = _stack(enc["layers"])

    def body(x, blk):
        from repro.models.attention import self_attention
        from repro.models.ffn import apply_ffn
        h, _ = self_attention(blk["attn"], cfg,
                              apply_norm(cfg, blk["attn_norm"], x), pos, causal=False)
        x = x + h
        x = x + apply_ffn(blk["ffn"], cfg, apply_norm(cfg, blk["ffn_norm"], x))
        return x, None

    x, _ = jax.lax.scan(body, x, stacked)
    return apply_norm(cfg, enc["norm"], x)


def commit_cache(cfg, cache: dict, n_last: jax.Array) -> dict:
    pattern, n, shared = scan_pattern(cfg)
    groups = []
    for j, kind in enumerate(pattern):
        grp = cache["scan"][j]
        if kind == "ssm" and grp is not None and "states_all" in grp:
            grp = jax.vmap(commit_ssm_cache, in_axes=(0, None))(grp, n_last)
        groups.append(grp)
    out = {"scan": groups}
    if shared and "shared" in cache:
        out["shared"] = cache["shared"]
    return out
