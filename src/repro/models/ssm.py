"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Three execution paths share one parameterization:

* **chunked** (train / prefill): the SSD algorithm — quadratic
  attention-like intra-chunk term + an inter-chunk recurrence carried by
  ``lax.scan`` over chunk states.  O(T·Q) work, TPU-friendly (the intra
  term is an MXU matmul per chunk).
* **sequential** (decode / verify): step recurrence
  ``h_t = a_t·h_{t-1} + dt_t·(B_t ⊗ x_t)``; optionally collects the state
  after *every* step so speculative decoding can roll back to the last
  accepted token (cache commit is a gather — no recompute).
* cache: ``{"state": (B,H,P,N) f32, "conv": (B, K-1, di+2N)}`` — the SSD
  state plus the depthwise-conv tail window.

The in/out projections are quantizable linears (Quasar applies to them);
the recurrent state itself stays f32 — quantizing the recurrence would
compound error across thousands of steps (noted in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rms_norm
from repro.models.linear import apply_linear, init_linear
from repro.quant.smoothquant import record_act_stats

D_CONV = 4  # depthwise conv width


def init_ssm(key, cfg) -> dict:
    ki, ko, kc, ka, kd = jax.random.split(key, 5)
    D, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    d_in_proj = 2 * di + 2 * N + H
    conv_dim = di + 2 * N
    return {
        "in_proj": init_linear(ki, D, d_in_proj, False, cfg.dtype),
        "out_proj": init_linear(ko, di, D, False, cfg.dtype),
        "conv_w": (jax.random.normal(kc, (D_CONV, conv_dim), jnp.float32) * 0.2).astype(cfg.dtype),
        "conv_b": jnp.zeros((conv_dim,), cfg.dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),  # softplus^-1
        "norm": {"scale": jnp.ones((di,), jnp.float32)},
    }


def init_ssm_cache(cfg, batch: int) -> dict:
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    return {
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, D_CONV - 1, di + 2 * N), cfg.dtype),
    }


def _lin(p, x, collect, path):
    if collect is not None:
        record_act_stats(collect, path, x)
    return apply_linear(p, x)


def _preprocess(p, cfg, u, conv_state, collect, path):
    """Shared projections: returns (z, x, Bm, Cm, dt, xBC_pad)."""
    B, T, _ = u.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = _lin(p["in_proj"], u, collect, f"{path}/in_proj")
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * N]
    dt_raw = zxbcdt[..., 2 * di + 2 * N :].astype(jnp.float32)            # (B,T,H)

    # causal depthwise conv of width 4 over time (with cached tail)
    if conv_state is None:
        conv_state = jnp.zeros((B, D_CONV - 1, di + 2 * N), xBC.dtype)
    xBC_pad = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)  # (B, T+3, C)
    w = p["conv_w"].astype(jnp.float32)
    conv = sum(
        xBC_pad[:, i : i + T].astype(jnp.float32) * w[i] for i in range(D_CONV)
    )
    xBC_c = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(u.dtype)

    x = xBC_c[..., :di].reshape(B, T, H, P)
    Bm = xBC_c[..., di : di + N].astype(jnp.float32)                      # (B,T,N)
    Cm = xBC_c[..., di + N :].astype(jnp.float32)                         # (B,T,N)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                           # (B,T,H)
    return z, x, Bm, Cm, dt, xBC_pad


def _ssd_sequential(x, Bm, Cm, dt, A, h0, collect_states: bool):
    """Step recurrence. x (B,T,H,P) f32; returns (y, h_T or states_all)."""
    a = jnp.exp(dt * (-A))                                                # (B,T,H)

    def step(h, inp):
        x_t, B_t, C_t, dt_t, a_t = inp
        h = a_t[..., None, None] * h + (dt_t[..., None] * x_t)[..., None] * B_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, C_t)
        return h, (y, h if collect_states else 0.0)

    xs = (
        jnp.moveaxis(x, 1, 0), jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0),
        jnp.moveaxis(dt, 1, 0), jnp.moveaxis(a, 1, 0),
    )
    hT, (ys, hs) = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                                            # (B,T,H,P)
    states = jnp.moveaxis(hs, 0, 1) if collect_states else hT
    return y, states


def _ssd_chunked(x, Bm, Cm, dt, A, h0, chunk: int):
    """SSD chunked algorithm. All f32. Returns (y (B,T,H,P), h_T)."""
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    Tp = -(-T // Q) * Q
    if Tp != T:  # pad: dt=0 ⇒ a=1, x=0 ⇒ state untouched
        pad = Tp - T
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = Tp // Q
    xc = x.reshape(B, nc, Q, H, P)
    Bc = Bm.reshape(B, nc, Q, N)
    Cc = Cm.reshape(B, nc, Q, N)
    dtc = dt.reshape(B, nc, Q, H)
    alog = dtc * (-A)                                                     # (B,nc,Q,H)
    cs = jnp.cumsum(alog, axis=2)                                         # ℓ_t (inclusive)

    # intra-chunk (quadratic, attention-like)
    decay = cs[:, :, :, None, :] - cs[:, :, None, :, :]                   # ℓ_t - ℓ_s (B,nc,t,s,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    att = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
    cb = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)                            # (B,nc,t,s)
    w = att * cb[..., None] * dtc[:, :, None, :, :]                       # (B,nc,t,s,H)
    y_intra = jnp.einsum("bctsh,bcshp->bcthp", w, xc)

    # chunk states
    last = cs[:, :, -1:, :]                                               # ℓ_Q
    sdecay = jnp.exp(last - cs)                                           # (B,nc,Q,H)
    S = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", sdecay * dtc, xc, Bc)        # (B,nc,H,P,N)

    # inter-chunk recurrence
    a_chunk = jnp.exp(last[:, :, 0, :])                                   # (B,nc,H)

    def step(h, inp):
        S_c, a_c = inp
        h_out = h                                                         # state before this chunk
        h = a_c[..., None, None] * h + S_c
        return h, h_out

    hT, h_before = jax.lax.scan(
        step, h0, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(a_chunk, 1, 0))
    )
    h_before = jnp.moveaxis(h_before, 0, 1)                               # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(cs), Cc, h_before)

    y = (y_intra + y_inter).reshape(B, Tp, H, P)[:, :T]
    return y, hT


def apply_ssm(
    p: dict,
    cfg,
    u,                        # (B, T, D)
    *,
    cache: dict | None = None,
    collect_states: bool = False,
    collect=None,
    path: str = "",
):
    """Returns (out (B,T,D), cache').

    With ``collect_states=True`` (speculative verify) the returned cache is
    a *candidate*: ``{"states_all": (B,T,H,P,N), "xbc_pad": (B,T+3,·)}`` to
    be resolved by :func:`commit_ssm_cache`.
    """
    B, T, D = u.shape
    di = cfg.d_inner
    conv_state = cache["conv"] if cache is not None else None
    h0 = cache["state"] if cache is not None else jnp.zeros(
        (B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
    )
    z, x, Bm, Cm, dt, xBC_pad = _preprocess(p, cfg, u, conv_state, collect, path)
    A = jnp.exp(p["A_log"])                                               # (H,) > 0
    xf = x.astype(jnp.float32)

    if T <= 16:
        y, states = _ssd_sequential(xf, Bm, Cm, dt, A, h0, collect_states)
    else:
        y, states = _ssd_chunked(xf, Bm, Cm, dt, A, h0, cfg.ssm_chunk)
        if collect_states:
            raise ValueError("collect_states requires the sequential path (T<=16)")

    y = y + p["D_skip"][None, None, :, None] * xf                         # skip connection
    y = y.reshape(B, T, di)
    y = rms_norm(y.astype(u.dtype), p["norm"]["scale"], cfg.norm_eps)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = _lin(p["out_proj"], y, collect, f"{path}/out_proj")

    new_cache = None
    if cache is not None:
        if collect_states:
            new_cache = {"states_all": states, "xbc_pad": xBC_pad}
        else:
            new_cache = {"state": states, "conv": xBC_pad[:, -(D_CONV - 1):]}
    return out, new_cache


def commit_ssm_cache(cand: dict, n_last: jax.Array) -> dict:
    """Resolve a verify candidate: keep the state after window token
    ``n_last`` (per row) and the conv tail ending at that token."""
    B = n_last.shape[0]
    bidx = jnp.arange(B)
    state = cand["states_all"][bidx, n_last]                              # (B,H,P,N)
    # conv tail = raw xBC inputs for tokens n-2..n  (pad offset: token t ↔ slot t+3)
    idx = n_last[:, None] + 1 + jnp.arange(D_CONV - 1)[None, :]           # (B,3)
    conv = jnp.take_along_axis(cand["xbc_pad"], idx[:, :, None], axis=1)
    return {"state": state, "conv": conv}
