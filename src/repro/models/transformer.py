"""Composable decoder stack covering all six assigned arch families.

Layer kinds are derived from the :class:`ModelConfig`:

* ``dense``  — self-attn + FFN                     (stablelm, smollm, codeqwen)
* ``moe``    — self-attn + MoE FFN (+ dense residual)  (phi3.5, arctic, moonshot)
* ``ssm``    — Mamba2/SSD block                    (mamba2; zamba2 backbone)
* ``cross``  — cross-attn + FFN every k-th layer   (llama-3.2-vision)
* ``audio``  — self-attn + cross-attn + FFN        (whisper decoder)

zamba2 (hybrid) additionally applies a *shared* attention block (single
param set) after every ``attn_every``-th SSM layer, each application with
its own KV cache slot.  whisper gets a bidirectional encoder stack whose
output feeds the decoder cross-attention.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (
    cross_attention,
    init_attention,
    init_attn_cache,
    self_attention,
)
from repro.models.common import apply_norm, embed_init, init_norm
from repro.models.ffn import apply_ffn, init_ffn
from repro.models.linear import apply_linear, init_linear
from repro.models.moe import apply_moe, init_moe
from repro.models.ssm import apply_ssm, commit_ssm_cache, init_ssm, init_ssm_cache
from repro.quant.smoothquant import record_act_stats


# ---------------------------------------------------------------------------
# Layer census
# ---------------------------------------------------------------------------

def layer_kinds(cfg) -> List[str]:
    kinds = []
    for i in range(cfg.num_layers):
        if cfg.arch_type in ("ssm", "hybrid"):
            kinds.append("ssm")
        elif cfg.arch_type == "audio":
            kinds.append("audio")
        elif cfg.cross_attn_every and (i + 1) % cfg.cross_attn_every == 0:
            kinds.append("cross")
        elif cfg.is_moe:
            kinds.append("moe")
        else:
            kinds.append("dense")
    return kinds


def shared_attn_positions(cfg) -> List[int]:
    if cfg.arch_type != "hybrid" or not cfg.attn_every:
        return []
    return [i for i in range(cfg.num_layers) if (i + 1) % cfg.attn_every == 0]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg, kind: str) -> dict:
    ks = jax.random.split(key, 4)
    if kind == "ssm":
        return {"norm": init_norm(cfg, cfg.d_model), "ssm": init_ssm(ks[0], cfg)}
    if kind == "cross":
        return {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "cross": init_attention(ks[0], cfg, cross=True),
            "gate_attn": jnp.zeros((), jnp.float32),  # llama-3.2 tanh gate
            "ffn_norm": init_norm(cfg, cfg.d_model),
            "ffn": init_ffn(ks[1], cfg),
            "gate_ffn": jnp.zeros((), jnp.float32),
        }
    if kind == "audio":
        return {
            "attn_norm": init_norm(cfg, cfg.d_model),
            "attn": init_attention(ks[0], cfg),
            "cross_norm": init_norm(cfg, cfg.d_model),
            "cross": init_attention(ks[1], cfg, cross=True),
            "ffn_norm": init_norm(cfg, cfg.d_model),
            "ffn": init_ffn(ks[2], cfg),
        }
    block = {
        "attn_norm": init_norm(cfg, cfg.d_model),
        "attn": init_attention(ks[0], cfg),
        "ffn_norm": init_norm(cfg, cfg.d_model),
    }
    if kind == "moe":
        block["moe"] = init_moe(ks[1], cfg)
    else:
        block["ffn"] = init_ffn(ks[1], cfg)
    return block


def init_params(key, cfg) -> dict:
    kinds = layer_kinds(cfg)
    keys = jax.random.split(key, cfg.num_layers + 4)
    params = {
        "embed": {"w": embed_init(keys[-1], (cfg.vocab_size, cfg.d_model), cfg.dtype)},
        "layers": [_init_block(keys[i], cfg, kinds[i]) for i in range(cfg.num_layers)],
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(keys[-2], cfg.d_model, cfg.vocab_size, False, cfg.dtype)
    if shared_attn_positions(cfg):
        params["shared_attn"] = _init_block(keys[-3], cfg, "dense")
    if cfg.encoder_layers:
        ek = jax.random.split(keys[-4], cfg.encoder_layers + 1)
        params["encoder"] = {
            "layers": [_init_block(ek[i], cfg, "dense") for i in range(cfg.encoder_layers)],
            "norm": init_norm(cfg, cfg.d_model),
        }
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int, num_layers: Optional[int] = None) -> dict:
    """Allocate the serving cache pytree.  ``max_len`` is rounded up so the
    chunked-attention path (multiples of 1024) always applies to big buffers."""
    max_len = -(-max_len // 1024) * 1024 if max_len > 4096 else -(-max_len // 128) * 128
    kinds = layer_kinds(cfg)[: num_layers or cfg.num_layers]
    w = cfg.sliding_window
    layers = []
    for kind in kinds:
        if kind == "ssm":
            layers.append(init_ssm_cache(cfg, batch))
        elif kind == "cross":
            layers.append({
                "ck": jnp.zeros((batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
                "cv": jnp.zeros((batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
            })
        elif kind == "audio":
            layers.append({
                "self": init_attn_cache(cfg, batch, max_len, w),
                "ck": jnp.zeros((batch, cfg.num_audio_frames, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
                "cv": jnp.zeros((batch, cfg.num_audio_frames, cfg.num_kv_heads, cfg.head_dim), cfg.dtype),
            })
        else:
            layers.append(init_attn_cache(cfg, batch, max_len, w))
    cache = {"layers": layers}
    shared = shared_attn_positions(cfg)
    if shared and (num_layers is None or any(i < num_layers for i in shared)):
        cache["shared"] = [
            init_attn_cache(cfg, batch, max_len, w) for i in shared
            if num_layers is None or i < num_layers
        ]
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _maybe(c, key, default=None):
    return c[key] if (c is not None and key in c) else default


def _apply_block(
    kind: str,
    blk: dict,
    cfg,
    x,
    qpos,
    lcache,
    *,
    read_cache: bool = True,
    collect_states: bool = False,
    enc_out=None,
    collect=None,
    path: str = "",
    slots=None,
    tree_mask=None,
    win_start=None,
    block_tables=None,
):
    """One decoder block of any kind.  Returns (x, new_cache, aux)."""
    w = cfg.sliding_window
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, lcache = apply_ssm(
            blk["ssm"], cfg, apply_norm(cfg, blk["norm"], x),
            cache=lcache, collect_states=collect_states,
            collect=collect, path=f"{path}/ssm",
        )
        x = x + h
    elif kind == "cross":
        h, lcache = cross_attention(
            blk["cross"], cfg, apply_norm(cfg, blk["attn_norm"], x),
            kv_embeds=enc_out, cache=lcache, collect=collect, path=f"{path}/cross",
        )
        x = x + jnp.tanh(blk["gate_attn"]).astype(x.dtype) * h
        h = apply_ffn(blk["ffn"], cfg, apply_norm(cfg, blk["ffn_norm"], x),
                      collect, f"{path}/ffn")
        x = x + jnp.tanh(blk["gate_ffn"]).astype(x.dtype) * h
    elif kind == "audio":
        sc = _maybe(lcache, "self")
        h, sc = self_attention(
            blk["attn"], cfg, apply_norm(cfg, blk["attn_norm"], x), qpos,
            cache=sc, read_cache=read_cache, window=w,
            collect=collect, path=f"{path}/attn",
            slots=slots, tree_mask=tree_mask, win_start=win_start,
        )
        x = x + h
        ccache = {"ck": lcache["ck"], "cv": lcache["cv"]} if lcache is not None else None
        h, ccache = cross_attention(
            blk["cross"], cfg, apply_norm(cfg, blk["cross_norm"], x),
            kv_embeds=enc_out, cache=ccache, collect=collect, path=f"{path}/cross",
        )
        x = x + h
        x = x + apply_ffn(blk["ffn"], cfg, apply_norm(cfg, blk["ffn_norm"], x),
                          collect, f"{path}/ffn")
        if lcache is not None:
            lcache = {"self": sc, **(ccache or {})}
    else:  # dense | moe (self-attn + FFN/MoE)
        h, lcache = self_attention(
            blk["attn"], cfg, apply_norm(cfg, blk["attn_norm"], x), qpos,
            cache=lcache, read_cache=read_cache, window=w,
            collect=collect, path=f"{path}/attn",
            slots=slots, tree_mask=tree_mask, win_start=win_start,
            block_tables=block_tables,
        )
        x = x + h
        xn = apply_norm(cfg, blk["ffn_norm"], x)
        if kind == "moe":
            h, aux = apply_moe(blk["moe"], cfg, xn, collect, f"{path}/moe")
        else:
            h = apply_ffn(blk["ffn"], cfg, xn, collect, f"{path}/ffn")
        x = x + h
    return x, lcache, aux


def _apply_shared(sp: dict, cfg, x, qpos, scache, *, read_cache=True,
                  collect=None, path: str = ""):
    """zamba2 shared attention+FFN block (single param set, per-app cache)."""
    h, scache = self_attention(
        sp["attn"], cfg, apply_norm(cfg, sp["attn_norm"], x), qpos,
        cache=scache, read_cache=read_cache, window=cfg.sliding_window,
        collect=collect, path=f"{path}/attn",
    )
    x = x + h
    x = x + apply_ffn(sp["ffn"], cfg, apply_norm(cfg, sp["ffn_norm"], x),
                      collect, f"{path}/ffn")
    return x, scache, jnp.zeros((), jnp.float32)


def forward(
    params: dict,
    cfg,
    tokens: jax.Array,                 # (B, T) int32
    start: jax.Array,                  # (B,) absolute position of tokens[:, 0]
    *,
    cache: Optional[dict] = None,
    read_cache: bool = True,
    collect_states: bool = False,      # speculative verify (SSM rollback states)
    aux_embeds: Optional[jax.Array] = None,  # (B, Sa, D) image/audio embeddings
    collect=None,                      # SmoothQuant calibration collector
    num_layers: Optional[int] = None,  # structural-pruning baseline (Table 5)
    need_logits: bool = True,          # prefill skips the LM head entirely
    path: str = "",
    tree_depths=None,                  # (T,) node depths of a tree window
    tree_mask=None,                    # (T, T) ancestor-or-self mask
):
    """Returns (logits (B,T,V) or None, new_cache, aux_loss)."""
    B, T = tokens.shape
    # paged serving cache: per-layer physical pools + a shared block table
    # (repro.core.paged_cache).  Decode/verify only — paged prefill is an
    # admission-time scatter, never a forward pass.
    block_tables = cache.get("bt") if cache is not None else None
    if block_tables is not None and not read_cache:
        raise NotImplementedError(
            "paged caches do not support forward-pass prefill; admission "
            "prefills a contiguous row and scatters it into the pool")
    if tree_depths is not None:
        # token-tree verify window: positions follow node *depth* while
        # cache slots follow packed node order (start + arange)
        qpos = start[:, None] + tree_depths[None, :]
        slots = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
        win_start = start
    else:
        qpos = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
        slots = win_start = None
    kinds = layer_kinds(cfg)
    n_layers = num_layers or cfg.num_layers
    w = cfg.sliding_window

    x = params["embed"]["w"][tokens].astype(cfg.dtype)
    aux_total = jnp.zeros((), jnp.float32)

    # encoder (whisper): run once at prefill to produce cross-attn source
    enc_out = None
    if cfg.encoder_layers and aux_embeds is not None:
        enc_out = _encode(params["encoder"], cfg, aux_embeds, collect, f"{path}encoder")
    elif aux_embeds is not None:
        enc_out = aux_embeds.astype(cfg.dtype)

    new_layers = []
    shared_pos = shared_attn_positions(cfg)
    shared_caches = list(_maybe(cache, "shared", []) or [])
    new_shared = []
    shared_i = 0

    for i in range(n_layers):
        lcache = cache["layers"][i] if cache is not None else None
        x, lcache, aux = _apply_block(
            kinds[i], params["layers"][i], cfg, x, qpos, lcache,
            read_cache=read_cache, collect_states=collect_states,
            enc_out=enc_out, collect=collect, path=f"{path}layers/{i}",
            slots=slots, tree_mask=tree_mask, win_start=win_start,
            block_tables=block_tables,
        )
        aux_total = aux_total + aux
        new_layers.append(lcache)

        # zamba2: shared attention block application
        if i in shared_pos:
            sp = params["shared_attn"]
            scache = shared_caches[shared_i] if cache is not None and shared_caches else None
            x, scache, _ = _apply_shared(
                sp, cfg, x, qpos, scache,
                read_cache=read_cache, collect=collect, path=f"{path}shared_attn",
            )
            new_shared.append(scache)
            shared_i += 1

    logits = None
    if need_logits:
        x = apply_norm(cfg, params["final_norm"], x)
        if cfg.tie_embeddings:
            logits = x.astype(jnp.float32) @ params["embed"]["w"].astype(jnp.float32).T
        else:
            if collect is not None:
                record_act_stats(collect, f"{path}lm_head", x)
            logits = apply_linear(params["lm_head"], x).astype(jnp.float32)

    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_layers}
        if "shared" in cache:
            new_cache["shared"] = new_shared
        if block_tables is not None:
            new_cache["bt"] = block_tables   # table is host-managed state
    return logits, new_cache, aux_total


def _encode(enc: dict, cfg, embeds: jax.Array, collect, path: str) -> jax.Array:
    """Bidirectional encoder (whisper): full attention, no cache."""
    B, S, _ = embeds.shape
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    x = embeds.astype(cfg.dtype)
    for i, blk in enumerate(enc["layers"]):
        h, _ = self_attention(
            blk["attn"], cfg, apply_norm(cfg, blk["attn_norm"], x), pos,
            causal=False, collect=collect, path=f"{path}/layers/{i}/attn",
        )
        x = x + h
        x = x + apply_ffn(blk["ffn"], cfg, apply_norm(cfg, blk["ffn_norm"], x),
                          collect, f"{path}/layers/{i}/ffn")
    return apply_norm(cfg, enc["norm"], x)


# ---------------------------------------------------------------------------
# Speculative cache commit
# ---------------------------------------------------------------------------

def commit_cache(cfg, cache: dict, n_last: jax.Array, num_layers: Optional[int] = None) -> dict:
    """Resolve verify-candidate caches after acceptance.

    ``n_last`` (B,) = index (within the verify window) of the last committed
    token.  Attention caches need no work (slot positions + masking handle
    rollback); SSM candidates are gathered to the accepted state.
    """
    kinds = layer_kinds(cfg)[: num_layers or cfg.num_layers]
    layers = []
    for kind, lcache in zip(kinds, cache["layers"]):
        if kind == "ssm" and lcache is not None and "states_all" in lcache:
            layers.append(commit_ssm_cache(lcache, n_last))
        else:
            layers.append(lcache)
    out = {"layers": layers}
    if "shared" in cache:
        out["shared"] = cache["shared"]
    if "bt" in cache:
        out["bt"] = cache["bt"]
    return out


def _compact_attn_rows(lcache: dict, start, path_nodes, n_accept) -> dict:
    """Gather the accepted tree path's K/V rows into chain slots.

    A tree verify window wrote node ``i`` at slot ``start + i`` with RoPE
    position ``start + depth[i]``; an accepted node at depth ``d`` has
    position ``start + d``, which is exactly its committed slot under the
    contiguous slot == position convention — so committing is a pure
    row move ``start + path_nodes[d] → start + d`` (``d ≤ n_accept``),
    no recompute.  Chain templates move rows onto themselves, keeping the
    degenerate path bit-identical to the chain commit (a no-op).
    """
    B, D1 = path_nodes.shape
    D = D1 - 1
    if D == 0:
        return lcache
    S = lcache["k"].shape[1]
    depth = jnp.arange(1, D + 1, dtype=jnp.int32)[None, :]           # (1, D)
    src = jnp.clip(start[:, None] + path_nodes[:, 1:], 0, S - 1)     # (B, D)
    dst = jnp.clip(start[:, None] + depth, 0, S - 1)
    keep = depth <= n_accept[:, None]                                # (B, D)
    bidx = jnp.arange(B)[:, None]
    new = dict(lcache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name not in lcache:
            continue
        buf = lcache[name]
        moved = buf[bidx, src]
        stay = buf[bidx, dst]
        tail = (1,) * (buf.ndim - 2)
        vals = jnp.where(keep.reshape(keep.shape + tail), moved, stay)
        new[name] = buf.at[bidx, dst].set(vals)
    return new


def _compact_attn_rows_paged(lcache: dict, bt, start, path_nodes,
                             n_accept) -> dict:
    """Paged-layout tree commit: the same accepted-path row moves as
    :func:`_compact_attn_rows`, with logical slots translated to pool
    rows through the block table.  Live rows move rows only inside their
    own blocks (``start + node <= start + gamma`` stays within the
    request's reservation); idle rows compact inside the scratch block,
    whose content is never validly read."""
    from repro.core.paged_cache import physical_slots

    B, D1 = path_nodes.shape
    D = D1 - 1
    if D == 0:
        return lcache
    block_size = lcache["k"].shape[1]
    S = bt.shape[1] * block_size
    depth = jnp.arange(1, D + 1, dtype=jnp.int32)[None, :]           # (1, D)
    src = jnp.clip(start[:, None] + path_nodes[:, 1:], 0, S - 1)     # (B, D)
    dst = jnp.clip(start[:, None] + depth, 0, S - 1)
    keep = depth <= n_accept[:, None]                                # (B, D)
    phys_src = physical_slots(bt, src, block_size)
    phys_dst = physical_slots(bt, dst, block_size)
    new = dict(lcache)
    for name in ("k", "v", "k_scale", "v_scale"):
        if name not in lcache:
            continue
        buf = lcache[name]
        flat = buf.reshape((-1,) + buf.shape[2:])
        moved = flat[phys_src]                                       # (B, D, ...)
        stay = flat[phys_dst]
        tail = (1,) * (moved.ndim - 2)
        vals = jnp.where(keep.reshape(keep.shape + tail), moved, stay)
        flat = flat.at[phys_dst.reshape(-1)].set(
            vals.reshape((-1,) + vals.shape[2:]))
        new[name] = flat.reshape(buf.shape)
    return new


def commit_cache_tree(cfg, cache: dict, start, path_nodes, n_accept,
                      num_layers: Optional[int] = None) -> dict:
    """Resolve tree-verify candidate caches: compact the accepted
    root-to-leaf path (see :func:`_compact_attn_rows`).  Attention-family
    layers only — recurrent (ssm/hybrid) caches are gated off by the
    decode-step builder."""
    kinds = layer_kinds(cfg)[: num_layers or cfg.num_layers]
    bt = cache.get("bt")
    layers = []
    for kind, lcache in zip(kinds, cache["layers"]):
        if kind == "ssm":
            raise NotImplementedError(
                "tree speculation does not support recurrent caches")
        if kind == "cross" or lcache is None:
            layers.append(lcache)
        elif kind == "audio":
            layers.append({**lcache, "self": _compact_attn_rows(
                lcache["self"], start, path_nodes, n_accept)})
        elif bt is not None:
            layers.append(_compact_attn_rows_paged(lcache, bt, start,
                                                   path_nodes, n_accept))
        else:
            layers.append(_compact_attn_rows(lcache, start, path_nodes,
                                             n_accept))
    out = {"layers": layers}
    if "shared" in cache:
        raise NotImplementedError(
            "tree speculation does not support shared-attention caches")
    if bt is not None:
        out["bt"] = bt
    return out
