from repro.quant.int8 import quantize_linear, quantize_batched  # noqa: F401
from repro.quant.smoothquant import calibrate, smoothing_factors  # noqa: F401
from repro.quant.apply import quantize_params  # noqa: F401
