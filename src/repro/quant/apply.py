"""Offline weight preparation (paper §3.3): walk the BF16 param pytree and
replace every quantizable linear with its smoothed W8A8 layout."""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core.config import QuantConfig
from repro.quant.int8 import quantize_batched, quantize_linear
from repro.quant.smoothquant import smoothing_factors

# Param-tree path fragments that must stay BF16: tiny and/or precision
# critical.  The router is the MoE dispatch decision (top-k flips are far
# more damaging than GEMM noise); norms/conv are not GEMMs.
_EXCLUDE = ("router", "embed", "norm", "conv", "A_log", "D_skip", "dt_bias")


def _excluded(path: str, qcfg: QuantConfig) -> bool:
    if qcfg.quantize_embedding and "embed" in path:
        return False
    return any(tag in path for tag in _EXCLUDE)


def quantize_params(
    params,
    act_stats: Optional[Dict[str, jnp.ndarray]] = None,
    qcfg: QuantConfig = QuantConfig(),
):
    """Return a new param pytree with W8A8 linears.

    ``act_stats`` maps apply-site paths (as recorded during calibration) to
    per-input-channel activation maxima; linears without stats fall back to
    s = 1 (weight-only smoothing).
    """
    act_stats = act_stats or {}

    def walk(node, path: str):
        if isinstance(node, dict):
            if "w" in node and not isinstance(node["w"], dict):
                w = node["w"]
                if w.ndim >= 2 and not _excluded(path, qcfg):
                    s = smoothing_factors(w, act_stats.get(path), qcfg.alpha)
                    if w.ndim == 3:
                        return quantize_batched(node, s)
                    if qcfg.w_bits == 4 and w.shape[0] % 2 == 0:
                        from repro.quant.int4 import quantize_linear_w4
                        return quantize_linear_w4(node, s)
                    return quantize_linear(node, s)
                return node
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            out = [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(out) if isinstance(node, tuple) else out
        return node

    return walk(params, "")
