"""W4A8: 4-bit weight quantization for the verifier (paper §6 future work —
"Ultra-low Bit Verification").

Weights are symmetric-quantized to [-7, 7] per output channel and PACKED
two nibbles per int8 byte along the input dim, so the stored (and
HBM-streamed) weight bytes are 0.25× BF16 / 0.5× W8A8.  Activations stay
INT8 (the W8A8 smooth+quant path); the GEMM unpacks nibbles on the fly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ref import EPS

INT4_MAX = 7.0


def quantize_symmetric_int4(x: jax.Array, axis: int):
    """Returns (q int8 in [-7,7], scale) — unpacked representation."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=axis)
    scale = jnp.maximum(amax, EPS) / INT4_MAX
    q = jnp.clip(jnp.round(x32 / jnp.expand_dims(scale, axis)), -INT4_MAX, INT4_MAX)
    return q.astype(jnp.int8), scale


def pack_int4(q: jax.Array) -> jax.Array:
    """(din, dout) int8 in [-7,7] → (din/2, dout) packed (low | high<<4)."""
    din = q.shape[0]
    assert din % 2 == 0, din
    lo = q[0::2].astype(jnp.uint8) & 0xF
    hi = (q[1::2].astype(jnp.uint8) & 0xF) << 4
    return (lo | hi).astype(jnp.int8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of pack_int4: (din/2, dout) → (din, dout) int8 in [-7,7].

    Sign extension via arithmetic shifts: (x << 4) >> 4 on int8.
    """
    p = packed.astype(jnp.int8)
    lo = jnp.left_shift(p, 4)
    lo = jnp.right_shift(lo, 4)                     # arithmetic shift: sign-extends
    hi = jnp.right_shift(p, 4)
    din2, dout = packed.shape
    out = jnp.stack([lo, hi], axis=1).reshape(din2 * 2, dout)
    return out


def quantize_linear_w4(p: dict, smooth: jax.Array) -> dict:
    """BF16 linear → W4A8 layout {"w_int4", "w_scale", "smooth" [, "b"]}."""
    w = p["w"].astype(jnp.float32) / smooth[:, None]
    q, scale = quantize_symmetric_int4(w, axis=0)
    out = {
        "w_int4": pack_int4(q),
        "w_scale": scale,
        "smooth": smooth.astype(jnp.float32),
    }
    if "b" in p:
        out["b"] = p["b"]
    return out


def w4a8_matmul(x: jax.Array, w_int4: jax.Array, w_scale: jax.Array,
                smooth: jax.Array) -> jax.Array:
    """(…, K) × packed (K/2, N) → (…, N); INT8 activations, unpacked-int4
    weights, int32 accumulation, fused dequant (mirrors w8a8_matmul)."""
    from repro.kernels.ref import smooth_quant_ref

    batch_shape = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    xq, dx = smooth_quant_ref(x2, smooth)
    w = unpack_int4(w_int4)                         # int8 in [-7, 7]
    acc = jax.lax.dot_general(
        xq, w, dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    y = acc.astype(jnp.float32) * dx[:, None] * w_scale[None, :]
    return y.astype(x.dtype).reshape(*batch_shape, w.shape[1])
