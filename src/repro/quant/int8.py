"""Symmetric INT8 weight quantization (paper Eq. 6) for linear param dicts."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ref import quantize_symmetric


def quantize_linear(p: dict, smooth: jnp.ndarray) -> dict:
    """Quantize a 2D linear param dict ``{"w": (din, dout) [, "b"]}``.

    Applies the offline smoothing ``W·diag(s)^-1`` first (paper §3.3), then
    symmetric per-output-channel quantization.  Returns the W8A8 layout
    consumed by :func:`repro.models.linear.apply_linear`.
    """
    w = p["w"].astype(jnp.float32) / smooth[:, None]
    w_int8, w_scale = quantize_symmetric(w, axis=0)   # per-out-channel Δw (dout,)
    q = {"w_int8": w_int8, "w_scale": w_scale, "smooth": smooth.astype(jnp.float32)}
    if "b" in p:
        q["b"] = p["b"]
    return q


def quantize_batched(p: dict, smooth: jnp.ndarray) -> dict:
    """Quantize batched expert weights ``{"w": (E, din, dout)}``.

    Per-expert per-output-channel scales ``(E, dout)``; the smoothing vector
    ``s (din,)`` is shared across experts (calibration statistics are
    collected on the pre-dispatch activations).
    """
    w = p["w"].astype(jnp.float32) / smooth[None, :, None]
    w_int8, w_scale = quantize_symmetric(w, axis=1)   # (E, dout)
    q = {"w_int8": w_int8, "w_scale": w_scale, "smooth": smooth.astype(jnp.float32)}
    if "b" in p:
        q["b"] = p["b"]
    return q
