"""Enhanced-SmoothQuant ("m2") offline calibration (paper §3.2).

Calibration runs the BF16 model eagerly over a few batches with a mutable
``collect`` dict threaded through the forward pass; every linear apply-site
records the per-input-channel absolute max of its activations under its
param-tree path.  :func:`smoothing_factors` then computes

    s_j = max|X_j|^alpha / max|W_j|^(1-alpha)        (Eq. 5)

per input channel j, balancing quantization difficulty between activations
and weights.
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable

import jax.numpy as jnp

EPS = 1e-5


def record_act_stats(collect: Dict[str, jnp.ndarray], path: str, x: jnp.ndarray) -> None:
    """Apply-site hook: fold |x| channel maxima into the collector."""
    a = jnp.max(jnp.abs(x.astype(jnp.float32)).reshape(-1, x.shape[-1]), axis=0)
    prev = collect.get(path)
    collect[path] = a if prev is None else jnp.maximum(prev, a)


def calibrate(forward_fn: Callable, batches: Iterable) -> Dict[str, jnp.ndarray]:
    """Run ``forward_fn(batch, collect)`` eagerly over calibration batches."""
    collect: Dict[str, jnp.ndarray] = {}
    for batch in batches:
        forward_fn(batch, collect)
    return collect


def smoothing_factors(
    w: jnp.ndarray,            # (din, dout) or (E, din, dout)
    act_amax: jnp.ndarray | None,  # (din,) from calibration, or None
    alpha: float = 0.5,
) -> jnp.ndarray:
    """Per-input-channel smoothing vector s (Eq. 5).

    The "m2" enhancement: clamp the factors into [1/8, 8] so that channels
    with degenerate statistics (never activated during calibration, or
    all-zero weight columns) cannot blow up either operand's range, and
    fall back to s = 1 when no activation statistics exist.
    """
    din = w.shape[-2]
    if act_amax is None:
        return jnp.ones((din,), jnp.float32)
    w32 = jnp.abs(w.astype(jnp.float32))
    w_amax = jnp.max(w32.reshape(-1, din, w.shape[-1]), axis=(0, 2))  # max|W_j| over out (+experts)
    s = jnp.power(jnp.maximum(act_amax, EPS), alpha) / jnp.power(
        jnp.maximum(w_amax, EPS), 1.0 - alpha
    )
    s = jnp.clip(s, 0.125, 8.0)
    return s.astype(jnp.float32)
