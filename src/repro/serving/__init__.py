from repro.serving.engine import GenResult, SpecEngine  # noqa: F401
from repro.serving.request import (  # noqa: F401
    GenerationRequest,
    RequestResult,
    pack_prompts,
)
