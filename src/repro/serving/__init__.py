from repro.serving.engine import (  # noqa: F401
    DEFAULT_BATCH_SLOTS,
    GenResult,
    SpecEngine,
    merge_state_rows,
)
from repro.serving.faults import (  # noqa: F401
    NULL_FAULTS,
    FaultPlan,
    FaultRule,
    InjectedFault,
    LaneCrashed,
    NullFaultPlan,
    RequestCancelled,
    RequestFault,
    RequestTimeout,
    VerifierNaNError,
)
from repro.serving.histogram import Histogram  # noqa: F401
from repro.serving.metrics import (  # noqa: F401
    AcceptanceStats,
    RequestTimeline,
    ServerMetrics,
    percentile,
)
from repro.serving.request import (  # noqa: F401
    GenerationRequest,
    RequestResult,
    pack_prompts,
    pad_prompt,
    safe_rate,
)
from repro.serving.scheduler import Scheduler, SlotEvent  # noqa: F401
from repro.serving.server import (  # noqa: F401
    ServerConfig,
    ServingLoop,
    StreamHandle,
    StreamingServer,
)
from repro.serving.trace import (  # noqa: F401
    NULL_TRACER,
    NullTracer,
    Tracer,
)
