from repro.serving.engine import (  # noqa: F401
    DEFAULT_BATCH_SLOTS,
    GenResult,
    SpecEngine,
)
from repro.serving.request import (  # noqa: F401
    GenerationRequest,
    RequestResult,
    pack_prompts,
    pad_prompt,
)
from repro.serving.scheduler import Scheduler, SlotEvent  # noqa: F401
