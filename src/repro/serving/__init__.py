from repro.serving.engine import SpecEngine  # noqa: F401
