"""Batched speculative serving engine over the pluggable decoding API.

``SpecEngine`` wraps the unified jitted decode step
(:func:`repro.core.spec_engine.make_decode_step`) with prompt prefill,
the generation loop, and acceptance/throughput statistics.  Drafting and
verification strategies are plugins resolved from the registries in
``repro.core.protocols``:

    engine = SpecEngine(model, SpecConfig(verifier="w8a8"))   # Quasar
    engine = SpecEngine(model, scfg, drafter="pruned")        # Table 5
    engine = SpecEngine(model, scfg, drafter=MyDrafter(...))  # custom
    engine = SpecEngine(                                      # token tree
        model, SpecConfig(tree_branches=(3, 2, 1, 1)), drafter="ngram-tree")

The verifier owns offline weight preparation: with ``verifier="w8a8"``
the engine quantizes BF16 params internally (SmoothQuant + INT8) on first
use — callers never invoke ``quantize_params`` by hand.

Two serving entry points:

* :meth:`generate` — one homogeneous batch ``(B, P)`` of prompts, shared
  token budget (the benchmark/table workhorse);
* :meth:`generate_requests` — a list of
  :class:`~repro.serving.request.GenerationRequest` with heterogeneous
  prompt lengths, budgets, seeds and temperatures, served through the
  continuous-batching :class:`~repro.serving.scheduler.Scheduler`: a
  fixed number of batch slots steps in one jit-compiled loop, and
  whenever a row exhausts its budget the next pending request is admitted
  into the freed slot via :meth:`prefill_into_slot` — the decode step
  never retraces on admission (``step_traces`` counts compilations).
  Returns per-request :class:`~repro.serving.request.RequestResult` with
  queue/service timing.

The legacy ``mode=`` constructor argument ("spec" | "vanilla" |
"pruned") remains as a deprecated shim: it maps to the matching drafter
with a passthrough BF16 verifier (params prepared by the caller), which
is exactly the seed-era behaviour.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import prng
from repro.core.config import SpecConfig
from repro.core.paged_cache import (
    SCRATCH_BLOCK,
    BlockPool,
    PrefixIndex,
    clone_block,
    init_paged_cache,
    plan_group,
    request_demand_tokens,
    scatter_prefill_rows,
    swap_in_blocks,
    swap_out_blocks,
)
from repro.core.protocols import get_drafter, get_verifier
from repro.core.spec_engine import init_state, make_decode_step
from repro.serving.request import (
    GenerationRequest,
    RequestResult,
    pad_prompt,
    safe_rate,
)
from repro.serving.metrics import AcceptanceStats
from repro.serving.scheduler import Scheduler
from repro.serving.trace import NULL_TRACER

# deprecated mode-string → drafter-registry-name mapping (public: the serve
# CLI builds its --mode choices from it)
LEGACY_MODES = {"spec": "ngram", "vanilla": "vanilla", "pruned": "pruned"}
_MAX_TEMP_STEPS = 8        # bound on per-temperature compiled-step cache
DEFAULT_BATCH_SLOTS = 8    # decode rows per scheduler loop (memory bound)


@dataclass
class GenResult:
    tokens: jnp.ndarray          # (B, S_buf) full buffers
    lengths: jnp.ndarray         # (B,)
    mean_accept_len: float       # L — committed tokens per verify step
    steps: int                   # verify steps taken
    wall_s: float
    new_tokens: int

    @property
    def tokens_per_s(self) -> float:
        # 0.0 (not a divide-by-zero spike) when a fast CPU run records
        # zero wall time
        return safe_rate(self.new_tokens, self.wall_s)


class SpecEngine:
    """Drafter x Verifier serving engine (see module docstring)."""

    def __init__(self, model, scfg: SpecConfig = SpecConfig(),
                 mode: Optional[str] = None, *,
                 drafter=None, verifier=None):
        self.model = model
        self.scfg = scfg
        self.mode = mode
        if scfg.kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {scfg.kv_layout!r}; "
                             "expected 'contiguous' or 'paged'")
        if mode is not None:                       # deprecated shim
            if mode not in LEGACY_MODES:
                raise ValueError(mode)
            drafter = drafter if drafter is not None else LEGACY_MODES[mode]
            # legacy callers quantize params themselves: passthrough prepare
            verifier = verifier if verifier is not None else "bf16"
        self.drafter = get_drafter(
            drafter if drafter is not None else scfg.drafter, scfg)
        self.verifier = get_verifier(
            verifier if verifier is not None else scfg.verifier, scfg)
        # decode-step (re)compilations across all temperature variants —
        # the continuous-batching tests assert admission never bumps this
        self.step_traces = 0
        # per-group sizing of the last generate_requests call
        self.group_stats = []
        # live per drafter×verifier acceptance/step-time telemetry,
        # accumulated across generate / generate_requests / serving-lane
        # calls (bounded histograms; benchmarks/run.py reads this)
        self.telemetry = AcceptanceStats()
        self._step = self._jit_counted(
            make_decode_step(model, self.drafter, self.verifier, scfg))
        self._steps_by_temp = {}                   # temperature overrides
        self._fallback_steps = {}                  # bf16 guardrail twins
        self._prepared = None                      # (params ref, prepared)

    def _jit_counted(self, step_fn):
        """jit the decode step, counting traces (== XLA compilations)."""
        def counted(params, state):
            self.step_traces += 1      # runs at trace time only
            return step_fn(params, state)
        return jax.jit(counted)

    # ------------------------------------------------------------------
    def prepare_params(self, params, act_stats=None):
        """Offline weight preparation for this engine's verifier
        (e.g. SmoothQuant + INT8 for ``w8a8``).  Idempotent."""
        return self.verifier.prepare(self.model, params, act_stats)

    def _prepare_cached(self, params):
        # NOTE: keeps a strong reference to the last input tree as the
        # cache key, so a w8a8 engine pins the BF16 original while alive.
        # Memory-sensitive callers: params = engine.prepare_params(params)
        # once, drop the original, and pass the prepared tree (idempotent).
        if self._prepared is not None and (
                params is self._prepared[0] or params is self._prepared[1]):
            return self._prepared[1]
        self._prepared = (params, self.prepare_params(params))
        return self._prepared[1]

    def _step_for_temperature(self, t: float):
        """(jitted step, drafter) with temperature ``t`` baked in."""
        if t == self.scfg.temperature:
            return self._step, self.drafter
        if t not in self._steps_by_temp:
            if len(self._steps_by_temp) >= _MAX_TEMP_STEPS:
                # each entry pins a compiled executable — evict the oldest
                self._steps_by_temp.pop(next(iter(self._steps_by_temp)))
            scfg_t = dataclasses.replace(self.scfg, temperature=t)
            drafter = self.drafter.with_temperature(t)
            step = self._jit_counted(
                make_decode_step(self.model, drafter, self.verifier, scfg_t))
            self._steps_by_temp[t] = (step, drafter)
        return self._steps_by_temp[t]

    def fallback_step_for(self, t: float):
        """Full-precision twin of the compiled step at temperature
        ``t``: same model and drafter, bf16 (passthrough) verifier.

        The serving lane's NaN guardrail retries a tripped step through
        it with the *raw* (unprepared) params — quantized-verification
        graceful degradation: the losslessness contract enforced at
        runtime instead of assumed (docs/robustness.md).  Lazily
        compiled on first trip and cached per temperature; compilation
        bumps ``step_traces``, but only ever after a fault, so the
        no-retrace-on-admission invariant is untouched.
        """
        t = float(t)
        if t not in self._fallback_steps:
            if len(self._fallback_steps) >= _MAX_TEMP_STEPS:
                self._fallback_steps.pop(next(iter(self._fallback_steps)))
            drafter = (self.drafter if t == self.scfg.temperature
                       else self.drafter.with_temperature(t))
            scfg_t = dataclasses.replace(self.scfg, temperature=t,
                                         verifier="bf16")
            self._fallback_steps[t] = self._jit_counted(
                make_decode_step(self.model, drafter, "bf16", scfg_t))
        return self._fallback_steps[t]

    # ------------------------------------------------------------------
    def _init_state(self, params, prompts, lengths, targets, buf, key, *,
                    drafter, aux_embeds=None, draft_params=None):
        """Prefill + assemble the decode-loop state pytree."""
        B, P = prompts.shape
        assert P >= 2, "prompts must have >= 2 tokens"
        state = init_state(self.model, B, buf, key, target=targets)
        state["tokens"] = state["tokens"].at[:, :P].set(prompts)
        state["length"] = jnp.asarray(lengths, jnp.int32)
        # cache covers committed tokens *except the last* (which becomes
        # the first token of the first verify window) — hence [:, :-1]
        state["cache"] = self.model.prefill(
            params, state["cache"], prompts[:, :-1], aux_embeds=aux_embeds)
        state["drafter_state"] = drafter.init_state(
            self.model, params, prompts, buf,
            aux_embeds=aux_embeds, draft_params=draft_params)
        return state

    def _run(self, step, params, state, max_steps: int):
        """Drive the jitted step until every row reaches its target,
        feeding per-step accepted-length/wall-time telemetry (host-side,
        from the same post-step length read the loop already does)."""
        tkey = f"{self.drafter.name}:{self.verifier.name}"
        targets = np.asarray(state["target"])
        prev = np.minimum(np.asarray(state["length"]), targets)
        t0 = time.perf_counter()
        steps = 0
        while True:
            t_s = time.perf_counter()
            state = step(params, state)
            lengths = np.asarray(state["length"])
            step_s = time.perf_counter() - t_s
            steps += 1
            cur = np.minimum(lengths, targets)
            active = prev < targets
            self.telemetry.on_decode_step(
                tkey, (cur - prev)[active].tolist(), step_s)
            prev = cur
            if bool((lengths >= targets).all()):
                break
            if steps > max_steps:      # safety: >= 1 token/step guaranteed
                break
        jax.block_until_ready(state["tokens"])
        return state, time.perf_counter() - t0

    # ------------------------------------------------------------------
    def generate(
        self,
        params,
        prompts: jnp.ndarray,          # (B, P) int32
        max_new_tokens: Optional[int] = None,
        *,
        aux_embeds=None,
        key=None,
        draft_params=None,             # pruned drafting with separate params
    ) -> GenResult:
        """Homogeneous batch: shared prompt length and token budget."""
        max_new = max_new_tokens or self.scfg.max_new_tokens
        B, P = prompts.shape
        buf = P + max_new + self.drafter.gamma + 2
        key = key if key is not None else jax.random.PRNGKey(0)

        params = self._prepare_cached(params)
        lengths = jnp.full((B,), P, jnp.int32)
        targets = jnp.full((B,), P + max_new, jnp.int32)
        state = self._init_state(params, prompts, lengths, targets, buf, key,
                                 drafter=self.drafter, aux_embeds=aux_embeds,
                                 draft_params=draft_params)
        state, wall = self._run(self._step, params, state, max_new * 2 + 8)

        commits = state["stats"]["commits"]
        n_steps = int(state["stats"]["steps"])
        # per-row denominator: steps while that row was still generating
        L = float(jnp.mean(
            commits / jnp.maximum(state["stats"]["row_steps"], 1)))
        new_tokens = int(jnp.sum(jnp.minimum(state["length"], P + max_new) - P))
        return GenResult(
            tokens=state["tokens"],
            lengths=state["length"],
            mean_accept_len=L,
            steps=n_steps,
            wall_s=wall,
            new_tokens=new_tokens,
        )

    # ------------------------------------------------------------------
    # Continuous batching
    # ------------------------------------------------------------------
    def prefill_into_slot(
        self,
        params,
        state: dict,
        row: int,
        request: GenerationRequest,
        *,
        pmax: Optional[int] = None,
        drafter=None,
        aux_embeds=None,               # (1, Sa, D) — this request's slice
        draft_params=None,
        pool: Optional[BlockPool] = None,   # paged layout: the group's
        #                                     block allocator
        rid: Optional[int] = None,          # paged layout: allocator id
        #                                     (must be reserved already)
        shared_blocks: int = 0,             # prefix cache: leading blocks of
        #                                     rid's table already stored
        shared_rows: int = 0,               # ... covering this many prompt
        #                                     rows (< P; cold tail is chunked)
    ) -> dict:
        """Admit ``request`` into slot ``row`` of a live decode state.

        Resets *every* per-row slice the decode step reads — token buffer,
        committed length, target, per-row PRNG stream, acceptance stats,
        KV/SSM cache row (freshly initialised then prefilled, so nothing
        leaks from the slot's previous occupant) and the drafter-state row
        (``Drafter.prefill_row``).  Pure host-side scatters on the state
        pytree: all shapes are unchanged, so the jitted decode step serves
        the updated state without retracing.

        With a **paged** cache (``"bt"`` in ``state["cache"]``) the cache
        reset becomes: allocate the prompt's blocks from ``pool`` under
        ``rid``'s admission-time reservation, reset the slot's
        block-table row to scratch, point its leading entries at the new
        blocks, and scatter the single-row contiguous prefill into them
        (``repro.core.paged_cache.scatter_prefill_rows``) — the prefill
        math itself is the contiguous code path, which is one of the two
        pillars of the paged-vs-contiguous bit-equality guarantee (the
        other being the position-masked read, see
        ``models/attention.attend_paged``).

        ``pmax`` fixes the padded prompt length (the serving group's
        maximum) so admission prefill compiles once per group; ``params``
        must already be prepared (``prepare_params``).  Returns a new
        state dict; the input is not mutated.
        """
        drafter = drafter if drafter is not None else self.drafter
        P = request.prompt.size
        buf = state["tokens"].shape[1]
        pmax = P if pmax is None else pmax
        if not P <= pmax <= buf:
            raise ValueError(f"pmax {pmax} outside [{P}, {buf}]")
        prompt = jnp.asarray(pad_prompt(request.prompt, pmax))[None]  # (1,pmax)

        state = dict(state)
        state["stats"] = dict(state["stats"])
        row_tokens = jnp.zeros((buf,), jnp.int32).at[:pmax].set(prompt[0])
        state["tokens"] = state["tokens"].at[row].set(row_tokens)
        state["length"] = state["length"].at[row].set(P)
        state["target"] = state["target"].at[row].set(
            P + request.max_new_tokens)
        state["key"] = prng.fill_row(state["key"], row, request.seed)
        state["stats"]["commits"] = state["stats"]["commits"].at[row].set(0)
        state["stats"]["row_steps"] = \
            state["stats"]["row_steps"].at[row].set(0)
        if "bad" in state["stats"]:
            state["stats"]["bad"] = \
                state["stats"]["bad"].at[row].set(False)

        # KV/SSM cache row: fresh init + single-row prefill, scattered in.
        # The padded prefill writes junk K/V at positions [P-1, pmax-1),
        # but verify windows cover every position gap-free before the
        # causal frontier reads it — dead weight, never live state.
        paged = "bt" in state["cache"]
        row_cache = self.model.init_cache(1, buf)
        # Warm prefix (prefix cache hit): gather the shared rows out of
        # the pool into the contiguous row cache and run *chunked*
        # prefill over the cold tail only.  The gather is an exact copy
        # (same dtype), and the chunk attends over it exactly like the
        # monolithic prefill attends over its own rows, so the admitted
        # row stays bit-identical to an unshared admission.  int8 KV
        # keeps the full recompute (attending a quantized prefix would
        # diverge from the solo run) and only skips re-*storing* the
        # shared blocks below — the capacity win without the compute
        # win.
        use_chunk = (paged and shared_rows > 0
                     and self.model.cfg.kv_cache_dtype != "int8")
        if use_chunk:
            c = int(shared_rows)
            shared_ids = pool.owned(rid)[: int(shared_blocks)]
            idx = jnp.asarray(np.asarray(shared_ids, np.int32))
            warm = []
            for pool_l, row_l in zip(state["cache"]["layers"],
                                     row_cache["layers"]):
                lay = dict(row_l)
                for name, buf_l in pool_l.items():
                    g = jnp.take(buf_l, idx, axis=0)
                    g = g.reshape((-1,) + g.shape[2:])[:c]
                    lay[name] = row_l[name].at[0, :c].set(
                        g.astype(row_l[name].dtype))
                warm.append(lay)
            row_cache = dict(row_cache)
            row_cache["layers"] = warm
            if P - 1 > c:
                row_cache = self.model.prefill_chunk(
                    params, row_cache, prompt[:, c: P - 1], c)
        else:
            row_cache = self.model.prefill(
                params, row_cache, prompt[:, :-1], aux_embeds=aux_embeds)
        if paged:                        # paged: blocks instead of a row
            if pool is None or rid is None:
                raise ValueError("paged admission needs pool= and rid=")
            n_shared = int(shared_blocks)
            fork = n_shared > 0 and int(shared_rows) % pool.block_size != 0
            if fork:
                # the last shared block is a partially-matched boundary:
                # fork it copy-on-write so our tail rows never touch the
                # donor's copy.  The "copy" is free — the scatter below
                # rewrites the fork block wholesale (gathered shared rows
                # + computed tail + zero pad).
                old = pool.owned(rid)[n_shared - 1]
                new = pool.cow(rid, old)
                if new == old and pool.prefix is not None:
                    # sole owner (resurrected cached block): write in
                    # place, but the donor's boundary entry may claim
                    # rows beyond what we matched — drop it before we
                    # overwrite them
                    pool.prefix.evict_block(old)
            pool.alloc(rid, pool.blocks_for(P) - n_shared)
            ids = pool.owned(rid)
            w0 = n_shared - (1 if fork else 0)   # first block we must write
            bt = state["cache"]["bt"].at[row].set(SCRATCH_BLOCK)
            bt = bt.at[row, : len(ids)].set(jnp.asarray(ids, jnp.int32))
            cache = dict(state["cache"])
            cache["layers"] = [
                scatter_prefill_rows(pool_l, ids[w0:], row_l,
                                     pool.block_size, first_block=w0)
                for pool_l, row_l in zip(cache["layers"],
                                         row_cache["layers"])]
            cache["bt"] = bt
            state["cache"] = cache
        else:
            state["cache"] = jax.tree.map(
                lambda full, one: full.at[row].set(one[0]),
                state["cache"], row_cache)
        # the drafter gets the UNPADDED prompt: draft-side caches may have
        # slots the drafter never rewrites (e.g. the pruned drafter skips
        # the last draft position on a full accept), so pad junk there
        # would be live — solo runs have zeros, and bit-identity demands
        # the admitted row does too
        state["drafter_state"] = drafter.prefill_row(
            self.model, params, state["drafter_state"], row,
            jnp.asarray(request.prompt, jnp.int32)[None], buf,
            aux_embeds=aux_embeds, draft_params=draft_params)
        return state

    def _check_paged_supported(self):
        """Paged KV needs attention-family, full-causal, contiguous-slot
        caches: recurrent state cannot be paged, ring buffers already
        bound their footprint, and cross-attention caches are per-request
        constants (paging them is a ROADMAP follow-up)."""
        cfg = self.model.cfg
        if cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(
                f"kv_layout='paged' needs attention KV caches; "
                f"{cfg.arch_type!r} caches are recurrent")
        if cfg.sliding_window:
            raise ValueError(
                "kv_layout='paged' does not compose with sliding-window "
                "(ring) caches — the ring already bounds the footprint")
        if cfg.cross_attn_every or cfg.encoder_layers \
                or cfg.arch_type == "audio":
            raise ValueError(
                "kv_layout='paged' supports dense/moe self-attention "
                "stacks only (cross-attention caches are unpaged)")

    def _append_paged_blocks(self, state: dict, pool: BlockPool,
                             live: dict, gamma: int) -> dict:
        """Append-on-commit: before each decode step, top every live
        row's blocks up to its next verify window's reach
        (``length + gamma + 1`` rows, capped at the request's demand).
        Draws against the admission-time reservation, so it cannot fail
        absent fault injection; host-side ``.at[].set`` on the block
        table only — the jitted step never retraces.

        Containment: a per-slot allocation failure (the pool's
        fault-injection hook, or a genuinely broken reservation) is
        collected instead of aborting the sweep — every *other* row's
        top-up still lands, then a single
        :class:`~repro.serving.faults.RequestFault` carries the failing
        slots plus the partially-topped-up state, so the scheduler
        adopts a pool-consistent state and fails only the rows it
        names.  Partial side effects on a failing row itself are
        impossible: ``BlockPool.alloc`` is atomic (the injection hook
        runs before the free list is touched).
        """
        if not live:
            return state
        lengths = np.asarray(state["length"])
        bt = state["cache"]["bt"]
        changed = False
        failures = []
        for slot, (rid, demand_tokens) in live.items():
            need = pool.blocks_for(
                min(int(lengths[slot]) + gamma + 1, demand_tokens))
            have = len(pool.owned(rid))
            if need > have:
                try:
                    ids = pool.alloc(rid, need - have)
                except Exception as exc:  # noqa: BLE001 — containment seam
                    failures.append((slot, exc))
                    continue
                bt = bt.at[slot, have:need].set(jnp.asarray(ids, jnp.int32))
                changed = True
        if changed:
            state = dict(state)
            state["cache"] = dict(state["cache"])
            state["cache"]["bt"] = bt
        if failures:
            from repro.serving.faults import RequestFault
            raise RequestFault(
                f"block append failed for slots "
                f"{[s for s, _ in failures]}: {failures[0][1]}",
                slots=[s for s, _ in failures], state=state,
                cause=failures[0][1])
        return state

    def paged_group(self, *, num_blocks: int, block_size: int,
                    gamma: int, tracer=None,
                    trace_tid: int = 0, faults=None) -> "PagedGroup":
        """Build the per-group paged-serving context (allocator + prefix
        index + swap pool) honouring ``SpecConfig.kv_prefix_sharing``.
        ``faults`` installs a :class:`~repro.serving.faults.FaultPlan`
        on the group's allocation and swap-in seams."""
        return PagedGroup(self, num_blocks=num_blocks,
                          block_size=block_size, gamma=gamma,
                          sharing=self.scfg.kv_prefix_sharing,
                          tracer=tracer, trace_tid=trace_tid,
                          faults=faults)

    def generate_requests(
        self,
        params,
        requests: Sequence[GenerationRequest],
        *,
        batch_slots: Optional[int] = None,
        aux_embeds=None,               # (len(requests), Sa, D), request order
        draft_params=None,
        admission: str = "fifo",       # "fifo" | "edf" (deadline-aware)
        on_tokens=None,                # per-request streaming callback
        tracer=None,                   # trace.Tracer: per-group tick spans
    ) -> List[RequestResult]:
        """Serve requests with heterogeneous prompt lengths, budgets,
        seeds and temperatures; returns results in request order.

        ``admission="edf"`` orders pending admissions earliest-deadline-
        first within each priority class (``GenerationRequest.deadline_s``;
        requests without one sort last) — like ``priority`` it shifts
        ``queue_s`` only, never the tokens.  The batch API never sheds:
        every request is served even past its deadline (SLO-aware
        shedding lives in the open-loop front-end,
        ``repro.serving.server``).

        ``on_tokens(request_index, tokens)`` streams each request's
        newly-committed tokens after every decode step (``np.int32``
        deltas, indices into ``requests``); the concatenated deltas are
        bit-identical to the returned ``RequestResult.tokens``.

        Requests flow through the continuous-batching scheduler:
        ``batch_slots`` rows (default ``min(len(group), 8)``) step in one
        fixed-shape jitted loop, and finished rows are refilled from the
        pending queue mid-loop — with ``len(requests) > batch_slots`` the
        batch stays saturated instead of freezing finished rows.  Each
        request's tokens are bit-identical to serving it solo (per-row
        PRNG streams + full per-row state reset at admission).

        With ``SpecConfig(kv_layout="paged")`` the serving cache is the
        block-granular pool (``repro.core.paged_cache``): admission
        *reserves* each request's worst-case block demand instead of a
        group-max contiguous row (requests wait when the pool is full —
        head-of-line, priority order preserved), blocks are appended as
        rows commit and released at harvest, and — when ``batch_slots``
        is not forced — the slot count is sized from pool occupancy
        (the largest queued-request subset whose demands co-fit the
        pool, greedy cheapest-first), so short-request mixes get more
        concurrent rows out of the same HBM.  Token streams stay
        bit-identical to the contiguous layout (and therefore to solo
        serving) for every drafter × verifier.

        Heterogeneous *prompt lengths* require attention-family caches
        (right-padding is masked positionally); recurrent-state archs
        (ssm/hybrid) must batch equal-length prompts.
        """
        if not requests:
            return []
        t_arrival = time.perf_counter()    # queue_s counts from call time,
        #                                    across sequential temp groups
        # per-temperature-group sizing record (what was ACTUALLY
        # allocated) — benchmarks read this instead of re-deriving the
        # sizing formulas (benchmarks/ablation_kv.py paged section)
        self.group_stats = []
        params = self._prepare_cached(params)
        results: List[Optional[RequestResult]] = [None] * len(requests)

        # temperature is jit-static: group requests per effective T
        groups = {}
        for i, r in enumerate(requests):
            t = (self.scfg.temperature if r.temperature is None
                 else float(r.temperature))
            groups.setdefault(t, []).append(i)

        paged = self.scfg.kv_layout == "paged"
        if paged:
            self._check_paged_supported()
        tr = tracer if tracer is not None else NULL_TRACER
        for gi, (t, idxs) in enumerate(groups.items()):
            step, drafter = self._step_for_temperature(t)
            tr.thread_name(gi, f"group{gi} T={t:g}")
            batch = [requests[i] for i in idxs]
            lengths = [r.prompt.size for r in batch]
            if (len(set(lengths)) > 1
                    and self.model.cfg.arch_type in ("ssm", "hybrid")):
                raise ValueError(
                    f"{self.model.cfg.arch_type} caches are recurrent: "
                    "heterogeneous prompt lengths cannot be right-padded; "
                    "batch equal-length prompts")
            pmax = max(lengths)
            buf = max(r.prompt.size + r.max_new_tokens for r in batch) \
                + drafter.gamma + 2

            plan = ctx = None
            cache = None
            if paged:
                plan = plan_group(
                    lengths, [r.max_new_tokens for r in batch],
                    drafter.gamma, buf,
                    block_size=self.scfg.kv_block_size,
                    pool_blocks=self.scfg.kv_pool_blocks,
                    batch_slots=batch_slots,
                    default_slots=DEFAULT_BATCH_SLOTS)
                slots = plan.slots
                ctx = self.paged_group(num_blocks=plan.num_blocks,
                                       block_size=plan.block_size,
                                       gamma=drafter.gamma,
                                       tracer=tracer, trace_tid=gi)
                cache = init_paged_cache(self.model.cfg, slots,
                                         plan.max_blocks, plan.num_blocks,
                                         plan.block_size)
            else:
                slots = min(DEFAULT_BATCH_SLOTS if batch_slots is None
                            else batch_slots, len(batch))

            # all slots idle (length == target == 0); the scheduler admits
            keys0 = jnp.zeros((slots, 2), jnp.uint32)   # per-row streams
            state = init_state(
                self.model, slots, buf, keys0,
                drafter_state=drafter.alloc_state(
                    self.model, params, slots, buf,
                    draft_params=draft_params),
                target=jnp.zeros((slots,), jnp.int32),
                cache=cache)

            self.group_stats.append({
                "temperature": t,
                "slots": slots,
                "buf": buf,
                "kv_layout": "paged" if paged else "contiguous",
                "cache_bytes": int(sum(
                    x.nbytes for x in jax.tree.leaves(state["cache"]))),
                **({"pool_blocks": plan.num_blocks,
                    "block_size": plan.block_size} if paged else {}),
            })

            can_admit = release = preempt = None
            if paged:
                for j, i in enumerate(idxs):
                    aux = (aux_embeds[i: i + 1]
                           if aux_embeds is not None else None)
                    ctx.register(j, batch[j], aux_embeds=aux)

                def admit(st, slot, j, _ctx=ctx, _drafter=drafter,
                          _pmax=pmax):
                    return _ctx.admit(st, slot, j, params=params,
                                      pmax=_pmax, drafter=_drafter,
                                      draft_params=draft_params)

                can_admit = ctx.can_admit
                release = ctx.release
                if self.scfg.kv_preempt:
                    preempt = ctx.preempt

                def step_fn(st, _s=step, _ctx=ctx):
                    return _s(params, _ctx.prepare_step(st))
            else:
                def admit(st, slot, j, _idxs=idxs, _drafter=drafter,
                          _pmax=pmax):
                    i = _idxs[j]
                    aux = (aux_embeds[i: i + 1]
                           if aux_embeds is not None else None)
                    return self.prefill_into_slot(
                        params, st, slot, requests[i], pmax=_pmax,
                        drafter=_drafter, aux_embeds=aux,
                        draft_params=draft_params)

                def step_fn(st, _s=step):
                    return _s(params, st)

            group_on_tokens = None
            if on_tokens is not None:
                def group_on_tokens(j, toks, _idxs=idxs):
                    on_tokens(_idxs[j], toks)     # group -> request index

            tkey = f"{drafter.name}:{self.verifier.name}"

            def group_stats_cb(accepted, step_s, n_tokens, _k=tkey):
                self.telemetry.on_decode_step(_k, accepted, step_s)

            sched = Scheduler(batch, slots, policy=admission,
                              tracer=tracer, trace_tid=gi,
                              trace_ids=idxs,
                              on_step_stats=group_stats_cb)
            _, group_results = sched.run(
                state, admit=admit, step=step_fn, t0=t_arrival,
                can_admit=can_admit, release=release, preempt=preempt,
                on_tokens=group_on_tokens)
            if paged:
                self.group_stats[-1].update(
                    peak_blocks=ctx.pool.peak_allocated,
                    shared_blocks=ctx.shared_blocks,
                    shared_rows=ctx.shared_rows,
                    cow_forks=ctx.cow_forks,
                    preemptions=sched.preemptions)
            for j, i in enumerate(idxs):
                results[i] = group_results[j]
        return results


def merge_state_rows(dst: dict, src: dict, rows: Sequence[int]) -> dict:
    """Graft ``rows`` of engine state ``src`` onto ``dst`` (row-sparse
    state merge — the NaN guardrail's rescue primitive).

    Contract: both states descend from the *same* pre-step state via one
    decode step each (the primary vs. the fallback execution).  Batch-
    leading leaves (tokens, length, target, key, per-row stats, drafter
    state) merge row-wise; scalar stats (``steps``) are equal in both by
    construction and kept from ``dst``.  For a paged cache the block
    table is identical in both (the jitted step never writes it), so
    the merge copies exactly the physical blocks the merged rows' table
    entries name — rows own disjoint block sets, so untouched rows'
    cache writes are preserved bit-for-bit.  Neither input is mutated.
    """
    rows = [int(r) for r in rows]
    if not rows:
        return dst
    B = dst["length"].shape[0]
    idx = jnp.asarray(rows, jnp.int32)

    def rowmerge(d, s):
        if d is s or getattr(d, "ndim", 0) < 1 or d.shape[0] != B:
            return d
        return d.at[idx].set(s[idx])

    out = dict(dst)
    for k in ("tokens", "length", "target", "key"):
        if k in dst:
            out[k] = rowmerge(dst[k], src[k])
    out["stats"] = {k: rowmerge(d, src["stats"][k])
                    for k, d in dst["stats"].items()}
    out["drafter_state"] = jax.tree.map(
        rowmerge, dst["drafter_state"], src["drafter_state"])
    if "bt" in dst["cache"]:
        bt_rows = np.asarray(dst["cache"]["bt"])[rows]
        ids = np.unique(bt_rows[bt_rows != SCRATCH_BLOCK])
        cache = dict(dst["cache"])
        if ids.size:
            bidx = jnp.asarray(ids, jnp.int32)
            cache["layers"] = jax.tree.map(
                lambda d, s: d.at[bidx].set(s[bidx]),
                dst["cache"]["layers"], src["cache"]["layers"])
        out["cache"] = cache
    else:
        out["cache"] = jax.tree.map(rowmerge, dst["cache"], src["cache"])
    return out


class PagedGroup:
    """Paged-serving context for one scheduler group: the refcounting
    :class:`~repro.core.paged_cache.BlockPool`, the prefix-cache
    :class:`~repro.core.paged_cache.PrefixIndex`, and the host-side
    ``numpy`` swap pool for preempted requests.

    Owns the scheduler-hook state machine around the jitted decode
    step — everything here is host-side bookkeeping plus ``.at[].set``
    scatters, so no hook ever retraces the step:

    * :meth:`can_admit` / :meth:`admit` — prefix-aware admission: probe
      the index, reserve only the *fresh-block* demand (minus shared
      full blocks, plus a fork for a partially-matched boundary), share
      the cached chain, prefill the cold tail (chunked), and register
      this prompt's blocks for later arrivals.  A swapped-out request
      resumes instead: re-reserve, re-alloc, copy the snapshot back.
    * :meth:`preempt` — snapshot the victim's committed cache rows and
      per-row decode state to host memory, free its blocks *now*.
    * :meth:`prepare_step` — append-on-commit block top-up plus a
      defensive copy-on-write sweep: any block in a live row's verify
      window still referenced by another request is forked before the
      step can write it.  Admission forks boundary blocks eagerly, so
      this fires only if that discipline is ever relaxed — the sweep is
      what makes "COW never mutates a shared block" an allocator
      invariant rather than a scheduling accident.
    * :meth:`release` / :meth:`drop` — exactly-once block return
      (a release racing an eviction frees nothing; regression-tested).

    The admission arithmetic degrades gracefully on tight pools: the
    boundary block is registered for sharing (which needs +1 COW
    headroom in the reservation) only when that headroom fits, so a
    pool sized for exactly one request serializes instead of
    deadlocking, and with sharing disabled every formula collapses to
    PR 5's worst-case reservation.
    """

    def __init__(self, engine: SpecEngine, *, num_blocks: int,
                 block_size: int, gamma: int, sharing: bool = True,
                 tracer=None, trace_tid: int = 0, faults=None):
        from repro.serving.faults import NULL_FAULTS, InjectedFault
        self.engine = engine
        self.gamma = int(gamma)
        self.index = PrefixIndex(block_size) if sharing else None
        self.pool = BlockPool(num_blocks, block_size, prefix=self.index)
        self.faults = faults if faults is not None else NULL_FAULTS
        if self.faults.enabled:
            def _alloc_fault(n, _f=self.faults):
                if _f.fire("alloc", blocks=int(n)):
                    raise InjectedFault(
                        f"injected BlockPool alloc failure ({n} blocks)")
            self.pool.fault_hook = _alloc_fault
        self.live: dict = {}       # slot -> (rid, demand_tokens)
        self.swap: dict = {}       # rid  -> host snapshot
        self._reqs: dict = {}      # rid  -> (request, aux_embeds)
        self._tr = tracer if tracer is not None else NULL_TRACER
        self.trace_tid = int(trace_tid)
        # telemetry (benchmarks/ablation_kv.py shared-prefix section)
        self.shared_blocks = 0     # prefix-cache block hits
        self.shared_rows = 0       # prompt rows served from cache
        self.swaps = 0             # preemptions executed
        self.cow_forks = 0         # boundary forks (admission + sweep)
        # observability counters (ServerMetrics kv_cache section via
        # :meth:`snapshot`) — admission-level prefix accounting, one
        # count per admitted request (the index's own probe counters
        # are inflated by speculative can_admit probes)
        self.prefix_hits = 0       # admissions that shared >= 1 block
        self.prefix_misses = 0     # sharing-eligible admissions, cold
        self.shared_tokens = 0     # prompt rows gathered from the cache
        self.cold_prefill_tokens = 0   # prompt rows prefilled cold
        self.resurrections = 0     # cached-free blocks shared back in
        self.swap_out_bytes = 0    # host-snapshot traffic, out
        self.swap_in_bytes = 0     # ... and back in
        self.swapped_out_blocks = 0
        self.swapped_in_blocks = 0

    # -- registration --------------------------------------------------
    def register(self, rid: int, request: GenerationRequest,
                 aux_embeds=None) -> None:
        """Associate ``rid`` with its request before any hook runs."""
        self._reqs[rid] = (request, aux_embeds)

    def demand_tokens(self, rid: int) -> int:
        r, _ = self._reqs[rid]
        return request_demand_tokens(r.prompt.size, r.max_new_tokens,
                                     self.gamma)

    def demand_blocks(self, rid: int) -> int:
        return self.pool.blocks_for(self.demand_tokens(rid))

    def _probe(self, rid: int):
        """(shared block ids, prompt rows they cover, cached-free count).

        Empty on a cold index, with sharing off, or when the request
        carries aux embeddings (prompt tokens alone don't determine its
        K/V content, so its blocks can neither be shared nor reused).
        """
        r, aux = self._reqs[rid]
        if self.index is None or aux is not None:
            return [], 0, 0
        ids, rows = self.index.lookup(np.asarray(r.prompt).ravel())
        n_res = sum(1 for b in ids if self.pool.ref(b) == 0)
        return ids, rows, n_res

    def _admission_need(self, rid: int):
        """(fresh-block reservation, probe) for admitting ``rid`` now.

        Graceful degradation: when the shared plan's slack cost (fresh
        blocks + a fork for a partially-matched boundary + resurrected
        cached blocks) does not fit but the plain worst-case demand
        does, the probe is discarded and the request admits *unshared*
        — a tight pool serializes exactly like PR 5 instead of
        deadlocking on sharing arithmetic.
        """
        d = self.demand_blocks(rid)
        ids, rows, n_res = self._probe(rid)
        if ids:
            fork = 1 if rows % self.pool.block_size != 0 else 0
            need = d - len(ids) + fork
            if self.pool.can_reserve(need + n_res):
                return need, (ids, rows, n_res)
        return d, ([], 0, 0)

    # -- scheduler hooks -----------------------------------------------
    def can_admit(self, rid: int) -> bool:
        if rid in self.swap:
            return self.pool.can_reserve(self.demand_blocks(rid))
        # resurrecting a cached-free block consumes one slack unit even
        # though it is not a fresh draw — count it in the gate
        need, (_, _, n_res) = self._admission_need(rid)
        return self.pool.can_reserve(need + n_res)

    def admit(self, state: dict, slot: int, rid: int, *, params,
              pmax: int, drafter, draft_params=None) -> dict:
        if rid in self.swap:
            return self._resume(state, slot, rid)
        r, aux = self._reqs[rid]
        need, (ids, rows, n_res) = self._admission_need(rid)
        P = r.prompt.size
        bs = self.pool.block_size
        # +1 COW headroom lets us *donate* our partially-filled boundary
        # block to the index (a later arrival may share it while we are
        # still decoding); skipped — not failed — when the pool is tight
        head = 1 if (self.index is not None and aux is None
                     and (P - 1) % bs != 0) else 0
        donate = bool(head) and self.pool.can_reserve(need + n_res + head)
        self.pool.reserve(rid, need + (head if donate else 0))
        if ids:
            self.pool.share(rid, ids)
            self.shared_blocks += len(ids)
            self.shared_rows += rows
        if self.index is not None and aux is None:
            if ids:
                self.prefix_hits += 1
            else:
                self.prefix_misses += 1
        self.shared_tokens += rows
        self.cold_prefill_tokens += max(P - 1 - rows, 0)
        self.resurrections += n_res
        self.live[slot] = (rid, self.demand_tokens(rid))
        if ids and rows % bs != 0:
            self.cow_forks += 1          # prefill_into_slot forks below
        with self._tr.span("prefill", tid=self.trace_tid, rid=rid,
                           shared_rows=rows, cold_rows=max(P - 1 - rows, 0)):
            state = self.engine.prefill_into_slot(
                params, state, slot, r, pmax=pmax, drafter=drafter,
                aux_embeds=aux, draft_params=draft_params,
                pool=self.pool, rid=rid,
                shared_blocks=len(ids), shared_rows=rows)
        if self.index is not None and aux is None:
            self.index.register(np.asarray(r.prompt).ravel(),
                                self.pool.owned(rid),
                                include_boundary=donate)
        return state

    def release(self, state: dict, slot: int, rid: int) -> dict:
        """Harvest hook: return blocks (exactly once) and idle the row."""
        self.pool.release(rid)
        self.live.pop(slot, None)
        state = dict(state)
        state["cache"] = dict(state["cache"])
        state["cache"]["bt"] = \
            state["cache"]["bt"].at[slot].set(SCRATCH_BLOCK)
        return state

    def drop(self, rid: int) -> None:
        """Forget a request that will never resume (shed while swapped)."""
        self.swap.pop(rid, None)
        self.pool.release(rid)

    # -- preemption / swap ---------------------------------------------
    def preempt(self, state: dict, slot: int, rid: int) -> dict:
        """Evict ``slot``'s occupant to the host swap pool.

        Saves the committed cache rows ``[0, length - 1)`` (everything a
        future verify window *reads*; the window itself rewrites rows
        from ``length - 1`` on) plus every per-row decode register, then
        frees the blocks and reservation so the pending head can admit.
        Pure host work — the decode step never retraces, and the row is
        left idle (``length == target == 0``) like any un-admitted slot.
        """
        self.live.pop(slot, None)
        L = int(np.asarray(state["length"])[slot])
        n_save = self.pool.blocks_for(max(L - 1, 0))
        ids = self.pool.owned(rid)[:n_save]
        with self._tr.span("swap_out", tid=self.trace_tid, rid=rid,
                           blocks=n_save):
            snap = {
                "n_blocks": n_save,
                "blocks": swap_out_blocks(state["cache"]["layers"], ids),
                "tokens": np.asarray(state["tokens"][slot]),
                "length": L,
                "target": int(np.asarray(state["target"])[slot]),
                "key": np.asarray(state["key"][slot]),
                "commits": int(np.asarray(state["stats"]["commits"])[slot]),
                "row_steps": int(
                    np.asarray(state["stats"]["row_steps"])[slot]),
                "drafter": jax.tree.map(lambda x: np.asarray(x[slot]),
                                        state["drafter_state"]),
            }
        self.pool.swap_out(rid)
        self.swap[rid] = snap
        self.swaps += 1
        nbytes = int(sum(x.nbytes for x in jax.tree.leaves(snap["blocks"])))
        self.swap_out_bytes += nbytes
        self.swapped_out_blocks += n_save
        state = dict(state)
        state["length"] = state["length"].at[slot].set(0)
        state["target"] = state["target"].at[slot].set(0)
        state["cache"] = dict(state["cache"])
        state["cache"]["bt"] = \
            state["cache"]["bt"].at[slot].set(SCRATCH_BLOCK)
        return state

    def _resume(self, state: dict, slot: int, rid: int) -> dict:
        """Re-admit a swapped request: fresh blocks, bit-exact copy-back."""
        with self._tr.span("swap_in", tid=self.trace_tid, rid=rid):
            return self._resume_inner(state, slot, rid)

    def _resume_inner(self, state: dict, slot: int, rid: int) -> dict:
        snap = self.swap.pop(rid)
        if self.faults.fire("swap_in", rid=rid):
            # corrupt the host snapshot's KV payload (float leaves →
            # NaN): the resumed row decodes against poisoned state, the
            # verify-path NaN tripwire flags it, and — since the
            # corruption lives in the cache, not the params — every
            # fallback stage reproduces it, so the request fails
            # (contained) rather than silently emitting garbage.
            # int8 KV snapshots have no float leaves; the injection is
            # a no-op there (documented in docs/robustness.md).
            snap = dict(snap)
            snap["blocks"] = jax.tree.map(
                lambda x: np.full_like(x, np.nan)
                if np.issubdtype(np.asarray(x).dtype, np.floating) else x,
                snap["blocks"])
        self.pool.reserve(rid, self.demand_blocks(rid))
        ids = self.pool.alloc(rid, snap["n_blocks"])
        self.swap_in_bytes += int(sum(
            x.nbytes for x in jax.tree.leaves(snap["blocks"])))
        self.swapped_in_blocks += len(ids)
        state = dict(state)
        state["stats"] = dict(state["stats"])
        state["tokens"] = state["tokens"].at[slot].set(
            jnp.asarray(snap["tokens"]))
        state["length"] = state["length"].at[slot].set(snap["length"])
        state["target"] = state["target"].at[slot].set(snap["target"])
        state["key"] = state["key"].at[slot].set(jnp.asarray(snap["key"]))
        state["stats"]["commits"] = \
            state["stats"]["commits"].at[slot].set(snap["commits"])
        state["stats"]["row_steps"] = \
            state["stats"]["row_steps"].at[slot].set(snap["row_steps"])
        if "bad" in state["stats"]:
            state["stats"]["bad"] = \
                state["stats"]["bad"].at[slot].set(False)
        state["drafter_state"] = jax.tree.map(
            lambda full, one: full.at[slot].set(
                jnp.asarray(one).astype(full.dtype)),
            state["drafter_state"], snap["drafter"])
        cache = dict(state["cache"])
        bt = cache["bt"].at[slot].set(SCRATCH_BLOCK)
        bt = bt.at[slot, : len(ids)].set(jnp.asarray(ids, jnp.int32))
        cache["bt"] = bt
        cache["layers"] = swap_in_blocks(cache["layers"], ids,
                                         snap["blocks"])
        state["cache"] = cache
        self.live[slot] = (rid, self.demand_tokens(rid))
        return state

    # -- per-step maintenance ------------------------------------------
    def prepare_step(self, state: dict) -> dict:
        """Run before every decode step: block top-up + COW sweep."""
        with self._tr.span("append_blocks", tid=self.trace_tid):
            state = self.engine._append_paged_blocks(
                state, self.pool, self.live, self.gamma)
        if self.index is None or not self.live:
            return state
        # defensive copy-on-write: fork any still-shared block the next
        # verify window would write (rows [L-1, L+gamma])
        bt = state["cache"]["bt"]
        bt_host = np.asarray(bt)
        lengths = np.asarray(state["length"])
        layers = state["cache"]["layers"]
        bs = self.pool.block_size
        changed = False
        for slot, (rid, _demand) in self.live.items():
            L = int(lengths[slot])
            lo = max(L - 1, 0) // bs
            hi = min((L + self.gamma) // bs, bt_host.shape[1] - 1)
            for k in range(lo, hi + 1):
                bid = int(bt_host[slot, k])
                if bid == SCRATCH_BLOCK or self.pool.ref(bid) <= 1:
                    continue
                new = self.pool.cow(rid, bid)
                layers = clone_block(layers, bid, new)
                bt = bt.at[slot, k].set(new)
                self.cow_forks += 1
                changed = True
        if changed:
            state = dict(state)
            state["cache"] = dict(state["cache"])
            state["cache"]["layers"] = layers
            state["cache"]["bt"] = bt
        return state

    # -- observability -------------------------------------------------
    def snapshot(self) -> dict:
        """Gauge snapshot for ``ServerMetrics.add_kv_source`` (schema:
        docs/observability.md, kv_cache section).  All counters are
        monotone; ``pool`` carries this group's point-in-time gauges."""
        return {
            "prefix_hits": self.prefix_hits,
            "prefix_misses": self.prefix_misses,
            "shared_blocks": self.shared_blocks,
            "shared_tokens": self.shared_tokens,
            "cold_prefill_tokens": self.cold_prefill_tokens,
            "cow_forks": self.cow_forks,
            "resurrections": self.resurrections,
            "cached_evicted": self.pool.counters["cached_evicted"],
            "swap_out_blocks": self.swapped_out_blocks,
            "swap_in_blocks": self.swapped_in_blocks,
            "swap_out_bytes": self.swap_out_bytes,
            "swap_in_bytes": self.swap_in_bytes,
            "preemptions": self.swaps,
            "pool": {
                "capacity": self.pool.capacity,
                "free": self.pool.free_blocks,
                "cached": self.pool.cached_blocks,
                "unique_allocated": self.pool.unique_allocated,
                "peak_allocated": self.pool.peak_allocated,
            },
        }

    # -- invariants ----------------------------------------------------
    def check_invariants(self) -> None:
        self.pool.check_invariants()
        for slot, (rid, _d) in self.live.items():
            assert rid not in self.swap, (
                f"request {rid} both live and swapped")
