"""Batched speculative serving engine over the pluggable decoding API.

``SpecEngine`` wraps the unified jitted decode step
(:func:`repro.core.spec_engine.make_decode_step`) with prompt prefill,
the generation loop, and acceptance/throughput statistics.  Drafting and
verification strategies are plugins resolved from the registries in
``repro.core.protocols``:

    engine = SpecEngine(model, SpecConfig(verifier="w8a8"))   # Quasar
    engine = SpecEngine(model, scfg, drafter="pruned")        # Table 5
    engine = SpecEngine(model, scfg, drafter=MyDrafter(...))  # custom
    engine = SpecEngine(                                      # token tree
        model, SpecConfig(tree_branches=(3, 2, 1, 1)), drafter="ngram-tree")

The verifier owns offline weight preparation: with ``verifier="w8a8"``
the engine quantizes BF16 params internally (SmoothQuant + INT8) on first
use — callers never invoke ``quantize_params`` by hand.

Two serving entry points:

* :meth:`generate` — one homogeneous batch ``(B, P)`` of prompts, shared
  token budget (the benchmark/table workhorse);
* :meth:`generate_requests` — a list of
  :class:`~repro.serving.request.GenerationRequest` with heterogeneous
  prompt lengths, budgets, seeds and temperatures, served through the
  continuous-batching :class:`~repro.serving.scheduler.Scheduler`: a
  fixed number of batch slots steps in one jit-compiled loop, and
  whenever a row exhausts its budget the next pending request is admitted
  into the freed slot via :meth:`prefill_into_slot` — the decode step
  never retraces on admission (``step_traces`` counts compilations).
  Returns per-request :class:`~repro.serving.request.RequestResult` with
  queue/service timing.

The legacy ``mode=`` constructor argument ("spec" | "vanilla" |
"pruned") remains as a deprecated shim: it maps to the matching drafter
with a passthrough BF16 verifier (params prepared by the caller), which
is exactly the seed-era behaviour.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import prng
from repro.core.config import SpecConfig
from repro.core.paged_cache import (
    SCRATCH_BLOCK,
    BlockPool,
    init_paged_cache,
    plan_group,
    request_demand_tokens,
    scatter_prefill_rows,
)
from repro.core.protocols import get_drafter, get_verifier
from repro.core.spec_engine import init_state, make_decode_step
from repro.serving.request import (
    GenerationRequest,
    RequestResult,
    pad_prompt,
    safe_rate,
)
from repro.serving.scheduler import Scheduler

# deprecated mode-string → drafter-registry-name mapping (public: the serve
# CLI builds its --mode choices from it)
LEGACY_MODES = {"spec": "ngram", "vanilla": "vanilla", "pruned": "pruned"}
_MAX_TEMP_STEPS = 8        # bound on per-temperature compiled-step cache
DEFAULT_BATCH_SLOTS = 8    # decode rows per scheduler loop (memory bound)


@dataclass
class GenResult:
    tokens: jnp.ndarray          # (B, S_buf) full buffers
    lengths: jnp.ndarray         # (B,)
    mean_accept_len: float       # L — committed tokens per verify step
    steps: int                   # verify steps taken
    wall_s: float
    new_tokens: int

    @property
    def tokens_per_s(self) -> float:
        # 0.0 (not a divide-by-zero spike) when a fast CPU run records
        # zero wall time
        return safe_rate(self.new_tokens, self.wall_s)


class SpecEngine:
    """Drafter x Verifier serving engine (see module docstring)."""

    def __init__(self, model, scfg: SpecConfig = SpecConfig(),
                 mode: Optional[str] = None, *,
                 drafter=None, verifier=None):
        self.model = model
        self.scfg = scfg
        self.mode = mode
        if scfg.kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {scfg.kv_layout!r}; "
                             "expected 'contiguous' or 'paged'")
        if mode is not None:                       # deprecated shim
            if mode not in LEGACY_MODES:
                raise ValueError(mode)
            drafter = drafter if drafter is not None else LEGACY_MODES[mode]
            # legacy callers quantize params themselves: passthrough prepare
            verifier = verifier if verifier is not None else "bf16"
        self.drafter = get_drafter(
            drafter if drafter is not None else scfg.drafter, scfg)
        self.verifier = get_verifier(
            verifier if verifier is not None else scfg.verifier, scfg)
        # decode-step (re)compilations across all temperature variants —
        # the continuous-batching tests assert admission never bumps this
        self.step_traces = 0
        # per-group sizing of the last generate_requests call
        self.group_stats = []
        self._step = self._jit_counted(
            make_decode_step(model, self.drafter, self.verifier, scfg))
        self._steps_by_temp = {}                   # temperature overrides
        self._prepared = None                      # (params ref, prepared)

    def _jit_counted(self, step_fn):
        """jit the decode step, counting traces (== XLA compilations)."""
        def counted(params, state):
            self.step_traces += 1      # runs at trace time only
            return step_fn(params, state)
        return jax.jit(counted)

    # ------------------------------------------------------------------
    def prepare_params(self, params, act_stats=None):
        """Offline weight preparation for this engine's verifier
        (e.g. SmoothQuant + INT8 for ``w8a8``).  Idempotent."""
        return self.verifier.prepare(self.model, params, act_stats)

    def _prepare_cached(self, params):
        # NOTE: keeps a strong reference to the last input tree as the
        # cache key, so a w8a8 engine pins the BF16 original while alive.
        # Memory-sensitive callers: params = engine.prepare_params(params)
        # once, drop the original, and pass the prepared tree (idempotent).
        if self._prepared is not None and (
                params is self._prepared[0] or params is self._prepared[1]):
            return self._prepared[1]
        self._prepared = (params, self.prepare_params(params))
        return self._prepared[1]

    def _step_for_temperature(self, t: float):
        """(jitted step, drafter) with temperature ``t`` baked in."""
        if t == self.scfg.temperature:
            return self._step, self.drafter
        if t not in self._steps_by_temp:
            if len(self._steps_by_temp) >= _MAX_TEMP_STEPS:
                # each entry pins a compiled executable — evict the oldest
                self._steps_by_temp.pop(next(iter(self._steps_by_temp)))
            scfg_t = dataclasses.replace(self.scfg, temperature=t)
            drafter = self.drafter.with_temperature(t)
            step = self._jit_counted(
                make_decode_step(self.model, drafter, self.verifier, scfg_t))
            self._steps_by_temp[t] = (step, drafter)
        return self._steps_by_temp[t]

    # ------------------------------------------------------------------
    def _init_state(self, params, prompts, lengths, targets, buf, key, *,
                    drafter, aux_embeds=None, draft_params=None):
        """Prefill + assemble the decode-loop state pytree."""
        B, P = prompts.shape
        assert P >= 2, "prompts must have >= 2 tokens"
        state = init_state(self.model, B, buf, key, target=targets)
        state["tokens"] = state["tokens"].at[:, :P].set(prompts)
        state["length"] = jnp.asarray(lengths, jnp.int32)
        # cache covers committed tokens *except the last* (which becomes
        # the first token of the first verify window) — hence [:, :-1]
        state["cache"] = self.model.prefill(
            params, state["cache"], prompts[:, :-1], aux_embeds=aux_embeds)
        state["drafter_state"] = drafter.init_state(
            self.model, params, prompts, buf,
            aux_embeds=aux_embeds, draft_params=draft_params)
        return state

    def _run(self, step, params, state, max_steps: int):
        """Drive the jitted step until every row reaches its target."""
        t0 = time.perf_counter()
        steps = 0
        while True:
            state = step(params, state)
            steps += 1
            if bool(jnp.all(state["length"] >= state["target"])):
                break
            if steps > max_steps:      # safety: >= 1 token/step guaranteed
                break
        jax.block_until_ready(state["tokens"])
        return state, time.perf_counter() - t0

    # ------------------------------------------------------------------
    def generate(
        self,
        params,
        prompts: jnp.ndarray,          # (B, P) int32
        max_new_tokens: Optional[int] = None,
        *,
        aux_embeds=None,
        key=None,
        draft_params=None,             # pruned drafting with separate params
    ) -> GenResult:
        """Homogeneous batch: shared prompt length and token budget."""
        max_new = max_new_tokens or self.scfg.max_new_tokens
        B, P = prompts.shape
        buf = P + max_new + self.drafter.gamma + 2
        key = key if key is not None else jax.random.PRNGKey(0)

        params = self._prepare_cached(params)
        lengths = jnp.full((B,), P, jnp.int32)
        targets = jnp.full((B,), P + max_new, jnp.int32)
        state = self._init_state(params, prompts, lengths, targets, buf, key,
                                 drafter=self.drafter, aux_embeds=aux_embeds,
                                 draft_params=draft_params)
        state, wall = self._run(self._step, params, state, max_new * 2 + 8)

        commits = state["stats"]["commits"]
        n_steps = int(state["stats"]["steps"])
        # per-row denominator: steps while that row was still generating
        L = float(jnp.mean(
            commits / jnp.maximum(state["stats"]["row_steps"], 1)))
        new_tokens = int(jnp.sum(jnp.minimum(state["length"], P + max_new) - P))
        return GenResult(
            tokens=state["tokens"],
            lengths=state["length"],
            mean_accept_len=L,
            steps=n_steps,
            wall_s=wall,
            new_tokens=new_tokens,
        )

    # ------------------------------------------------------------------
    # Continuous batching
    # ------------------------------------------------------------------
    def prefill_into_slot(
        self,
        params,
        state: dict,
        row: int,
        request: GenerationRequest,
        *,
        pmax: Optional[int] = None,
        drafter=None,
        aux_embeds=None,               # (1, Sa, D) — this request's slice
        draft_params=None,
        pool: Optional[BlockPool] = None,   # paged layout: the group's
        #                                     block allocator
        rid: Optional[int] = None,          # paged layout: allocator id
        #                                     (must be reserved already)
    ) -> dict:
        """Admit ``request`` into slot ``row`` of a live decode state.

        Resets *every* per-row slice the decode step reads — token buffer,
        committed length, target, per-row PRNG stream, acceptance stats,
        KV/SSM cache row (freshly initialised then prefilled, so nothing
        leaks from the slot's previous occupant) and the drafter-state row
        (``Drafter.prefill_row``).  Pure host-side scatters on the state
        pytree: all shapes are unchanged, so the jitted decode step serves
        the updated state without retracing.

        With a **paged** cache (``"bt"`` in ``state["cache"]``) the cache
        reset becomes: allocate the prompt's blocks from ``pool`` under
        ``rid``'s admission-time reservation, reset the slot's
        block-table row to scratch, point its leading entries at the new
        blocks, and scatter the single-row contiguous prefill into them
        (``repro.core.paged_cache.scatter_prefill_rows``) — the prefill
        math itself is the contiguous code path, which is one of the two
        pillars of the paged-vs-contiguous bit-equality guarantee (the
        other being the position-masked read, see
        ``models/attention.attend_paged``).

        ``pmax`` fixes the padded prompt length (the serving group's
        maximum) so admission prefill compiles once per group; ``params``
        must already be prepared (``prepare_params``).  Returns a new
        state dict; the input is not mutated.
        """
        drafter = drafter if drafter is not None else self.drafter
        P = request.prompt.size
        buf = state["tokens"].shape[1]
        pmax = P if pmax is None else pmax
        if not P <= pmax <= buf:
            raise ValueError(f"pmax {pmax} outside [{P}, {buf}]")
        prompt = jnp.asarray(pad_prompt(request.prompt, pmax))[None]  # (1,pmax)

        state = dict(state)
        state["stats"] = dict(state["stats"])
        row_tokens = jnp.zeros((buf,), jnp.int32).at[:pmax].set(prompt[0])
        state["tokens"] = state["tokens"].at[row].set(row_tokens)
        state["length"] = state["length"].at[row].set(P)
        state["target"] = state["target"].at[row].set(
            P + request.max_new_tokens)
        state["key"] = prng.fill_row(state["key"], row, request.seed)
        state["stats"]["commits"] = state["stats"]["commits"].at[row].set(0)
        state["stats"]["row_steps"] = \
            state["stats"]["row_steps"].at[row].set(0)

        # KV/SSM cache row: fresh init + single-row prefill, scattered in.
        # The padded prefill writes junk K/V at positions [P-1, pmax-1),
        # but verify windows cover every position gap-free before the
        # causal frontier reads it — dead weight, never live state.
        row_cache = self.model.init_cache(1, buf)
        row_cache = self.model.prefill(
            params, row_cache, prompt[:, :-1], aux_embeds=aux_embeds)
        if "bt" in state["cache"]:       # paged: blocks instead of a row
            if pool is None or rid is None:
                raise ValueError("paged admission needs pool= and rid=")
            ids = pool.alloc(rid, pool.blocks_for(P))
            bt = state["cache"]["bt"].at[row].set(SCRATCH_BLOCK)
            bt = bt.at[row, : len(ids)].set(jnp.asarray(ids, jnp.int32))
            cache = dict(state["cache"])
            cache["layers"] = [
                scatter_prefill_rows(pool_l, ids, row_l, pool.block_size)
                for pool_l, row_l in zip(cache["layers"],
                                         row_cache["layers"])]
            cache["bt"] = bt
            state["cache"] = cache
        else:
            state["cache"] = jax.tree.map(
                lambda full, one: full.at[row].set(one[0]),
                state["cache"], row_cache)
        # the drafter gets the UNPADDED prompt: draft-side caches may have
        # slots the drafter never rewrites (e.g. the pruned drafter skips
        # the last draft position on a full accept), so pad junk there
        # would be live — solo runs have zeros, and bit-identity demands
        # the admitted row does too
        state["drafter_state"] = drafter.prefill_row(
            self.model, params, state["drafter_state"], row,
            jnp.asarray(request.prompt, jnp.int32)[None], buf,
            aux_embeds=aux_embeds, draft_params=draft_params)
        return state

    def _check_paged_supported(self):
        """Paged KV needs attention-family, full-causal, contiguous-slot
        caches: recurrent state cannot be paged, ring buffers already
        bound their footprint, and cross-attention caches are per-request
        constants (paging them is a ROADMAP follow-up)."""
        cfg = self.model.cfg
        if cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(
                f"kv_layout='paged' needs attention KV caches; "
                f"{cfg.arch_type!r} caches are recurrent")
        if cfg.sliding_window:
            raise ValueError(
                "kv_layout='paged' does not compose with sliding-window "
                "(ring) caches — the ring already bounds the footprint")
        if cfg.cross_attn_every or cfg.encoder_layers \
                or cfg.arch_type == "audio":
            raise ValueError(
                "kv_layout='paged' supports dense/moe self-attention "
                "stacks only (cross-attention caches are unpaged)")

    def _append_paged_blocks(self, state: dict, pool: BlockPool,
                             live: dict, gamma: int) -> dict:
        """Append-on-commit: before each decode step, top every live
        row's blocks up to its next verify window's reach
        (``length + gamma + 1`` rows, capped at the request's demand).
        Draws against the admission-time reservation, so it cannot fail;
        host-side ``.at[].set`` on the block table only — the jitted
        step never retraces."""
        if not live:
            return state
        lengths = np.asarray(state["length"])
        bt = state["cache"]["bt"]
        changed = False
        for slot, (rid, demand_tokens) in live.items():
            need = pool.blocks_for(
                min(int(lengths[slot]) + gamma + 1, demand_tokens))
            have = len(pool.owned(rid))
            if need > have:
                ids = pool.alloc(rid, need - have)
                bt = bt.at[slot, have:need].set(jnp.asarray(ids, jnp.int32))
                changed = True
        if changed:
            state = dict(state)
            state["cache"] = dict(state["cache"])
            state["cache"]["bt"] = bt
        return state

    def generate_requests(
        self,
        params,
        requests: Sequence[GenerationRequest],
        *,
        batch_slots: Optional[int] = None,
        aux_embeds=None,               # (len(requests), Sa, D), request order
        draft_params=None,
        admission: str = "fifo",       # "fifo" | "edf" (deadline-aware)
        on_tokens=None,                # per-request streaming callback
    ) -> List[RequestResult]:
        """Serve requests with heterogeneous prompt lengths, budgets,
        seeds and temperatures; returns results in request order.

        ``admission="edf"`` orders pending admissions earliest-deadline-
        first within each priority class (``GenerationRequest.deadline_s``;
        requests without one sort last) — like ``priority`` it shifts
        ``queue_s`` only, never the tokens.  The batch API never sheds:
        every request is served even past its deadline (SLO-aware
        shedding lives in the open-loop front-end,
        ``repro.serving.server``).

        ``on_tokens(request_index, tokens)`` streams each request's
        newly-committed tokens after every decode step (``np.int32``
        deltas, indices into ``requests``); the concatenated deltas are
        bit-identical to the returned ``RequestResult.tokens``.

        Requests flow through the continuous-batching scheduler:
        ``batch_slots`` rows (default ``min(len(group), 8)``) step in one
        fixed-shape jitted loop, and finished rows are refilled from the
        pending queue mid-loop — with ``len(requests) > batch_slots`` the
        batch stays saturated instead of freezing finished rows.  Each
        request's tokens are bit-identical to serving it solo (per-row
        PRNG streams + full per-row state reset at admission).

        With ``SpecConfig(kv_layout="paged")`` the serving cache is the
        block-granular pool (``repro.core.paged_cache``): admission
        *reserves* each request's worst-case block demand instead of a
        group-max contiguous row (requests wait when the pool is full —
        head-of-line, priority order preserved), blocks are appended as
        rows commit and released at harvest, and — when ``batch_slots``
        is not forced — the slot count is sized from pool occupancy
        (the largest queued-request subset whose demands co-fit the
        pool, greedy cheapest-first), so short-request mixes get more
        concurrent rows out of the same HBM.  Token streams stay
        bit-identical to the contiguous layout (and therefore to solo
        serving) for every drafter × verifier.

        Heterogeneous *prompt lengths* require attention-family caches
        (right-padding is masked positionally); recurrent-state archs
        (ssm/hybrid) must batch equal-length prompts.
        """
        if not requests:
            return []
        t_arrival = time.perf_counter()    # queue_s counts from call time,
        #                                    across sequential temp groups
        # per-temperature-group sizing record (what was ACTUALLY
        # allocated) — benchmarks read this instead of re-deriving the
        # sizing formulas (benchmarks/ablation_kv.py paged section)
        self.group_stats = []
        params = self._prepare_cached(params)
        results: List[Optional[RequestResult]] = [None] * len(requests)

        # temperature is jit-static: group requests per effective T
        groups = {}
        for i, r in enumerate(requests):
            t = (self.scfg.temperature if r.temperature is None
                 else float(r.temperature))
            groups.setdefault(t, []).append(i)

        paged = self.scfg.kv_layout == "paged"
        if paged:
            self._check_paged_supported()
        for t, idxs in groups.items():
            step, drafter = self._step_for_temperature(t)
            batch = [requests[i] for i in idxs]
            lengths = [r.prompt.size for r in batch]
            if (len(set(lengths)) > 1
                    and self.model.cfg.arch_type in ("ssm", "hybrid")):
                raise ValueError(
                    f"{self.model.cfg.arch_type} caches are recurrent: "
                    "heterogeneous prompt lengths cannot be right-padded; "
                    "batch equal-length prompts")
            pmax = max(lengths)
            buf = max(r.prompt.size + r.max_new_tokens for r in batch) \
                + drafter.gamma + 2

            plan = pool = None
            cache = None
            if paged:
                plan = plan_group(
                    lengths, [r.max_new_tokens for r in batch],
                    drafter.gamma, buf,
                    block_size=self.scfg.kv_block_size,
                    pool_blocks=self.scfg.kv_pool_blocks,
                    batch_slots=batch_slots,
                    default_slots=DEFAULT_BATCH_SLOTS)
                slots = plan.slots
                pool = BlockPool(plan.num_blocks, plan.block_size)
                cache = init_paged_cache(self.model.cfg, slots,
                                         plan.max_blocks, plan.num_blocks,
                                         plan.block_size)
            else:
                slots = min(DEFAULT_BATCH_SLOTS if batch_slots is None
                            else batch_slots, len(batch))

            # all slots idle (length == target == 0); the scheduler admits
            keys0 = jnp.zeros((slots, 2), jnp.uint32)   # per-row streams
            state = init_state(
                self.model, slots, buf, keys0,
                drafter_state=drafter.alloc_state(
                    self.model, params, slots, buf,
                    draft_params=draft_params),
                target=jnp.zeros((slots,), jnp.int32),
                cache=cache)

            self.group_stats.append({
                "temperature": t,
                "slots": slots,
                "buf": buf,
                "kv_layout": "paged" if paged else "contiguous",
                "cache_bytes": int(sum(
                    x.nbytes for x in jax.tree.leaves(state["cache"]))),
                **({"pool_blocks": plan.num_blocks,
                    "block_size": plan.block_size} if paged else {}),
            })

            live = {}          # slot -> (rid, demand tokens); paged only

            def admit(st, slot, j, _idxs=idxs, _drafter=drafter, _pmax=pmax,
                      _batch=batch, _plan=plan, _pool=pool, _live=live):
                i = _idxs[j]
                aux = aux_embeds[i: i + 1] if aux_embeds is not None else None
                if _pool is not None:
                    _pool.reserve(j, _plan.demands[j])
                    _live[slot] = (j, request_demand_tokens(
                        _batch[j].prompt.size, _batch[j].max_new_tokens,
                        _drafter.gamma))
                return self.prefill_into_slot(
                    params, st, slot, requests[i], pmax=_pmax,
                    drafter=_drafter, aux_embeds=aux,
                    draft_params=draft_params, pool=_pool, rid=j)

            can_admit = release = None
            if paged:
                def can_admit(j, _plan=plan, _pool=pool):
                    return _pool.can_reserve(_plan.demands[j])

                def release(st, slot, j, _pool=pool, _live=live):
                    _pool.release(j)
                    _live.pop(slot, None)
                    st = dict(st)
                    st["cache"] = dict(st["cache"])
                    st["cache"]["bt"] = \
                        st["cache"]["bt"].at[slot].set(SCRATCH_BLOCK)
                    return st

                def step_fn(st, _s=step, _pool=pool, _live=live,
                            _g=drafter.gamma):
                    st = self._append_paged_blocks(st, _pool, _live, _g)
                    return _s(params, st)
            else:
                def step_fn(st, _s=step):
                    return _s(params, st)

            group_on_tokens = None
            if on_tokens is not None:
                def group_on_tokens(j, toks, _idxs=idxs):
                    on_tokens(_idxs[j], toks)     # group -> request index

            sched = Scheduler(batch, slots, policy=admission)
            _, group_results = sched.run(
                state, admit=admit, step=step_fn, t0=t_arrival,
                can_admit=can_admit, release=release,
                on_tokens=group_on_tokens)
            for j, i in enumerate(idxs):
                results[i] = group_results[j]
        return results
