"""Batched speculative serving engine over the pluggable decoding API.

``SpecEngine`` wraps the unified jitted decode step
(:func:`repro.core.spec_engine.make_decode_step`) with prompt prefill,
the generation loop, and acceptance/throughput statistics.  Drafting and
verification strategies are plugins resolved from the registries in
``repro.core.protocols``:

    engine = SpecEngine(model, SpecConfig(verifier="w8a8"))   # Quasar
    engine = SpecEngine(model, scfg, drafter="pruned")        # Table 5
    engine = SpecEngine(model, scfg, drafter=MyDrafter(...))  # custom

The verifier owns offline weight preparation: with ``verifier="w8a8"``
the engine quantizes BF16 params internally (SmoothQuant + INT8) on first
use — callers never invoke ``quantize_params`` by hand.

Two serving entry points:

* :meth:`generate` — one homogeneous batch ``(B, P)`` of prompts, shared
  token budget (the benchmark/table workhorse);
* :meth:`generate_requests` — a list of
  :class:`~repro.serving.request.GenerationRequest` with heterogeneous
  prompt lengths, ``max_new_tokens`` and seeds, served in one batched
  loop with per-request early exit; returns per-request
  :class:`~repro.serving.request.RequestResult`.

The legacy ``mode=`` constructor argument ("spec" | "vanilla" |
"pruned") remains as a deprecated shim: it maps to the matching drafter
with a passthrough BF16 verifier (params prepared by the caller), which
is exactly the seed-era behaviour.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import SpecConfig
from repro.core.protocols import get_drafter, get_verifier
from repro.core.spec_engine import init_state, make_decode_step
from repro.serving.request import GenerationRequest, RequestResult, pack_prompts

# deprecated mode-string → drafter-registry-name mapping (public: the serve
# CLI builds its --mode choices from it)
LEGACY_MODES = {"spec": "ngram", "vanilla": "vanilla", "pruned": "pruned"}
_MAX_TEMP_STEPS = 8        # bound on per-temperature compiled-step cache


@dataclass
class GenResult:
    tokens: jnp.ndarray          # (B, S_buf) full buffers
    lengths: jnp.ndarray         # (B,)
    mean_accept_len: float       # L — committed tokens per verify step
    steps: int                   # verify steps taken
    wall_s: float
    new_tokens: int

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / max(self.wall_s, 1e-9)


class SpecEngine:
    """Drafter x Verifier serving engine (see module docstring)."""

    def __init__(self, model, scfg: SpecConfig = SpecConfig(),
                 mode: Optional[str] = None, *,
                 drafter=None, verifier=None):
        self.model = model
        self.scfg = scfg
        self.mode = mode
        if mode is not None:                       # deprecated shim
            if mode not in LEGACY_MODES:
                raise ValueError(mode)
            drafter = drafter if drafter is not None else LEGACY_MODES[mode]
            # legacy callers quantize params themselves: passthrough prepare
            verifier = verifier if verifier is not None else "bf16"
        self.drafter = get_drafter(
            drafter if drafter is not None else scfg.drafter, scfg)
        self.verifier = get_verifier(
            verifier if verifier is not None else scfg.verifier, scfg)
        self._step = jax.jit(
            make_decode_step(model, self.drafter, self.verifier, scfg))
        self._steps_by_temp = {}                   # temperature overrides
        self._prepared = None                      # (params ref, prepared)

    # ------------------------------------------------------------------
    def prepare_params(self, params, act_stats=None):
        """Offline weight preparation for this engine's verifier
        (e.g. SmoothQuant + INT8 for ``w8a8``).  Idempotent."""
        return self.verifier.prepare(self.model, params, act_stats)

    def _prepare_cached(self, params):
        # NOTE: keeps a strong reference to the last input tree as the
        # cache key, so a w8a8 engine pins the BF16 original while alive.
        # Memory-sensitive callers: params = engine.prepare_params(params)
        # once, drop the original, and pass the prepared tree (idempotent).
        if self._prepared is not None and (
                params is self._prepared[0] or params is self._prepared[1]):
            return self._prepared[1]
        self._prepared = (params, self.prepare_params(params))
        return self._prepared[1]

    def _step_for_temperature(self, t: float):
        """(jitted step, drafter) with temperature ``t`` baked in."""
        if t == self.scfg.temperature:
            return self._step, self.drafter
        if t not in self._steps_by_temp:
            if len(self._steps_by_temp) >= _MAX_TEMP_STEPS:
                # each entry pins a compiled executable — evict the oldest
                self._steps_by_temp.pop(next(iter(self._steps_by_temp)))
            scfg_t = dataclasses.replace(self.scfg, temperature=t)
            drafter = self.drafter.with_temperature(t)
            step = jax.jit(
                make_decode_step(self.model, drafter, self.verifier, scfg_t))
            self._steps_by_temp[t] = (step, drafter)
        return self._steps_by_temp[t]

    # ------------------------------------------------------------------
    def _init_state(self, params, prompts, lengths, targets, buf, key, *,
                    drafter, aux_embeds=None, draft_params=None):
        """Prefill + assemble the decode-loop state pytree."""
        B, P = prompts.shape
        assert P >= 2, "prompts must have >= 2 tokens"
        state = init_state(self.model, B, buf, key, target=targets)
        state["tokens"] = state["tokens"].at[:, :P].set(prompts)
        state["length"] = jnp.asarray(lengths, jnp.int32)
        # cache covers committed tokens *except the last* (which becomes
        # the first token of the first verify window) — hence [:, :-1]
        state["cache"] = self.model.prefill(
            params, state["cache"], prompts[:, :-1], aux_embeds=aux_embeds)
        state["drafter_state"] = drafter.init_state(
            self.model, params, prompts, buf,
            aux_embeds=aux_embeds, draft_params=draft_params)
        return state

    def _run(self, step, params, state, max_steps: int):
        """Drive the jitted step until every row reaches its target."""
        t0 = time.perf_counter()
        steps = 0
        while True:
            state = step(params, state)
            steps += 1
            if bool(jnp.all(state["length"] >= state["target"])):
                break
            if steps > max_steps:      # safety: >= 1 token/step guaranteed
                break
        jax.block_until_ready(state["tokens"])
        return state, time.perf_counter() - t0

    # ------------------------------------------------------------------
    def generate(
        self,
        params,
        prompts: jnp.ndarray,          # (B, P) int32
        max_new_tokens: Optional[int] = None,
        *,
        aux_embeds=None,
        key=None,
        draft_params=None,             # pruned drafting with separate params
    ) -> GenResult:
        """Homogeneous batch: shared prompt length and token budget."""
        max_new = max_new_tokens or self.scfg.max_new_tokens
        B, P = prompts.shape
        buf = P + max_new + self.drafter.gamma + 2
        key = key if key is not None else jax.random.PRNGKey(0)

        params = self._prepare_cached(params)
        lengths = jnp.full((B,), P, jnp.int32)
        targets = jnp.full((B,), P + max_new, jnp.int32)
        state = self._init_state(params, prompts, lengths, targets, buf, key,
                                 drafter=self.drafter, aux_embeds=aux_embeds,
                                 draft_params=draft_params)
        state, wall = self._run(self._step, params, state, max_new * 2 + 8)

        commits = state["stats"]["commits"]
        n_steps = int(state["stats"]["steps"])
        # per-row denominator: steps while that row was still generating
        L = float(jnp.mean(
            commits / jnp.maximum(state["stats"]["row_steps"], 1)))
        new_tokens = int(jnp.sum(jnp.minimum(state["length"], P + max_new) - P))
        return GenResult(
            tokens=state["tokens"],
            lengths=state["length"],
            mean_accept_len=L,
            steps=n_steps,
            wall_s=wall,
            new_tokens=new_tokens,
        )

    # ------------------------------------------------------------------
    def generate_requests(
        self,
        params,
        requests: Sequence[GenerationRequest],
        *,
        aux_embeds=None,
        draft_params=None,
    ) -> List[RequestResult]:
        """Serve a batch of requests with heterogeneous prompt lengths,
        budgets and seeds; returns results in request order.

        Heterogeneous *prompt lengths* require attention-family caches
        (right-padding is masked positionally); recurrent-state archs
        (ssm/hybrid) must batch equal-length prompts.
        """
        if not requests:
            return []
        params = self._prepare_cached(params)
        results: List[Optional[RequestResult]] = [None] * len(requests)

        # temperature is jit-static: group requests per effective T
        groups = {}
        for i, r in enumerate(requests):
            t = self.scfg.temperature if r.temperature is None else float(r.temperature)
            groups.setdefault(t, []).append(i)

        for t, idxs in groups.items():
            step, drafter = self._step_for_temperature(t)
            batch = [requests[i] for i in idxs]
            prompts_np, lengths_np = pack_prompts(batch)
            if (len(set(lengths_np.tolist())) > 1
                    and self.model.cfg.arch_type in ("ssm", "hybrid")):
                raise ValueError(
                    f"{self.model.cfg.arch_type} caches are recurrent: "
                    "heterogeneous prompt lengths cannot be right-padded; "
                    "batch equal-length prompts")
            targets_np = lengths_np + np.array(
                [r.max_new_tokens for r in batch], np.int32)
            buf = int(targets_np.max()) + drafter.gamma + 2

            key = jax.random.PRNGKey(len(batch))
            for r in batch:
                key = jax.random.fold_in(key, r.seed)

            state = self._init_state(
                params, jnp.asarray(prompts_np), lengths_np, targets_np,
                buf, key, drafter=drafter, aux_embeds=aux_embeds,
                draft_params=draft_params)
            max_new_max = int((targets_np - lengths_np).max())
            state, wall = self._run(step, params, state, max_new_max * 2 + 8)

            tokens = np.asarray(state["tokens"])
            commits = np.asarray(state["stats"]["commits"])
            row_steps = np.asarray(state["stats"]["row_steps"])
            n_steps = int(state["stats"]["steps"])
            for row, i in enumerate(idxs):
                p = int(lengths_np[row])
                results[i] = RequestResult(
                    request=requests[i],
                    tokens=tokens[row, p: int(targets_np[row])].copy(),
                    prompt_len=p,
                    accept_len=float(commits[row]) / max(int(row_steps[row]), 1),
                    steps=n_steps,
                    wall_s=wall,
                )
        return results
