"""Batched speculative serving engine.

Wraps the jitted step functions from ``repro.core.spec_engine`` with
prompt prefill, the generation loop, and acceptance/throughput statistics.
The engine is verifier-agnostic: pass BF16 params (Ngram baseline), W8A8
quantized params (Quasar), or choose the vanilla / pruned-drafter modes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import SpecConfig
from repro.core.spec_engine import (
    init_state,
    make_pruned_step,
    make_serve_step,
    make_vanilla_step,
)


@dataclass
class GenResult:
    tokens: jnp.ndarray          # (B, S_buf) full buffers
    lengths: jnp.ndarray         # (B,)
    mean_accept_len: float       # L — committed tokens per verify step
    steps: int                   # verify steps taken
    wall_s: float
    new_tokens: int

    @property
    def tokens_per_s(self) -> float:
        return self.new_tokens / max(self.wall_s, 1e-9)


class SpecEngine:
    """mode ∈ {"spec", "vanilla", "pruned"}."""

    def __init__(self, model, scfg: SpecConfig = SpecConfig(), mode: str = "spec"):
        self.model = model
        self.scfg = scfg
        self.mode = mode
        if mode == "spec":
            step = make_serve_step(model, scfg)
        elif mode == "vanilla":
            step = make_vanilla_step(model, scfg.temperature)
        elif mode == "pruned":
            step = make_pruned_step(model, scfg, scfg.pruned_retention)
        else:
            raise ValueError(mode)
        self._step = jax.jit(step)

    # ------------------------------------------------------------------
    def generate(
        self,
        params,
        prompts: jnp.ndarray,          # (B, P) int32
        max_new_tokens: Optional[int] = None,
        *,
        aux_embeds=None,
        key=None,
        draft_params=None,             # pruned mode: params used for drafting
    ) -> GenResult:
        max_new = max_new_tokens or self.scfg.max_new_tokens
        B, P = prompts.shape
        buf = P + max_new + self.scfg.gamma + 2
        key = key if key is not None else jax.random.PRNGKey(0)

        state = init_state(self.model, B, buf, key)
        state["tokens"] = state["tokens"].at[:, :P].set(prompts)
        state["length"] = jnp.full((B,), P, jnp.int32)
        # cache covers committed tokens *except the last* (which becomes the
        # first token of the first verify window) — hence prompts[:, :-1]
        assert P >= 2, "prompts must have ≥ 2 tokens"
        state["cache"] = self.model.prefill(
            params, state["cache"], prompts[:, :-1], aux_embeds=aux_embeds
        )
        if self.mode == "pruned":
            n_keep = max(1, int(round(self.model.cfg.num_layers * self.scfg.pruned_retention)))
            pcache = self.model.init_cache(B, buf, num_layers=n_keep)
            state["pruned_cache"] = self.model.prefill(
                draft_params if draft_params is not None else params,
                pcache, prompts[:, :-1], aux_embeds=aux_embeds, num_layers=n_keep,
            )

        target = P + max_new
        t0 = time.perf_counter()
        steps = 0
        while True:
            state = self._step(params, state)
            steps += 1
            if int(jnp.min(state["length"])) >= target:
                break
            if steps > max_new * 2 + 8:   # safety: ≥1 token/step guaranteed
                break
        jax.block_until_ready(state["tokens"])
        wall = time.perf_counter() - t0

        commits = state["stats"]["commits"]
        n_steps = int(state["stats"]["steps"])
        L = float(jnp.mean(commits / jnp.maximum(n_steps, 1)))
        new_tokens = int(jnp.sum(jnp.minimum(state["length"], target) - P))
        return GenResult(
            tokens=state["tokens"],
            lengths=state["length"],
            mean_accept_len=L,
            steps=n_steps,
            wall_s=wall,
            new_tokens=new_tokens,
        )
