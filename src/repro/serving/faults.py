"""Deterministic fault injection for the serving stack.

A seeded :class:`FaultPlan` decides, at named *seams*, whether an
injected failure fires.  The seams cover every class of runtime fault
the serving loop must contain (``docs/robustness.md`` maps each one to
its containment and observable signal):

  ``submit``        malformed / corrupted request at ingestion
  ``admit``         exception inside the admission (prefill) hook
  ``step``          exception inside the lane's step function
  ``poll``          exception escaping the poll loop (worker crash —
                    exercises the lane supervisor's restart path)
  ``nan_verify``    transient NaN/Inf logits out of the verifier for
                    one step (device bitflip / numerics glitch)
  ``quant_corrupt`` sticky corruption of the lane's *prepared*
                    (quantized) params — every later step is poisoned
                    until the lane re-prepares them
  ``alloc``         ``BlockPool`` allocation failure (admission or
                    mid-``_append_paged_blocks``)
  ``swap_in``       corruption of a preemption snapshot on resume
  ``stall``         slow/hung tick (``delay`` returns a sleep length)

Determinism: each seam owns an independent ``numpy`` Generator seeded
from ``(seed, seam index)`` plus a per-seam call counter, so a plan
replayed against the same deterministic serving run (virtual clock,
single poller) fires at exactly the same points — the chaos gate in
``benchmarks/serve_load.py --chaos`` relies on this to compare a
faulted replay against its fault-free twin bit-for-bit.

Zero overhead when no plan is installed: call sites hold the shared
:data:`NULL_FAULTS` singleton (mirroring ``trace.NULL_TRACER``) whose
``fire`` is a constant ``False`` — they never branch on "is a plan
installed".
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

#: Every seam a plan may target, in a fixed order (the order seeds the
#: per-seam RNG streams — do not reorder, append only).
SEAMS = ("submit", "admit", "step", "poll", "nan_verify", "quant_corrupt",
         "alloc", "swap_in", "stall")


# ---------------------------------------------------------------------------
# Exceptions
# ---------------------------------------------------------------------------

class InjectedFault(RuntimeError):
    """Raised by a firing fault hook — the thing containment contains."""


class RequestFault(RuntimeError):
    """A step-phase failure attributable to specific slots.

    Raised by the lane step function when it can pin a failure on
    particular rows (unrescuable NaN, per-slot block-append failure).
    ``Scheduler.tick`` catches it, adopts ``state`` (a coherent
    engine state to continue from, when the raiser has one), and fails
    only the ``slots`` listed — ``None`` means every occupied slot.
    """

    def __init__(self, msg: str, *, slots: Optional[List[int]] = None,
                 state: Optional[dict] = None,
                 cause: Optional[BaseException] = None):
        super().__init__(msg)
        self.slots = list(slots) if slots is not None else None
        self.state = state
        self.cause = cause


class VerifierNaNError(RuntimeError):
    """Non-finite verifier logits that survived every fallback stage."""


class RequestCancelled(RuntimeError):
    """Terminal error carried by a request the client cancelled."""


class RequestTimeout(RuntimeError):
    """Terminal error carried by a request that exceeded
    ``ServerConfig.request_timeout_s``."""


class LaneCrashed(RuntimeError):
    """Terminal error carried by in-flight requests when the serving
    loop's worker thread crashed (the supervisor records the original
    exception as ``__cause__``)."""


# ---------------------------------------------------------------------------
# Plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultRule:
    """When one seam fires.

    * ``at`` — explicit per-seam call indices (0-based) that always
      fire; the precise scalpel the fault-matrix tests use.
    * ``p`` — independent per-call firing probability (seeded, so still
      deterministic); the chaos benchmark's shotgun.
    * ``count`` — cap on total firings (``None`` = unlimited).
    * ``delay_s`` — for the ``stall`` seam: how long a firing stalls.
    """

    p: float = 0.0
    at: Tuple[int, ...] = ()
    count: Optional[int] = None
    delay_s: float = 0.0


class NullFaultPlan:
    """No-op plan: never fires, never delays.

    Shared singleton (:data:`NULL_FAULTS`) installed by default so call
    sites pay one attribute load + a constant-returning call — the same
    zero-cost-off pattern as ``trace.NULL_TRACER``.
    """

    enabled = False

    def fire(self, seam: str, **ctx) -> bool:  # noqa: ARG002
        return False

    def delay(self, seam: str = "stall") -> float:  # noqa: ARG002
        return 0.0


NULL_FAULTS = NullFaultPlan()


class FaultPlan:
    """Seeded, seam-addressed fault schedule.

    ``rules`` maps seam name → :class:`FaultRule` (or a kwargs dict).
    ``fire(seam, **ctx)`` returns whether this call's fault fires and
    appends a record to ``log`` when it does — the chaos gate uses the
    log to know which requests a run *intended* to disturb.
    """

    enabled = True

    def __init__(self, rules: Dict[str, object], seed: int = 0):
        self.seed = int(seed)
        self.rules: Dict[str, FaultRule] = {}
        for seam, rule in dict(rules).items():
            if seam not in SEAMS:
                raise ValueError(f"unknown fault seam {seam!r}; "
                                 f"expected one of {SEAMS}")
            if isinstance(rule, dict):
                rule = FaultRule(**rule)
            self.rules[seam] = rule
        self.calls: Dict[str, int] = {s: 0 for s in SEAMS}
        self.fired: Dict[str, int] = {s: 0 for s in SEAMS}
        self.log: List[dict] = []
        self._rng = {s: np.random.default_rng([self.seed, k])
                     for k, s in enumerate(SEAMS)}

    def fire(self, seam: str, **ctx) -> bool:
        rule = self.rules.get(seam)
        n = self.calls[seam]
        self.calls[seam] = n + 1
        if rule is None:
            return False
        # the probability draw is unconditional per call (when p > 0) so
        # the stream stays aligned whatever `at` contains
        hit = rule.p > 0.0 and float(self._rng[seam].random()) < rule.p
        hit = hit or (n in rule.at)
        if hit and rule.count is not None and self.fired[seam] >= rule.count:
            hit = False
        if hit:
            self.fired[seam] += 1
            self.log.append({
                "seam": seam, "call": n,
                **{k: v for k, v in sorted(ctx.items())
                   if isinstance(v, (int, float, str, bool))}})
        return hit

    def delay(self, seam: str = "stall") -> float:
        rule = self.rules.get(seam)
        if rule is None:
            return 0.0
        return rule.delay_s if self.fire(seam) else 0.0

    def summary(self) -> Dict[str, Dict[str, int]]:
        return {s: {"calls": self.calls[s], "fired": self.fired[s]}
                for s in SEAMS if self.calls[s] or self.fired[s]}

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0,
              stall_s: float = 1.0) -> "FaultPlan":
        """Build a plan from a compact CLI spec.

        Comma-separated terms: ``seam@i`` / ``seam@i+j+k`` fire at
        explicit call indices; ``seam~p`` fires with probability ``p``
        per call.  ``stall`` terms use ``stall_s`` as the delay.
        Example: ``"step@3,alloc~0.05,nan_verify@2,stall~0.02"``.
        """
        rules: Dict[str, FaultRule] = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "@" in part:
                seam, _, idx = part.partition("@")
                at = tuple(int(x) for x in idx.split("+"))
                rules[seam] = FaultRule(
                    at=at, delay_s=stall_s if seam == "stall" else 0.0)
            elif "~" in part:
                seam, _, p = part.partition("~")
                rules[seam] = FaultRule(
                    p=float(p), delay_s=stall_s if seam == "stall" else 0.0)
            else:
                raise ValueError(
                    f"bad fault term {part!r}: expected seam@i[+j...] "
                    "or seam~p")
        return cls(rules, seed=seed)


# ---------------------------------------------------------------------------
# Injection helpers
# ---------------------------------------------------------------------------

def poison_params(params):
    """Same-structure copy of ``params`` with the largest floating-point
    leaf overwritten with NaN.

    Identical pytree structure and leaf shapes/dtypes, so a jitted step
    accepts it without retracing — the NaN surfaces exactly where a real
    corrupted weight would: in the verifier's logits, caught by the
    per-row ``stats["bad"]`` detector.
    """
    import jax
    import jax.numpy as jnp

    leaves, treedef = jax.tree_util.tree_flatten(params)
    best, best_size = None, -1
    for i, leaf in enumerate(leaves):
        if (hasattr(leaf, "dtype")
                and jnp.issubdtype(leaf.dtype, jnp.floating)
                and leaf.size > best_size):
            best, best_size = i, leaf.size
    if best is None:
        raise ValueError("params tree has no floating-point leaf to poison")
    leaves = list(leaves)
    leaves[best] = jnp.full_like(leaves[best], jnp.nan)
    return jax.tree_util.tree_unflatten(treedef, leaves)
