"""Bounded-memory log-bucketed mergeable histograms.

``ServerMetrics`` previously kept every TTFT/ITL/queue/service sample in
raw Python lists — an unbounded leak on a long-lived server (the module
docstring promised bounded memory; it lied).  :class:`Histogram` fixes
that: samples land in geometrically-spaced buckets (sparse dict, at most
``max_buckets`` entries regardless of sample count), so memory is O(1)
per sample and O(log(max/min)) total, while quantile error is bounded by
one bucket's relative width (``growth - 1``, ~15% by default — tighter
than the natural run-to-run variance of any latency it measures).

Properties the serving stack relies on (tests/test_observability.py):

* **Mergeable**: ``merge`` of per-lane histograms is exactly equivalent
  to single-pass ingestion of the concatenated samples (bucket counts
  are integers; addition commutes) — hypothesis-tested.
* **Exact edges**: ``count``/``sum``/``min``/``max`` are tracked
  exactly, so ``mean`` and ``max`` in summaries are exact, and
  percentile estimates are clamped to the observed ``[min, max]`` —
  a single-sample histogram reports its one value *exactly*, which
  keeps ``ServerMetrics.summary()``'s small-n behaviour (pinned by the
  serving front-end tests) unchanged.
* **Nearest-rank quantiles**: same ceil-based nearest-rank convention
  as ``repro.serving.metrics.percentile`` — the bucket holding the
  k-th smallest sample (k = ⌈q/100·n⌉) is found by cumulative count
  and represented by its geometric midpoint.

Values ≤ ``min_value`` (including zero — ITL of a same-step token) fall
into a dedicated underflow bucket represented as ``min_value`` before
clamping; values ≥ ``max_value`` clamp into the top bucket.  Negative
values are invalid (latencies only) and raise.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, Optional


class Histogram:
    """Log-bucketed histogram: bucket ``i`` covers
    ``[min_value * growth**i, min_value * growth**(i+1))``."""

    __slots__ = ("min_value", "max_value", "growth", "_inv_log_g",
                 "max_buckets", "buckets", "count", "total",
                 "vmin", "vmax")

    def __init__(self, *, min_value: float = 1e-6, max_value: float = 1e7,
                 growth: float = 1.15):
        if not (min_value > 0 and max_value > min_value and growth > 1):
            raise ValueError("need 0 < min_value < max_value, growth > 1")
        self.min_value = float(min_value)
        self.max_value = float(max_value)
        self.growth = float(growth)
        self._inv_log_g = 1.0 / math.log(self.growth)
        # bucket index of max_value, +1 for the underflow bucket (-1)
        self.max_buckets = int(math.ceil(
            math.log(self.max_value / self.min_value) * self._inv_log_g)) + 1
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin: Optional[float] = None
        self.vmax: Optional[float] = None

    # ------------------------------------------------------------------
    def _index(self, v: float) -> int:
        if v <= self.min_value:
            return -1                              # underflow bucket
        i = int(math.floor(math.log(v / self.min_value) * self._inv_log_g))
        return min(i, self.max_buckets - 2)        # clamp overflow to top

    def _bounds(self, i: int) -> tuple:
        if i < 0:
            return (0.0, self.min_value)
        lo = self.min_value * self.growth ** i
        return (lo, lo * self.growth)

    # ------------------------------------------------------------------
    def add(self, v: float, n: int = 1) -> None:
        """Record ``n`` occurrences of value ``v`` (seconds, tokens, ...)."""
        v = float(v)
        if v < 0 or v != v:
            raise ValueError(f"histogram values must be finite >= 0: {v}")
        if n <= 0:
            return
        i = self._index(v)
        self.buckets[i] = self.buckets.get(i, 0) + n
        self.count += n
        self.total += v * n
        self.vmin = v if self.vmin is None else min(self.vmin, v)
        self.vmax = v if self.vmax is None else max(self.vmax, v)

    def extend(self, values: Iterable[float]) -> None:
        for v in values:
            self.add(v)

    def merge(self, other: "Histogram") -> "Histogram":
        """In-place merge; bucket layouts must match exactly."""
        if (other.min_value != self.min_value
                or other.max_value != self.max_value
                or other.growth != self.growth):
            raise ValueError("cannot merge histograms with different "
                             "bucket layouts")
        for i, n in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + n
        self.count += other.count
        self.total += other.total
        if other.vmin is not None:
            self.vmin = other.vmin if self.vmin is None \
                else min(self.vmin, other.vmin)
            self.vmax = other.vmax if self.vmax is None \
                else max(self.vmax, other.vmax)
        return self

    # ------------------------------------------------------------------
    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else math.nan

    def percentile(self, q: float) -> float:
        """Nearest-rank quantile estimate, clamped to the exact observed
        ``[min, max]`` (single-sample and extreme quantiles are exact)."""
        if not self.count:
            return math.nan
        k = max(1, int(math.ceil(q / 100.0 * self.count)))
        k = min(k, self.count)
        seen = 0
        idx = None
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= k:
                idx = i
                break
        lo, hi = self._bounds(idx)
        rep = self.min_value if idx < 0 else math.sqrt(lo * hi)
        return min(max(rep, self.vmin), self.vmax)

    def summary(self) -> dict:
        """Same schema as ``metrics._dist``: ``{"n": 0}`` when empty,
        else n/mean/p50/p99/max (mean and max exact)."""
        if not self.count:
            return {"n": 0}
        return {"n": self.count,
                "mean": self.mean,
                "p50": self.percentile(50),
                "p99": self.percentile(99),
                "max": self.vmax}

    def to_dict(self) -> dict:
        """Full bucket dump (Prometheus-style cumulative export feeds
        off this): upper bounds + counts, sorted."""
        items = sorted(self.buckets.items())
        return {"count": self.count,
                "sum": self.total,
                "min": self.vmin,
                "max": self.vmax,
                "le": [self._bounds(i)[1] for i, _ in items],
                "counts": [n for _, n in items]}

    def __len__(self) -> int:
        return len(self.buckets)

    def __repr__(self) -> str:           # pragma: no cover - debug aid
        return (f"Histogram(n={self.count}, buckets={len(self.buckets)}, "
                f"min={self.vmin}, max={self.vmax})")
