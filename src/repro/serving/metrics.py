"""Serving observability: the SlotEvent audit trail as a metrics surface.

The scheduler's ``SlotEvent`` list started life as a test artifact (the
conservation property tests assert on it).  A long-lived server needs the
same information as *aggregates with bounded memory*: counters
(submitted / admitted / completed / shed), an occupancy gauge, and
per-request latency timelines — time-to-first-token (TTFT) and
inter-token latency (ITL), the two numbers an interactive SLO is written
against (the deployment-side framing of the SD survey, arXiv:2401.07851).

:class:`ServerMetrics` is a sink of host-side hooks the serving
front-end (``repro.serving.server``) calls as requests flow through:

    on_submit → on_admit → on_tokens* → on_finish      (served)
    on_submit → on_shed                                (deadline shed)
    on_submit → [on_admit → on_tokens*] → on_failed    (fault/cancel/timeout)

plus ``on_step`` (per scheduler tick: the occupancy gauge),
``on_slot_event`` (the drain target for ``Scheduler.on_event`` — every
completed occupancy is counted here even when the scheduler's retained
``events`` list is capped), and ``on_decode_step`` (per decode step:
accepted-length and step wall-time samples per drafter×verifier — the
live monitor of the paper's Table-1 signal that quantized verification
preserves acceptance length).  All timestamps come from the caller's
clock (wall or virtual), so load-replay benchmarks produce deterministic
latency distributions.

Latency/acceptance aggregates are **bounded**: samples land in
log-bucketed :class:`repro.serving.histogram.Histogram`\\ s (O(1) per
sample regardless of request count), never in raw lists.  Per-request
timelines are kept in full by default — pass ``keep_timelines=False``
for a months-lived process where only the aggregates should stay
resident; finished/shed timelines are then dropped on fold and memory
stays flat.

Failure containment (``docs/robustness.md``) adds a third terminal
state: ``on_failed`` counts requests that ended in the terminal
``failed`` status, and ``on_guardrail`` accumulates the named
robustness event counters (NaN trips, bf16 rescues, lane restarts,
timeouts, …).  The conservation law becomes three-term:
``completed + shed + failed == submitted``.

``summary()`` returns the JSON-ready schema (documented in
``docs/observability.md``); ``save()`` writes it;
:meth:`ServerMetrics.expose_text` renders a Prometheus-style text
exposition for scrape-based monitoring.  KV-cache gauges are pulled at
summary time from registered sources (:meth:`add_kv_source` — the
serving loop registers each paged lane's ``PagedGroup.snapshot``).
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.histogram import Histogram


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy: metrics
    must stay importable in the scheduler's framework-agnostic layer.

    Nearest-rank proper: the smallest value with at least ``⌈q/100·n⌉``
    samples at or below it (``q=0`` → min, ``q=100`` → max).  The
    previous implementation used Python ``round()``, whose banker's
    rounding made p50 of even-length lists inconsistent with the
    documented method (p50 of ``[1,2,3,4]`` returned 3, not 2).
    """
    if not values:
        return float("nan")
    v = sorted(values)
    k = max(1, min(len(v), math.ceil(q / 100.0 * len(v))))
    return float(v[k - 1])


def _dist(values) -> dict:
    """p50/p99/mean/max summary of a raw sample list."""
    if not values:
        return {"n": 0}
    return {
        "n": len(values),
        "mean": sum(values) / len(values),
        "p50": percentile(values, 50),
        "p99": percentile(values, 99),
        "max": max(values),
    }


@dataclass
class RequestTimeline:
    """Per-request latency timeline (all timestamps on the server clock).

    ``emits`` records every streaming delta as ``(t, n_tokens)``; TTFT
    and ITL derive from it.  A delta carries several tokens when a
    verify step accepts a multi-token draft — its gap is attributed
    evenly across the tokens it committed, so ITL reflects what a
    streaming client observes per token.
    """

    rid: int
    arrival_t: float
    deadline_t: Optional[float] = None     # absolute; None = no SLO
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None
    status: str = "queued"                 # queued|running|done|shed|failed
    degraded: bool = False                 # served by the degraded lane
    emits: List[Tuple[float, int]] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        """Arrival → first streamed token."""
        return self.emits[0][0] - self.arrival_t if self.emits else None

    @property
    def itl(self) -> List[float]:
        """Per-token inter-token gaps after the first delta."""
        gaps: List[float] = []
        if len(self.emits) < 2:
            return gaps
        prev = self.emits[0][0]
        for t, n in self.emits[1:]:
            gaps.extend([(t - prev) / max(n, 1)] * n)
            prev = t
        return gaps

    @property
    def deadline_hit(self) -> Optional[bool]:
        """None when the request has no deadline; any terminal state
        other than ``done`` (shed, failed) counts as a miss."""
        if self.deadline_t is None:
            return None
        if self.status != "done" or self.finish_t is None:
            return False
        return self.finish_t <= self.deadline_t

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "status": self.status,
            "degraded": self.degraded,
            "arrival_t": self.arrival_t,
            "admit_t": self.admit_t,
            "finish_t": self.finish_t,
            "deadline_t": self.deadline_t,
            "deadline_hit": self.deadline_hit,
            "ttft": self.ttft,
            "n_tokens": sum(n for _, n in self.emits),
            "emits": [[t, n] for t, n in self.emits],
        }


class AcceptanceStats:
    """Per drafter×verifier decode-step telemetry: accepted-length and
    step wall-time histograms, bounded memory.

    One entry per ``"drafter:verifier"`` key.  ``accept_len`` samples
    are the per-row tokens committed by one verify step (the live L
    signal); ``step_s`` is host wall time of the whole fused step.
    Owned by both :class:`ServerMetrics` (server view) and
    ``SpecEngine.telemetry`` (engine view, batch/solo paths included).
    """

    def __init__(self):
        self._per_key: Dict[str, dict] = {}

    def _entry(self, key: str) -> dict:
        e = self._per_key.get(key)
        if e is None:
            e = self._per_key[key] = {
                "steps": 0,
                "tokens": 0,
                # accepted lengths are small ints >= 0: min_value .5
                # puts 0 in the underflow bucket and 1, 2, 3... in
                # distinct buckets up to max_value
                "accept_len": Histogram(min_value=0.5, max_value=4096,
                                        growth=1.15),
                "step_s": Histogram(),
            }
        return e

    def on_decode_step(self, key: str, accepted, step_s: float) -> None:
        """One fused decode step: ``accepted`` is the per-active-row
        committed-token count, ``step_s`` the step's wall time."""
        e = self._entry(key)
        e["steps"] += 1
        for a in accepted:
            e["accept_len"].add(float(a))
            e["tokens"] += int(a)
        if step_s >= 0:
            e["step_s"].add(float(step_s))

    def mean_accept(self, key: str) -> Optional[float]:
        """Mean accepted length per row-step (the measured L)."""
        e = self._per_key.get(key)
        if e is None or not e["accept_len"].count:
            return None
        return e["accept_len"].mean

    @property
    def keys(self) -> List[str]:
        return sorted(self._per_key)

    def summary(self) -> dict:
        return {
            key: {
                "steps": e["steps"],
                "committed_tokens": e["tokens"],
                "accept_len": e["accept_len"].summary(),
                "step_s": e["step_s"].summary(),
            }
            for key, e in sorted(self._per_key.items())
        }


# KV-cache snapshot keys summed across registered sources; everything a
# ``PagedGroup.snapshot()`` emits except the non-additive pool gauges.
_KV_SUMMED = (
    "prefix_hits", "prefix_misses", "shared_blocks", "shared_tokens",
    "cold_prefill_tokens", "cow_forks", "resurrections", "cached_evicted",
    "swap_out_blocks", "swap_in_blocks", "swap_out_bytes", "swap_in_bytes",
    "preemptions",
)


class ServerMetrics:
    """Aggregating sink for the serving front-end's lifecycle hooks."""

    def __init__(self, *, keep_timelines: bool = True):
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "completed": 0, "shed": 0,
            "failed": 0, "degraded": 0, "slot_events": 0,
            "stream_tokens": 0, "decode_steps": 0,
        }
        # robustness event counters (docs/robustness.md) — pre-seeded so
        # the zero baseline is visible in every summary/scrape
        self.robustness: Dict[str, int] = {
            "verify_nan_trips": 0,     # steps with non-finite verifier
            #                            logits on an active row
            "retry_rescued_rows": 0,   # rows saved by same-precision retry
            "bf16_rescued_rows": 0,    # rows saved by the bf16 fallback
            "unrescued_rows": 0,       # rows failed after the full ladder
            "collapse_trips": 0,       # acceptance-collapse detections
            "reprepares": 0,           # lane params re-quantized (repair)
            "lane_restarts": 0,        # serving-loop supervisor restarts
            "request_faults": 0,       # requests failed by step/admit fault
            "timeouts": 0,             # requests failed by request_timeout_s
            "cancelled": 0,            # requests failed by client cancel
            "rejected": 0,             # malformed/unservable at submit
        }
        self.keep_timelines = keep_timelines
        self.timelines: Dict[int, RequestTimeline] = {}
        # occupancy gauge: running aggregate, O(1) memory
        self._occ_samples = 0
        self._occ_sum = 0
        self._occ_max = 0
        self._slots_total = 0
        # latency aggregates: bounded log-bucketed histograms (memory is
        # O(buckets), independent of request count — the fix for the
        # unbounded raw lists keep_timelines=False used to accumulate)
        self._ttft = Histogram()
        self._itl = Histogram()
        self._queue = Histogram()
        self._service = Histogram()
        self._deadline_total = 0
        self._deadline_hits = 0
        self.acceptance = AcceptanceStats()
        # keyed by name: a lane rebuilt after a supervisor restart
        # re-registers under the same name and replaces its dead source
        self._kv_sources: Dict[str, Callable[[], dict]] = {}

    # -- lifecycle hooks ------------------------------------------------
    def on_submit(self, rid: int, t: float,
                  deadline_t: Optional[float] = None,
                  degraded: bool = False) -> None:
        self.counters["submitted"] += 1
        if degraded:
            self.counters["degraded"] += 1
        self.timelines[rid] = RequestTimeline(
            rid=rid, arrival_t=t, deadline_t=deadline_t, degraded=degraded)

    def on_admit(self, rid: int, t: float) -> None:
        self.counters["admitted"] += 1
        tl = self.timelines.get(rid)
        if tl is not None:
            tl.admit_t = t
            tl.status = "running"

    def on_tokens(self, rid: int, t: float, n: int) -> None:
        self.counters["stream_tokens"] += int(n)
        tl = self.timelines.get(rid)
        if tl is not None:
            tl.emits.append((t, int(n)))

    def on_finish(self, rid: int, t: float) -> None:
        self.counters["completed"] += 1
        tl = self.timelines.pop(rid) if not self.keep_timelines \
            else self.timelines.get(rid)
        if tl is None:
            return
        tl.finish_t = t
        tl.status = "done"
        self._fold(tl)

    def on_shed(self, rid: int, t: float) -> None:
        self.counters["shed"] += 1
        tl = self.timelines.pop(rid) if not self.keep_timelines \
            else self.timelines.get(rid)
        if tl is None:
            return
        tl.finish_t = t
        tl.status = "shed"
        self._fold(tl)

    def on_failed(self, rid: int, t: float) -> None:
        """Terminal ``failed`` state (fault, cancel, timeout, crash)."""
        self.counters["failed"] += 1
        tl = self.timelines.pop(rid) if not self.keep_timelines \
            else self.timelines.get(rid)
        if tl is None:
            return
        tl.finish_t = t
        tl.status = "failed"
        self._fold(tl)

    def on_guardrail(self, name: str, n: int = 1) -> None:
        """Bump a named robustness event counter (see ``self.robustness``
        for the pre-seeded vocabulary; unknown names are accepted so
        callers can add events without a schema change here)."""
        self.robustness[name] = self.robustness.get(name, 0) + int(n)

    def on_step(self, t: float, busy_slots: int, total_slots: int) -> None:
        """Occupancy gauge sample: one scheduler tick."""
        self._occ_samples += 1
        self._occ_sum += int(busy_slots)
        self._occ_max = max(self._occ_max, int(busy_slots))
        self._slots_total = max(self._slots_total, int(total_slots))

    def on_slot_event(self, ev) -> None:
        """Drain target for ``Scheduler.on_event``: counts completed slot
        occupancies so the audit trail survives in aggregate even when
        the scheduler's retained ``events`` list is capped."""
        self.counters["slot_events"] += 1

    def on_decode_step(self, key: str, accepted, step_s: float) -> None:
        """Per decode step acceptance telemetry (``Scheduler.
        on_step_stats`` target): ``key`` is ``"drafter:verifier"``."""
        self.counters["decode_steps"] += 1
        self.acceptance.on_decode_step(key, accepted, step_s)

    def add_kv_source(self, name: str, snapshot: Callable[[], dict]) -> None:
        """Register a KV-cache gauge source (e.g. one paged lane's
        ``PagedGroup.snapshot``); polled lazily at summary time.
        Re-registering a name replaces the previous source (lane
        restart), so monotone counters restart from the new pool."""
        self._kv_sources[name] = snapshot

    # -- aggregation ----------------------------------------------------
    def _fold(self, tl: RequestTimeline) -> None:
        if tl.deadline_t is not None:
            self._deadline_total += 1
            if tl.deadline_hit:
                self._deadline_hits += 1
        if tl.status != "done":
            return
        if tl.ttft is not None:
            self._ttft.add(tl.ttft)
        for gap in tl.itl:
            self._itl.add(gap)
        if tl.admit_t is not None:
            self._queue.add(tl.admit_t - tl.arrival_t)
            if tl.finish_t is not None:
                self._service.add(tl.finish_t - tl.admit_t)

    @property
    def deadline_hit_rate(self) -> Optional[float]:
        if self._deadline_total == 0:
            return None
        return self._deadline_hits / self._deadline_total

    def check_conservation(self) -> None:
        """No request silently lost: every submitted request reached
        exactly one terminal state — completed + shed + failed."""
        c = self.counters
        if c["completed"] + c["shed"] + c["failed"] != c["submitted"]:
            raise AssertionError(
                f"conservation violated: completed={c['completed']} + "
                f"shed={c['shed']} + failed={c['failed']} "
                f"!= submitted={c['submitted']}")

    def kv_cache_summary(self) -> dict:
        """Aggregate of all registered KV sources (counters summed,
        pool gauges listed per source) + the derived prefix hit rate."""
        out = {k: 0 for k in _KV_SUMMED}
        pools = {}
        for name, snap in self._kv_sources.items():
            s = snap()
            for k in _KV_SUMMED:
                out[k] += int(s.get(k, 0))
            if "pool" in s:
                pools[name] = s["pool"]
        probes = out["prefix_hits"] + out["prefix_misses"]
        out["prefix_hit_rate"] = (out["prefix_hits"] / probes
                                  if probes else None)
        out["sources"] = len(self._kv_sources)
        if pools:
            out["pools"] = pools
        return out

    def summary(self, *, include_requests: bool = False) -> dict:
        """JSON-ready metrics snapshot (schema: docs/observability.md)."""
        out = {
            "counters": dict(self.counters),
            "occupancy": {
                "samples": self._occ_samples,
                "mean": (self._occ_sum / self._occ_samples
                         if self._occ_samples else 0.0),
                "max": self._occ_max,
                "slots": self._slots_total,
            },
            "latency": {
                "ttft_s": self._ttft.summary(),
                "itl_s": self._itl.summary(),
                "queue_s": self._queue.summary(),
                "service_s": self._service.summary(),
            },
            "deadlines": {
                "with_deadline": self._deadline_total,
                "hits": self._deadline_hits,
                "hit_rate": self.deadline_hit_rate,
            },
            "acceptance": self.acceptance.summary(),
            "robustness": dict(self.robustness),
            "kv_cache": self.kv_cache_summary(),
        }
        if include_requests and self.keep_timelines:
            out["requests"] = [self.timelines[r].to_dict()
                               for r in sorted(self.timelines)]
        return out

    def save(self, path: str, *, include_requests: bool = False) -> str:
        with open(path, "w") as f:
            json.dump(self.summary(include_requests=include_requests), f,
                      indent=1)
        return path

    # -- Prometheus-style exposition ------------------------------------
    def expose_text(self) -> str:
        """Prometheus text-format exposition of the summary (counters,
        gauges, latency/acceptance summaries with stat labels, KV-cache
        counters).  Deterministic ordering: scrape diffs are meaningful.
        """
        s = self.summary()
        lines: List[str] = []

        def emit(name, mtype, help_, samples):
            lines.append(f"# HELP {name} {help_}")
            lines.append(f"# TYPE {name} {mtype}")
            for labels, v in samples:
                if v is None or (isinstance(v, float) and math.isnan(v)):
                    continue
                lab = ("{" + ",".join(f'{k}="{val}"'
                                      for k, val in labels) + "}"
                       if labels else "")
                lines.append(f"{name}{lab} {v}")

        emit("serve_requests_total", "counter",
             "Requests by lifecycle outcome.",
             [([("event", k)], v) for k, v in sorted(s["counters"].items())])
        occ = s["occupancy"]
        emit("serve_slot_occupancy", "gauge", "Busy decode slots.",
             [([("stat", k)], occ[k]) for k in ("mean", "max", "slots")])
        for kind, d in sorted(s["latency"].items()):
            name = f"serve_latency_{kind}"
            emit(name, "gauge", f"Latency summary ({kind}).",
                 [([("stat", st)], d.get(st))
                  for st in ("n", "mean", "p50", "p99", "max")])
        emit("serve_robustness_total", "counter",
             "Fault-containment and guardrail event counters.",
             [([("event", k)], v)
              for k, v in sorted(s["robustness"].items())])
        dl = s["deadlines"]
        emit("serve_deadline_hit_rate", "gauge",
             "Deadline hit rate over requests with an SLO.",
             [([], dl["hit_rate"])])
        acc_samples, step_samples = [], []
        for key, e in s["acceptance"].items():
            drafter, _, verifier = key.partition(":")
            base = [("drafter", drafter), ("verifier", verifier)]
            acc_samples.append((base + [("stat", "mean")],
                                e["accept_len"].get("mean")))
            acc_samples.append((base + [("stat", "p50")],
                                e["accept_len"].get("p50")))
            acc_samples.append((base + [("stat", "steps")], e["steps"]))
            acc_samples.append((base + [("stat", "tokens")],
                                e["committed_tokens"]))
            step_samples.append((base + [("stat", "mean")],
                                 e["step_s"].get("mean")))
            step_samples.append((base + [("stat", "p99")],
                                 e["step_s"].get("p99")))
        emit("serve_accept_len", "gauge",
             "Accepted tokens per row-step (live L) by drafter/verifier.",
             acc_samples)
        emit("serve_decode_step_seconds", "gauge",
             "Decode step wall time by drafter/verifier.", step_samples)
        kv = s["kv_cache"]
        emit("serve_kv_cache_total", "counter",
             "Paged KV-cache event counters (summed over lanes).",
             [([("event", k)], kv[k]) for k in _KV_SUMMED])
        emit("serve_kv_prefix_hit_rate", "gauge",
             "Prefix-cache admission hit rate.",
             [([], kv["prefix_hit_rate"])])
        return "\n".join(lines) + "\n"
