"""Serving observability: the SlotEvent audit trail as a metrics surface.

The scheduler's ``SlotEvent`` list started life as a test artifact (the
conservation property tests assert on it).  A long-lived server needs the
same information as *aggregates with bounded memory*: counters
(submitted / admitted / completed / shed), an occupancy gauge, and
per-request latency timelines — time-to-first-token (TTFT) and
inter-token latency (ITL), the two numbers an interactive SLO is written
against (the deployment-side framing of the SD survey, arXiv:2401.07851).

:class:`ServerMetrics` is a sink of host-side hooks the serving
front-end (``repro.serving.server``) calls as requests flow through:

    on_submit → on_admit → on_tokens* → on_finish      (served)
    on_submit → on_shed                                (deadline shed)

plus ``on_step`` (per scheduler tick: the occupancy gauge) and
``on_slot_event`` (the drain target for ``Scheduler.on_event`` — every
completed occupancy is counted here even when the scheduler's retained
``events`` list is capped).  All timestamps come from the caller's clock
(wall or virtual), so load-replay benchmarks produce deterministic
latency distributions.

``summary()`` returns the JSON-ready schema (documented in
``docs/decoding_api.md``); ``save()`` writes it.  Per-request timelines
are kept in full by default — pass ``keep_timelines=False`` for a
months-lived process where only the aggregates should stay resident.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


def percentile(values, q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) without numpy: metrics
    must stay importable in the scheduler's framework-agnostic layer."""
    if not values:
        return float("nan")
    v = sorted(values)
    k = max(0, min(len(v) - 1, round(q / 100.0 * (len(v) - 1))))
    return float(v[int(k)])


def _dist(values) -> dict:
    """p50/p99/mean/max summary of a latency sample list."""
    if not values:
        return {"n": 0}
    return {
        "n": len(values),
        "mean": sum(values) / len(values),
        "p50": percentile(values, 50),
        "p99": percentile(values, 99),
        "max": max(values),
    }


@dataclass
class RequestTimeline:
    """Per-request latency timeline (all timestamps on the server clock).

    ``emits`` records every streaming delta as ``(t, n_tokens)``; TTFT
    and ITL derive from it.  A delta carries several tokens when a
    verify step accepts a multi-token draft — its gap is attributed
    evenly across the tokens it committed, so ITL reflects what a
    streaming client observes per token.
    """

    rid: int
    arrival_t: float
    deadline_t: Optional[float] = None     # absolute; None = no SLO
    admit_t: Optional[float] = None
    finish_t: Optional[float] = None
    status: str = "queued"                 # queued|running|done|shed
    degraded: bool = False                 # served by the degraded lane
    emits: List[Tuple[float, int]] = field(default_factory=list)

    @property
    def ttft(self) -> Optional[float]:
        """Arrival → first streamed token."""
        return self.emits[0][0] - self.arrival_t if self.emits else None

    @property
    def itl(self) -> List[float]:
        """Per-token inter-token gaps after the first delta."""
        gaps: List[float] = []
        if len(self.emits) < 2:
            return gaps
        prev = self.emits[0][0]
        for t, n in self.emits[1:]:
            gaps.extend([(t - prev) / max(n, 1)] * n)
            prev = t
        return gaps

    @property
    def deadline_hit(self) -> Optional[bool]:
        """None when the request has no deadline; shed counts as a miss."""
        if self.deadline_t is None:
            return None
        if self.status == "shed" or self.finish_t is None:
            return False
        return self.finish_t <= self.deadline_t

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "status": self.status,
            "degraded": self.degraded,
            "arrival_t": self.arrival_t,
            "admit_t": self.admit_t,
            "finish_t": self.finish_t,
            "deadline_t": self.deadline_t,
            "deadline_hit": self.deadline_hit,
            "ttft": self.ttft,
            "n_tokens": sum(n for _, n in self.emits),
            "emits": [[t, n] for t, n in self.emits],
        }


class ServerMetrics:
    """Aggregating sink for the serving front-end's lifecycle hooks."""

    def __init__(self, *, keep_timelines: bool = True):
        self.counters: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "completed": 0, "shed": 0,
            "degraded": 0, "slot_events": 0, "stream_tokens": 0,
        }
        self.keep_timelines = keep_timelines
        self.timelines: Dict[int, RequestTimeline] = {}
        # occupancy gauge: running aggregate, O(1) memory
        self._occ_samples = 0
        self._occ_sum = 0
        self._occ_max = 0
        self._slots_total = 0
        # latency aggregates survive even with keep_timelines=False
        self._ttft: List[float] = []
        self._itl: List[float] = []
        self._queue: List[float] = []
        self._service: List[float] = []
        self._deadline_total = 0
        self._deadline_hits = 0

    # -- lifecycle hooks ------------------------------------------------
    def on_submit(self, rid: int, t: float,
                  deadline_t: Optional[float] = None,
                  degraded: bool = False) -> None:
        self.counters["submitted"] += 1
        if degraded:
            self.counters["degraded"] += 1
        self.timelines[rid] = RequestTimeline(
            rid=rid, arrival_t=t, deadline_t=deadline_t, degraded=degraded)

    def on_admit(self, rid: int, t: float) -> None:
        self.counters["admitted"] += 1
        tl = self.timelines.get(rid)
        if tl is not None:
            tl.admit_t = t
            tl.status = "running"

    def on_tokens(self, rid: int, t: float, n: int) -> None:
        self.counters["stream_tokens"] += int(n)
        tl = self.timelines.get(rid)
        if tl is not None:
            tl.emits.append((t, int(n)))

    def on_finish(self, rid: int, t: float) -> None:
        self.counters["completed"] += 1
        tl = self.timelines.pop(rid) if not self.keep_timelines \
            else self.timelines.get(rid)
        if tl is None:
            return
        tl.finish_t = t
        tl.status = "done"
        self._fold(tl)

    def on_shed(self, rid: int, t: float) -> None:
        self.counters["shed"] += 1
        tl = self.timelines.pop(rid) if not self.keep_timelines \
            else self.timelines.get(rid)
        if tl is None:
            return
        tl.finish_t = t
        tl.status = "shed"
        self._fold(tl)

    def on_step(self, t: float, busy_slots: int, total_slots: int) -> None:
        """Occupancy gauge sample: one scheduler tick."""
        self._occ_samples += 1
        self._occ_sum += int(busy_slots)
        self._occ_max = max(self._occ_max, int(busy_slots))
        self._slots_total = max(self._slots_total, int(total_slots))

    def on_slot_event(self, ev) -> None:
        """Drain target for ``Scheduler.on_event``: counts completed slot
        occupancies so the audit trail survives in aggregate even when
        the scheduler's retained ``events`` list is capped."""
        self.counters["slot_events"] += 1

    # -- aggregation ----------------------------------------------------
    def _fold(self, tl: RequestTimeline) -> None:
        if tl.deadline_t is not None:
            self._deadline_total += 1
            if tl.deadline_hit:
                self._deadline_hits += 1
        if tl.status != "done":
            return
        if tl.ttft is not None:
            self._ttft.append(tl.ttft)
        self._itl.extend(tl.itl)
        if tl.admit_t is not None:
            self._queue.append(tl.admit_t - tl.arrival_t)
            if tl.finish_t is not None:
                self._service.append(tl.finish_t - tl.admit_t)

    @property
    def deadline_hit_rate(self) -> Optional[float]:
        if self._deadline_total == 0:
            return None
        return self._deadline_hits / self._deadline_total

    def check_conservation(self) -> None:
        """No request silently lost: completed + shed == submitted."""
        c = self.counters
        if c["completed"] + c["shed"] != c["submitted"]:
            raise AssertionError(
                f"conservation violated: completed={c['completed']} + "
                f"shed={c['shed']} != submitted={c['submitted']}")

    def summary(self, *, include_requests: bool = False) -> dict:
        """JSON-ready metrics snapshot (schema: docs/decoding_api.md)."""
        out = {
            "counters": dict(self.counters),
            "occupancy": {
                "samples": self._occ_samples,
                "mean": (self._occ_sum / self._occ_samples
                         if self._occ_samples else 0.0),
                "max": self._occ_max,
                "slots": self._slots_total,
            },
            "latency": {
                "ttft_s": _dist(self._ttft),
                "itl_s": _dist(self._itl),
                "queue_s": _dist(self._queue),
                "service_s": _dist(self._service),
            },
            "deadlines": {
                "with_deadline": self._deadline_total,
                "hits": self._deadline_hits,
                "hit_rate": self.deadline_hit_rate,
            },
        }
        if include_requests and self.keep_timelines:
            out["requests"] = [self.timelines[r].to_dict()
                               for r in sorted(self.timelines)]
        return out

    def save(self, path: str, *, include_requests: bool = False) -> str:
        with open(path, "w") as f:
            json.dump(self.summary(include_requests=include_requests), f,
                      indent=1)
        return path
