"""Request-level serving types: per-request prompts, budgets, and results.

``SpecEngine.generate_requests`` serves a list of
:class:`GenerationRequest` with heterogeneous prompt lengths,
``max_new_tokens``, seeds and temperatures through the continuous-batching
scheduler (:class:`repro.serving.scheduler.Scheduler`):

* a fixed number of batch *slots* steps in one jit-compiled decode loop;
  prompts are right-padded to the serving group's maximum (padding junk
  beyond a row's committed length is never attended — verify windows
  overwrite positions before the causal frontier reaches them);
* a per-row ``target`` slot in the engine state masks commits, so a row
  that exhausts its budget freezes exactly there; the scheduler harvests
  it and admits the next pending request into the freed slot
  (prefill-into-slot — no recompilation, the decode step stays
  fixed-shape);
* each request's ``seed`` derives a per-row PRNG stream
  (``repro.core.prng.request_key``), so generated tokens are invariant to
  batch composition, admission order and slot placement;
* requests with different temperatures are grouped and scheduled per
  group (temperature is a jit-static of the decode step).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


def safe_rate(count: int, seconds: float) -> float:
    """``count / seconds`` guarded against zero/negative time.

    Fast CPU runs (and synthetic test loops) can legitimately record a
    0.0 wall/service time; a rate of 0.0 is the honest answer there —
    not a division crash, and not the absurd ``count / 1e-9`` spike.
    """
    return float(count) / seconds if seconds > 0.0 else 0.0


@dataclass
class GenerationRequest:
    """One decode request.

    ``temperature=None`` inherits the engine's ``SpecConfig.temperature``.
    ``seed`` derives the request's own PRNG stream: the generated tokens
    depend only on (prompt, seed, temperature, params), never on which
    other requests happened to share the batch.
    ``priority`` orders *admission* (lower = more urgent; FIFO within a
    priority class) — it shifts ``queue_s``, never the generated tokens.
    ``deadline_s`` is the request's SLO: seconds from *submission* by
    which the full generation should complete.  Under ``admission="edf"``
    pending requests are ordered earliest-deadline-first within their
    priority class, and the serving front-end
    (``repro.serving.server``) may shed a request whose deadline passed
    while it was still queued.  Like ``priority`` it only reorders
    admission — never the generated tokens.
    """

    prompt: np.ndarray                  # (P,) int32 token ids, P >= 2
    max_new_tokens: int = 64
    temperature: Optional[float] = None
    seed: int = 0
    priority: int = 0
    deadline_s: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 2:
            raise ValueError("prompt must have >= 2 tokens")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.deadline_s is not None and not self.deadline_s > 0.0:
            raise ValueError("deadline_s must be positive (or None)")

    def violation(self, max_prompt_len: int,
                  max_new_tokens: int) -> Optional[str]:
        """Why this request cannot be served under the given server caps
        (None if it can).  The serving front-end rejects a violating
        request as terminally ``failed`` instead of raising into the
        caller — one bad request never takes down the submit path
        (docs/robustness.md)."""
        if self.prompt.size > max_prompt_len:
            return (f"prompt length {self.prompt.size} exceeds the "
                    f"server's max_prompt_len={max_prompt_len}")
        if self.max_new_tokens > max_new_tokens:
            return (f"max_new_tokens {self.max_new_tokens} exceeds the "
                    f"server's cap {max_new_tokens}")
        return None


@dataclass
class RequestResult:
    """Per-request generation output (all fields are request-level)."""

    request: GenerationRequest
    tokens: np.ndarray                  # (max_new_tokens,) int32 new tokens
    prompt_len: int
    accept_len: float                   # committed tokens per verify step
    #                                     while this request occupied a slot
    steps: int                          # verify steps this request was
    #                                     actively decoding for
    queue_s: float                      # time spent waiting for a slot
    service_s: float                    # time from slot admission to the
    #                                     step that completed the request

    @property
    def wall_s(self) -> float:
        """End-to-end request latency: queueing + service."""
        return self.queue_s + self.service_s

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.size)

    @property
    def tokens_per_s(self) -> float:
        """Decode throughput while the request held a slot (0.0 when the
        recorded service time is zero — see :func:`safe_rate`)."""
        return safe_rate(self.new_tokens, self.service_s)

    @property
    def sequence(self) -> np.ndarray:
        """prompt + generated tokens."""
        return np.concatenate([self.request.prompt, self.tokens])


def pack_prompts(requests) -> tuple:
    """Right-pad request prompts to a fixed-shape batch.

    Returns ``(prompts (B, Pmax) int32, lengths (B,) int32)``.  Pad slots
    repeat the row's last real token; they sit beyond the row's committed
    length, so drafting masks them and the cache positions they prefill
    are overwritten/causally masked before ever being read.
    """
    if not requests:
        raise ValueError("pack_prompts needs at least one request")
    lengths = np.array([r.prompt.size for r in requests], np.int32)
    pmax = int(lengths.max())
    out = np.empty((len(requests), pmax), np.int32)
    for i, r in enumerate(requests):
        out[i, : r.prompt.size] = r.prompt
        out[i, r.prompt.size :] = r.prompt[-1]
    return out, lengths


def pad_prompt(prompt: np.ndarray, pmax: int) -> np.ndarray:
    """Right-pad one prompt to ``pmax`` with its last real token (the
    single-row analogue of :func:`pack_prompts`, used by slot admission)."""
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    out = np.full((pmax,), prompt[-1], np.int32)
    out[: prompt.size] = prompt
    return out
