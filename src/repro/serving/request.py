"""Request-level serving types: per-request prompts, budgets, and results.

``SpecEngine.generate_requests`` serves a list of
:class:`GenerationRequest` with heterogeneous prompt lengths,
``max_new_tokens`` and seeds in one fixed-shape batched decode loop:

* prompts are right-padded to the batch maximum (padding junk beyond a
  row's committed length is never attended — verify windows overwrite
  positions before the causal frontier reaches them);
* a per-row ``target`` slot in the engine state masks commits, so rows
  that finish early freeze exactly at their budget while the batch keeps
  stepping (early-exit masking);
* requests with different temperatures are grouped and served per group
  (temperature is a jit-static of the decode step).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class GenerationRequest:
    """One decode request.

    ``temperature=None`` inherits the engine's ``SpecConfig.temperature``.
    ``seed`` feeds the batch PRNG derivation (sampling noise is shared
    across a batch — per-request streams are reproducible for a fixed
    batch composition, not across different co-batchings).
    """

    prompt: np.ndarray                  # (P,) int32 token ids, P >= 2
    max_new_tokens: int = 64
    temperature: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size < 2:
            raise ValueError("prompt must have >= 2 tokens")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclass
class RequestResult:
    """Per-request generation output."""

    request: GenerationRequest
    tokens: np.ndarray                  # (max_new_tokens,) int32 new tokens
    prompt_len: int
    accept_len: float                   # committed tokens per verify step
    #                                     (counted while the row was active)
    steps: int                          # verify steps of the serving group
    wall_s: float                       # wall time of the serving group

    @property
    def new_tokens(self) -> int:
        return int(self.tokens.size)

    @property
    def sequence(self) -> np.ndarray:
        """prompt + generated tokens."""
        return np.concatenate([self.request.prompt, self.tokens])


def pack_prompts(requests) -> tuple:
    """Right-pad request prompts to a fixed-shape batch.

    Returns ``(prompts (B, Pmax) int32, lengths (B,) int32)``.  Pad slots
    repeat the row's last real token; they sit beyond the row's committed
    length, so drafting masks them and the cache positions they prefill
    are overwritten/causally masked before ever being read.
    """
    if not requests:
        raise ValueError("pack_prompts needs at least one request")
    lengths = np.array([r.prompt.size for r in requests], np.int32)
    pmax = int(lengths.max())
    out = np.empty((len(requests), pmax), np.int32)
    for i, r in enumerate(requests):
        out[i, : r.prompt.size] = r.prompt
        out[i, r.prompt.size :] = r.prompt[-1]
    return out, lengths
