"""Sampling utilities shared by the engine and the verifier."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(key, logits: jax.Array, temperature: float) -> jax.Array:
    """(..., V) logits → token ids.  T=0 ⇒ greedy."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature
    ).astype(jnp.int32)
