"""Slot-level continuous batching: admit → step → harvest.

The scheduler keeps the fixed-shape batched decode loop saturated.  A
serving *group* (requests sharing a temperature) gets ``batch_slots``
rows in the engine-state pytree; the scheduler

1. **admits** pending requests into free slots
   (``SpecEngine.prefill_into_slot`` resets the row's token buffer,
   KV/SSM cache slice, drafter-state row, per-row PRNG stream, ``length``
   / ``target`` and per-row stats — all pure host-side ``.at[row].set``
   scatters, so the jit-compiled decode step never retraces);
2. **steps** the whole batch through the jitted decode step;
3. **harvests** rows whose per-row ``target`` fired (``length >=
   target``), records the request's tokens + queue/service timing, and
   frees the slot for the next admission;

until the pending queue drains and every slot is empty.  Because each
row's PRNG stream, cache slice and token buffer are functions of its own
request only, the harvested tokens are bit-identical to serving the
request solo — scheduling is an invisible throughput optimisation, never
a semantic one (the losslessness framing of Draft & Verify, arXiv:
2309.08168, extended to the serving loop).

The scheduler is deliberately array-framework-agnostic: it orchestrates
via two callables (``admit``, ``step``) and reads the canonical engine
state schema (``repro.core.spec_engine.init_state``) with
``np.asarray``.  That keeps it unit-testable without a model and reusable
by any engine that honours the state schema.
"""
from __future__ import annotations

import heapq
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.serving.request import GenerationRequest, RequestResult


@dataclass
class SlotEvent:
    """Audit-trail entry: one request's occupancy of one slot."""

    request_index: int
    slot: int
    admit_step: int            # scheduler step count at admission
    harvest_step: int = -1     # step count when the row was harvested


@dataclass
class Scheduler:
    """Continuous-batching loop over a fixed number of decode slots.

    ``run`` returns per-request :class:`RequestResult` in request order.
    Admission is **priority-aware**: pending requests pop by
    ``(request.priority, arrival index)`` — lower priority value first,
    FIFO within a class — so an urgent late arrival jumps the queue the
    moment a slot frees, while the all-default case is plain FIFO.
    Priority only reorders *admission* (it shifts ``queue_s``); per-row
    seed streams keep every request's tokens independent of when it was
    admitted.  The ``events`` audit trail records every (request, slot)
    occupancy with admit/harvest step counts — the property tests assert
    the scheduler's conservation laws on it (every request served exactly
    once, no slot double-booked).
    """

    requests: Sequence[GenerationRequest]
    batch_slots: int
    events: List[SlotEvent] = field(default_factory=list)
    steps: int = 0             # decode steps taken by the loop

    def __post_init__(self):
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        self.requests = list(self.requests)
        self._pending = [(int(getattr(r, "priority", 0)), i)
                         for i, r in enumerate(self.requests)]
        heapq.heapify(self._pending)
        self._slots: List[Optional[SlotEvent]] = [None] * self.batch_slots

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._pending) or any(
            ev is not None for ev in self._slots)

    def run(
        self,
        state: dict,
        *,
        admit: Callable[[dict, int, int], dict],
        step: Callable[[dict], dict],
        t0: Optional[float] = None,
        can_admit: Optional[Callable[[int], bool]] = None,
        release: Optional[Callable[[dict, int, int], dict]] = None,
    ) -> tuple:
        """Drive the loop until the queue drains.

        Lifecycle hooks (all host-side callables):

        * ``admit(state, slot, request_index) -> state`` — **required**.
          Must return the state with ``slot`` prefilled for the request
          (every per-row slice reset; see
          ``SpecEngine.prefill_into_slot``).  Called whenever a slot is
          free and the pending queue is non-empty.
        * ``step(state) -> state`` — **required**.  Advances the whole
          batch one verify step (typically the jitted decode step, plus
          any host-side bookkeeping such as paged block appends).
        * ``can_admit(request_index) -> bool`` — optional admission
          gate, consulted for the *head* of the priority queue before
          each admission.  A ``False`` stops this wave's admissions
          (head-of-line blocking — a denied high-priority request is
          never overtaken by a cheaper one, so priority order and
          token-stream invariance are preserved).  The paged KV engine
          uses this to admit only requests whose worst-case block
          demand fits the pool.
        * ``release(state, slot, request_index) -> state`` — optional
          harvest hook, called after a finished request's result is
          recorded and before the slot is marked free.  The paged KV
          engine returns the request's cache blocks to the pool here
          **and resets the slot's block-table row to scratch** — an idle
          row keeps stepping, and its (discarded) window writes must not
          land in blocks the free list may hand to the next admission.

        ``t0`` is the arrival timestamp the requests' ``queue_s`` is
        measured from (``time.perf_counter`` clock) — callers serving
        several scheduler loops sequentially pass the call-level start so
        later loops report the full wait.  Raises ``RuntimeError`` if
        ``can_admit`` permanently rejects the queue head while every
        slot is idle (a request that can never be served).  Returns
        ``(state, results)`` with ``results`` in request order.
        """
        results: List[Optional[RequestResult]] = [None] * len(self.requests)
        t0 = time.perf_counter() if t0 is None else t0
        admit_t = [time.perf_counter()] * self.batch_slots
        # hard safety: every active row commits >= 1 token per step, so
        # the loop is bounded by the total token budget (+ slack per wave)
        max_steps = sum(r.max_new_tokens for r in self.requests) \
            + 8 * (len(self.requests) + self.batch_slots) + 8

        while self.busy:
            for slot in range(self.batch_slots):
                if self._slots[slot] is None and self._pending:
                    # head-of-line gate: a denied head blocks the wave so
                    # admission order (and queue_s) stays priority-exact
                    if can_admit is not None \
                            and not can_admit(self._pending[0][1]):
                        break
                    _, i = heapq.heappop(self._pending)
                    # stamp before admit(): prefill cost is service, not
                    # queueing
                    admit_t[slot] = time.perf_counter()
                    state = admit(state, slot, i)
                    ev = SlotEvent(request_index=i, slot=slot,
                                   admit_step=self.steps)
                    self._slots[slot] = ev
                    self.events.append(ev)

            if self._pending and all(ev is None for ev in self._slots):
                # every slot idle yet the head was denied: it can never
                # be admitted (e.g. demand larger than the whole pool)
                raise RuntimeError(
                    f"request {self._pending[0][1]} rejected by can_admit "
                    "with every slot idle — it can never be served")

            state = step(state)
            self.steps += 1

            lengths = np.asarray(state["length"])
            targets = np.asarray(state["target"])
            done = [s for s in range(self.batch_slots)
                    if self._slots[s] is not None
                    and lengths[s] >= targets[s]]
            if done:
                now = time.perf_counter()
                tokens = np.asarray(state["tokens"])
                commits = np.asarray(state["stats"]["commits"])
                row_steps = np.asarray(state["stats"]["row_steps"])
                for s in done:
                    ev = self._slots[s]
                    ev.harvest_step = self.steps
                    r = self.requests[ev.request_index]
                    P = r.prompt.size
                    results[ev.request_index] = RequestResult(
                        request=r,
                        tokens=tokens[s, P: P + r.max_new_tokens].copy(),
                        prompt_len=P,
                        accept_len=float(commits[s])
                        / max(int(row_steps[s]), 1),
                        steps=int(row_steps[s]),
                        queue_s=admit_t[s] - t0,
                        service_s=now - admit_t[s],
                    )
                    if release is not None:
                        state = release(state, s, ev.request_index)
                    self._slots[s] = None

            if self.steps > max_steps:
                stuck = [ev.request_index for ev in self._slots
                         if ev is not None]
                raise RuntimeError(
                    f"scheduler failed to drain: {len(self._pending)} "
                    f"pending, slots stuck on requests {stuck} after "
                    f"{self.steps} steps")
        return state, results
