"""Slot-level continuous batching: admit → step → harvest.

The scheduler keeps the fixed-shape batched decode loop saturated.  A
serving *group* (requests sharing a temperature) gets ``batch_slots``
rows in the engine-state pytree; the scheduler

1. **admits** pending requests into free slots
   (``SpecEngine.prefill_into_slot`` resets the row's token buffer,
   KV/SSM cache slice, drafter-state row, per-row PRNG stream, ``length``
   / ``target`` and per-row stats — all pure host-side ``.at[row].set``
   scatters, so the jit-compiled decode step never retraces);
2. **steps** the whole batch through the jitted decode step;
3. **harvests** rows whose per-row ``target`` fired (``length >=
   target``), records the request's tokens + queue/service timing, and
   frees the slot for the next admission;

until the pending queue drains and every slot is empty.  Because each
row's PRNG stream, cache slice and token buffer are functions of its own
request only, the harvested tokens are bit-identical to serving the
request solo — scheduling is an invisible throughput optimisation, never
a semantic one (the losslessness framing of Draft & Verify, arXiv:
2309.08168, extended to the serving loop).

Two driving modes share the same machinery:

* **batch** — :meth:`Scheduler.run` drains a fixed request list and
  returns results in request order (``SpecEngine.generate_requests``);
* **open-loop** — the serving front-end (``repro.serving.server``)
  :meth:`submit`\\ s requests as they arrive and calls :meth:`tick`
  once per decode step, interleaving arrival ingestion, deadline
  shedding (:meth:`shed_pending`) and harvesting forever.

Admission order is a policy: ``"fifo"`` pops pending requests by
``(priority, arrival)``; ``"edf"`` pops by ``(priority, deadline,
arrival)`` — earliest-deadline-first within a priority class, which is
the optimal single-machine policy for deadline hit-rate under overload.
Both only reorder *admission*: per-request seed streams keep the
generated tokens invariant to scheduling (asserted per drafter ×
verifier in tests/test_serving_frontend.py).

Per-request **streaming** rides the harvest machinery: pass
``on_tokens`` to :meth:`run`/:meth:`tick` and after every step each
occupied row's newly-committed tokens are forwarded as
``on_tokens(request_index, np.ndarray)``.  The concatenation of a
request's deltas is bit-identical to its final ``RequestResult.tokens``
(committed positions are never rewritten — the same invariant the
verify-window cache writes rely on).

The scheduler is deliberately array-framework-agnostic: it orchestrates
via two callables (``admit``, ``step``) and reads the canonical engine
state schema (``repro.core.spec_engine.init_state``) with
``np.asarray``.  That keeps it unit-testable without a model and reusable
by any engine that honours the state schema.
"""
from __future__ import annotations

import heapq
import math
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.faults import RequestFault
from repro.serving.request import GenerationRequest, RequestResult
from repro.serving.trace import NULL_TRACER


@dataclass
class SlotEvent:
    """Audit-trail entry: one request's occupancy of one slot."""

    request_index: int
    slot: int
    admit_step: int            # scheduler step count at admission
    harvest_step: int = -1     # step count when the row was harvested
    streamed: int = 0          # new tokens already forwarded via on_tokens
    preempted: bool = False    # occupancy ended by eviction, not harvest
    failed: bool = False       # occupancy ended by a contained failure


@dataclass
class Scheduler:
    """Continuous-batching loop over a fixed number of decode slots.

    ``run`` returns per-request :class:`RequestResult` in request order.
    Admission is **priority-aware**: pending requests pop by
    ``(request.priority, arrival index)`` — lower priority value first,
    FIFO within a class — so an urgent late arrival jumps the queue the
    moment a slot frees, while the all-default case is plain FIFO.
    With ``policy="edf"`` the key becomes ``(priority, deadline,
    arrival)``: earliest absolute deadline first inside each priority
    class (requests without a deadline sort last).  Priority and policy
    only reorder *admission* (they shift ``queue_s``); per-row seed
    streams keep every request's tokens independent of when it was
    admitted.

    The ``events`` audit trail records every (request, slot) occupancy
    with admit/harvest step counts — the property tests assert the
    scheduler's conservation laws on it (every request served exactly
    once, no slot double-booked).  A long-lived server bounds its
    growth: ``max_events`` caps the retained list (oldest dropped
    first), and ``on_event`` streams each *completed* event (harvest
    time, so admit/harvest steps are both final) to an observability
    sink before any trimming — set both and the full trail survives in
    aggregate form while the in-memory list stays O(cap).  Both default
    off, keeping test-mode behaviour byte-identical.

    Conservation counters for the open-loop mode: ``submitted`` (all
    requests ever accepted), ``results`` (request index → result) and
    ``shed_indices`` (requests dropped by :meth:`shed_pending` before
    ever holding a slot) and ``failed`` (request index → exception: the
    terminal state of requests killed by a contained failure).
    ``completed + shed + failed == submitted`` once idle — no request
    is silently lost (property-tested; :meth:`check_conservation`).

    **Observability** (all optional, zero-cost when unset):

    * ``tracer`` — a :class:`repro.serving.trace.Tracer`.  The scheduler
      emits per-tick duration spans (``tick`` → ``admit`` / ``decode`` /
      ``harvest`` / ``preempt`` on track ``trace_tid``) and per-request
      async lifecycle phases (``queued`` → ``running`` → finish, with
      ``preempted`` interludes and ``shed`` instants) keyed by the
      request's trace id.
    * ``trace_ids`` — external ids for the batch path's initial
      requests (``generate_requests`` passes the caller's request
      indices); open-loop callers pass ``trace_id=`` per
      :meth:`submit`.  Defaults to the scheduler-local index.
    * ``on_step_stats(accepted, step_s, n_tokens)`` — called after
      every decode step with the per-active-row committed-token counts
      (derived host-side from the length deltas the harvest already
      reads — no extra device sync), the step wall time, and their sum.
      The serving loop folds this into acceptance histograms per
      drafter×verifier.
    """

    requests: Sequence[GenerationRequest]
    batch_slots: int
    policy: str = "fifo"                       # "fifo" | "edf"
    max_events: Optional[int] = None           # retained-events cap
    on_event: Optional[Callable[[SlotEvent], None]] = None
    events: List[SlotEvent] = field(default_factory=list)
    steps: int = 0             # decode steps taken by the loop
    preemptions: int = 0       # running slots evicted for a better head
    tracer: Optional[object] = None            # trace.Tracer (or None)
    trace_tid: int = 0                         # tracer track for spans
    trace_ids: Optional[Sequence[int]] = None  # ids for initial requests
    on_step_stats: Optional[Callable[[List[int], float, int], None]] = None

    def __post_init__(self):
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.policy not in ("fifo", "edf"):
            raise ValueError(f"unknown admission policy {self.policy!r}; "
                             "expected 'fifo' or 'edf'")
        if self.max_events is not None and self.max_events < 0:
            raise ValueError("max_events must be >= 0 (or None)")
        initial = list(self.requests)
        self.requests = []
        self.results: Dict[int, RequestResult] = {}
        self.shed_indices: List[int] = []
        # terminal `failed` state: request index -> the exception that
        # killed it.  Conservation becomes
        # completed + shed + failed == submitted (check_conservation)
        self.failed: Dict[int, BaseException] = {}
        self._deadlines: List[float] = []      # absolute, math.inf = none
        self._arrival_t: List[float] = []
        self._pending: List[tuple] = []
        self._slots: List[Optional[SlotEvent]] = [None] * self.batch_slots
        self._admit_t = [0.0] * self.batch_slots
        # preemption accounting: queue_s is measured to the FIRST
        # admission (being evicted and resumed is service disruption,
        # not queueing) and streaming resumes where it left off
        self._first_admit_t: Dict[int, float] = {}
        self._resume_streamed: Dict[int, int] = {}
        self._tr = self.tracer if self.tracer is not None else NULL_TRACER
        self._trace_ids_list: List[int] = []
        # host-side committed length per slot: admission knows the
        # prompt length (fresh) or the preemption snapshot (resume), and
        # the harvest already reads post-step lengths — so per-step
        # accepted-token counts cost zero extra device syncs
        self._row_len = [0] * self.batch_slots
        self._preempted_len: Dict[int, int] = {}
        self._preempted: set = set()
        ids = list(self.trace_ids) if self.trace_ids is not None else None
        if ids is not None and len(ids) != len(initial):
            raise ValueError("trace_ids must match the initial requests")
        now = time.perf_counter()
        for j, r in enumerate(initial):
            self.submit(r, arrival_t=now,
                        trace_id=ids[j] if ids is not None else None)

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return bool(self._pending) or any(
            ev is not None for ev in self._slots)

    @property
    def submitted(self) -> int:
        return len(self.requests)

    @property
    def completed(self) -> int:
        return len(self.results)

    @property
    def shed(self) -> int:
        return len(self.shed_indices)

    @property
    def failed_count(self) -> int:
        return len(self.failed)

    def check_conservation(self) -> None:
        """Assert the terminal-state conservation law: every submitted
        request is exactly one of completed / shed / failed (meaningful
        once ``busy`` is False)."""
        got = self.completed + self.shed + self.failed_count
        assert got == self.submitted, (
            f"conservation broken: completed {self.completed} + shed "
            f"{self.shed} + failed {self.failed_count} = {got} "
            f"!= submitted {self.submitted}")

    def _key(self, i: int) -> tuple:
        pr = int(getattr(self.requests[i], "priority", 0))
        if self.policy == "edf":
            return (pr, self._deadlines[i], i)
        return (pr, i)

    # ------------------------------------------------------------------
    def submit(self, request: GenerationRequest, *,
               arrival_t: Optional[float] = None,
               deadline: Optional[float] = None,
               trace_id: Optional[int] = None) -> int:
        """Enqueue ``request``; returns its request index.

        ``arrival_t`` stamps when the request arrived (``perf_counter``
        clock, or the caller's injected clock) — ``queue_s`` is measured
        from it.  ``deadline`` is the *absolute* deadline on the same
        clock; when omitted it is derived as ``arrival_t +
        request.deadline_s`` (``inf`` if the request has no deadline).
        ``trace_id`` names the request in trace lifecycle spans (the
        serving front-end passes its global request id); defaults to the
        scheduler-local index.  Safe to call mid-loop between
        :meth:`tick`\\ s — this is the open-loop ingestion path.
        """
        i = len(self.requests)
        self.requests.append(request)
        arrival = time.perf_counter() if arrival_t is None else arrival_t
        if deadline is None:
            dl = getattr(request, "deadline_s", None)
            deadline = math.inf if dl is None else arrival + float(dl)
        self._arrival_t.append(arrival)
        self._deadlines.append(float(deadline))
        self._trace_ids_list.append(i if trace_id is None else int(trace_id))
        rid = self._trace_ids_list[i]
        targs = {"rid": rid,
                 "priority": int(getattr(request, "priority", 0))}
        if math.isfinite(deadline):
            targs["deadline_s"] = float(deadline)
        self._tr.begin_async("queued", rid, **targs)
        heapq.heappush(self._pending, self._key(i))
        return i

    def _rid(self, i: int) -> int:
        return self._trace_ids_list[i]

    def deadline(self, i: int) -> float:
        """Absolute deadline of request ``i`` (``inf`` if none)."""
        return self._deadlines[i]

    def shed_pending(self, now: float, *, slack: float = 0.0) -> List[int]:
        """Drop still-queued requests whose deadline has (effectively)
        passed: ``deadline <= now + slack``.

        ``slack`` pre-sheds requests that would miss even if admitted
        right now (e.g. an estimated minimum service time).  Only
        *pending* requests are shed — a request already holding a slot
        runs to completion (its tokens are already partially committed).
        Returns the shed request indices; they are recorded in
        ``shed_indices`` so ``completed + shed + failed == submitted``
        stays an invariant.  Never called by the batch :meth:`run` path —
        ``generate_requests`` serves every request.
        """
        cut = now + slack
        keep, out = [], []
        for key in self._pending:
            i = key[-1]
            (out if self._deadlines[i] <= cut else keep).append(key)
        if out:
            heapq.heapify(keep)
            self._pending = keep
            self.shed_indices.extend(key[-1] for key in out)
            for key in out:
                i = key[-1]
                rid = self._rid(i)
                phase = "preempted" if i in self._preempted else "queued"
                self._preempted.discard(i)
                self._preempted_len.pop(i, None)
                self._tr.end_async(phase, rid)
                self._tr.instant("shed", tid=self.trace_tid, rid=rid)
        return [key[-1] for key in out]

    # ------------------------------------------------------------------
    def _record_admit(self, ev: SlotEvent) -> None:
        self.events.append(ev)
        if self.max_events is not None and len(self.events) > self.max_events:
            del self.events[: len(self.events) - self.max_events]

    def tick(
        self,
        state: dict,
        *,
        admit: Callable[[dict, int, int], dict],
        step: Callable[[dict], dict],
        can_admit: Optional[Callable[[int], bool]] = None,
        release: Optional[Callable[[dict, int, int], dict]] = None,
        preempt: Optional[Callable[[dict, int, int], dict]] = None,
        on_tokens: Optional[Callable[[int, np.ndarray], None]] = None,
        on_fail: Optional[
            Callable[[dict, Optional[int], int, BaseException], dict]] = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> tuple:
        """One admission wave + one batch step + harvest.

        The open-loop building block :meth:`run` iterates (hook
        contracts are documented there).  Additionally:

        * ``on_tokens(request_index, tokens)`` — per-request streaming:
          called after the step for every occupied row that committed
          new tokens, with the newly-committed ``np.int32`` slice
          (clipped to the request's budget).  Deltas concatenate
          bit-identically to the final ``RequestResult.tokens``.
        * ``on_fail(state, slot, request_index, exc) -> state`` —
          failure-containment hook, called after a request transitions
          to the terminal ``failed`` state (``slot`` is None when it
          never held one this occupancy).  The serving front-end idles
          the engine row and finishes the stream handle here; ``release``
          has already returned the request's blocks.
        * ``clock`` — timestamp source for queue/service accounting
          (injectable so load-replay benchmarks can run on a virtual
          clock).

        **Failure containment**: an exception escaping the ``admit``
        hook fails only the request being admitted; an exception
        escaping ``step`` fails the occupied slots it is attributable to
        (a :class:`~repro.serving.faults.RequestFault` names them and
        may carry a coherent post-fault state to adopt — any other
        exception conservatively fails every occupied slot, since the
        batch step is all-or-nothing) and the tick returns with no
        harvest.  Queued work and the scheduler itself survive either
        way.

        Returns ``(state, harvested request indices)``; results land in
        ``self.results``.
        """
        with self._tr.span("tick", tid=self.trace_tid, step=self.steps):
            return self._tick_inner(
                state, admit=admit, step=step, can_admit=can_admit,
                release=release, preempt=preempt, on_tokens=on_tokens,
                on_fail=on_fail, clock=clock)

    def _tick_inner(
        self,
        state: dict,
        *,
        admit: Callable[[dict, int, int], dict],
        step: Callable[[dict], dict],
        can_admit: Optional[Callable[[int], bool]] = None,
        release: Optional[Callable[[dict, int, int], dict]] = None,
        preempt: Optional[Callable[[dict, int, int], dict]] = None,
        on_tokens: Optional[Callable[[int, np.ndarray], None]] = None,
        on_fail: Optional[
            Callable[[dict, Optional[int], int, BaseException], dict]] = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> tuple:
        while self._pending:
            free_slot = next((s for s in range(self.batch_slots)
                              if self._slots[s] is None), None)
            if free_slot is None:
                break
            head_key = self._pending[0]
            i = head_key[-1]
            if can_admit is not None and not can_admit(i):
                # head-of-line gate: a denied head blocks the wave so
                # admission order (and queue_s) stays priority-exact.
                # With a preempt hook, evict strictly-worse-key running
                # occupants (lowest priority first) until the head fits
                # — their blocks move to the host swap pool and they
                # re-enter the queue with their original keys.
                while preempt is not None and not can_admit(i):
                    victim = None
                    for s in range(self.batch_slots):
                        ev = self._slots[s]
                        if ev is None:
                            continue
                        k = self._key(ev.request_index)
                        if k > head_key and (
                                victim is None or k > victim[1]):
                            victim = (s, k)
                    if victim is None:
                        break
                    vs = victim[0]
                    vev = self._slots[vs]
                    vi = vev.request_index
                    vrid = self._rid(vi)
                    with self._tr.span("preempt", tid=self.trace_tid,
                                       rid=vrid, slot=vs):
                        state = preempt(state, vs, vi)
                    vev.preempted = True
                    self._slots[vs] = None
                    self.preemptions += 1
                    self._resume_streamed[vi] = vev.streamed
                    self._preempted_len[vi] = self._row_len[vs]
                    self._preempted.add(vi)
                    self._tr.end_async("running", vrid)
                    self._tr.begin_async("preempted", vrid, rid=vrid)
                    heapq.heappush(self._pending, self._key(vi))
                if not can_admit(i):
                    break
                free_slot = next(s for s in range(self.batch_slots)
                                 if self._slots[s] is None)
            heapq.heappop(self._pending)
            # stamp before admit(): prefill cost is service, not
            # queueing; a resumed request keeps its first admission
            # stamp (eviction is service disruption, not queueing)
            self._admit_t[free_slot] = \
                self._first_admit_t.setdefault(i, clock())
            rid = self._rid(i)
            resumed = i in self._preempted
            self._tr.end_async("preempted" if resumed else "queued", rid)
            self._preempted.discard(i)
            self._tr.begin_async("running", rid, rid=rid, slot=free_slot,
                                 resumed=resumed)
            try:
                with self._tr.span("admit", tid=self.trace_tid, rid=rid,
                                   slot=free_slot, resumed=resumed):
                    state = admit(state, free_slot, i)
            except Exception as exc:  # noqa: BLE001 — containment seam
                # the failure is the admitted request's alone: release
                # whatever partial pool state admission left behind
                # (exactly-once release machinery makes this safe), fail
                # the request, and keep admitting the rest of the wave
                self.failed[i] = exc
                self._resume_streamed.pop(i, None)
                self._preempted_len.pop(i, None)
                self._first_admit_t.pop(i, None)
                self._tr.end_async("running", rid, failed=True)
                self._tr.instant("failed", tid=self.trace_tid, rid=rid,
                                 where="admit", error=type(exc).__name__)
                if release is not None:
                    state = release(state, free_slot, i)
                if on_fail is not None:
                    state = on_fail(state, free_slot, i, exc)
                continue
            self._row_len[free_slot] = self._preempted_len.pop(
                i, self.requests[i].prompt.size)
            ev = SlotEvent(request_index=i, slot=free_slot,
                           admit_step=self.steps,
                           streamed=self._resume_streamed.pop(i, 0))
            self._slots[free_slot] = ev
            self._record_admit(ev)

        if self._pending and all(ev is None for ev in self._slots):
            # every slot idle yet the head was denied: it can never be
            # admitted (e.g. demand larger than the whole pool).  Fail
            # it — terminal, carrying the reason — instead of wedging
            # the lane behind an unservable request; the wave resumes
            # next tick.  (One per tick keeps the drain bound honest.)
            i = heapq.heappop(self._pending)[-1]
            state = self._fail_unqueued(
                state, i,
                RuntimeError(
                    f"request {i} rejected by can_admit with every slot "
                    "idle — it can never be served"),
                on_fail=on_fail)

        occupied = [s for s in range(self.batch_slots)
                    if self._slots[s] is not None]
        t_step = clock()
        try:
            with self._tr.span("decode", tid=self.trace_tid, step=self.steps,
                               rows=len(occupied)):
                state = step(state)
        except RequestFault as rf:
            # attributable step failure: adopt the coherent state the
            # raiser carries (when it has one) and fail only the named
            # slots; everyone else continues next tick
            self.steps += 1
            if rf.state is not None:
                state = rf.state
            cause = rf.cause if rf.cause is not None else rf
            slots = rf.slots if rf.slots is not None else list(occupied)
            for s in slots:
                if self._slots[s] is not None:
                    state = self.fail_running(state, s, cause,
                                              release=release,
                                              on_fail=on_fail)
            return state, []
        except Exception as exc:  # noqa: BLE001 — containment seam
            # unattributable step failure: the batch step is
            # all-or-nothing, so conservatively fail every occupied
            # slot (their blocks release exactly-once; queued and
            # preempted requests are untouched)
            self.steps += 1
            for s in occupied:
                if self._slots[s] is not None:
                    state = self.fail_running(state, s, exc,
                                              release=release,
                                              on_fail=on_fail)
            return state, []
        step_s = clock() - t_step
        self.steps += 1

        lengths = np.asarray(state["length"])
        targets = np.asarray(state["target"])
        if occupied:
            accepted = []
            for s in occupied:
                cur = int(min(lengths[s], targets[s]))
                accepted.append(max(0, cur - self._row_len[s]))
                self._row_len[s] = cur
            if self.on_step_stats is not None:
                self.on_step_stats(accepted, step_s, sum(accepted))
        tokens_np = None                       # fetched lazily, once
        if on_tokens is not None:
            for s in occupied:
                ev = self._slots[s]
                P = self.requests[ev.request_index].prompt.size
                committed = int(min(lengths[s], targets[s])) - P
                if committed > ev.streamed:
                    if tokens_np is None:
                        tokens_np = np.asarray(state["tokens"])
                    on_tokens(ev.request_index,
                              tokens_np[s, P + ev.streamed:
                                        P + committed].copy())
                    ev.streamed = committed

        done = [s for s in occupied if lengths[s] >= targets[s]]
        harvested: List[int] = []
        if done:
            now = clock()
            if tokens_np is None:
                tokens_np = np.asarray(state["tokens"])
            commits = np.asarray(state["stats"]["commits"])
            row_steps = np.asarray(state["stats"]["row_steps"])
            with self._tr.span("harvest", tid=self.trace_tid,
                               rows=len(done)):
                for s in done:
                    ev = self._slots[s]
                    ev.harvest_step = self.steps
                    i = ev.request_index
                    r = self.requests[i]
                    P = r.prompt.size
                    self.results[i] = RequestResult(
                        request=r,
                        tokens=tokens_np[s, P: P + r.max_new_tokens].copy(),
                        prompt_len=P,
                        accept_len=float(commits[s])
                        / max(int(row_steps[s]), 1),
                        steps=int(row_steps[s]),
                        queue_s=self._admit_t[s] - self._arrival_t[i],
                        service_s=now - self._admit_t[s],
                    )
                    harvested.append(i)
                    self._first_admit_t.pop(i, None)
                    self._tr.end_async("running", self._rid(i),
                                       tokens=int(r.max_new_tokens),
                                       steps=int(row_steps[s]))
                    if self.on_event is not None:
                        self.on_event(ev)
                    if release is not None:
                        state = release(state, s, i)
                    self._slots[s] = None
        return state, harvested

    # ------------------------------------------------------------------
    # Failure containment (terminal `failed` state)
    # ------------------------------------------------------------------
    def _fail_unqueued(self, state, i: int, exc: BaseException, *,
                       on_fail=None):
        """Record request ``i`` (already removed from the pending heap)
        as failed and fire the containment hook."""
        rid = self._rid(i)
        self.failed[i] = exc
        phase = "preempted" if i in self._preempted else "queued"
        self._preempted.discard(i)
        self._preempted_len.pop(i, None)
        self._resume_streamed.pop(i, None)
        self._first_admit_t.pop(i, None)
        self._tr.end_async(phase, rid, failed=True)
        self._tr.instant("failed", tid=self.trace_tid, rid=rid,
                         where="queue", error=type(exc).__name__)
        if on_fail is not None:
            state = on_fail(state, None, i, exc)
        return state

    def fail_pending(self, state, i: int, exc: BaseException, *,
                     on_fail=None):
        """Fail a still-queued (or preempted-and-requeued) request:
        remove it from the pending heap and record the terminal
        ``failed`` state.  The serving front-end drives client cancels
        and queue timeouts through this.  Returns the (unchanged
        engine) state, for symmetry with :meth:`fail_running`."""
        keep = [k for k in self._pending if k[-1] != i]
        if len(keep) == len(self._pending):
            raise KeyError(f"request {i} is not pending")
        heapq.heapify(keep)
        self._pending = keep
        return self._fail_unqueued(state, i, exc, on_fail=on_fail)

    def fail_running(self, state, slot: int, exc: BaseException, *,
                     release=None, on_fail=None):
        """Fail the request occupying ``slot``: record the terminal
        state, stream the audit event, release its blocks (``release``
        hook — exactly-once safe) and idle the slot.  Used by the tick's
        step containment and by the front-end's running-request
        timeout/cancel paths."""
        ev = self._slots[slot]
        if ev is None:
            raise KeyError(f"slot {slot} is idle")
        i = ev.request_index
        rid = self._rid(i)
        self.failed[i] = exc
        ev.harvest_step = self.steps
        ev.failed = True
        self._first_admit_t.pop(i, None)
        self._tr.end_async("running", rid, failed=True)
        self._tr.instant("failed", tid=self.trace_tid, rid=rid,
                         where="slot", error=type(exc).__name__)
        if self.on_event is not None:
            self.on_event(ev)
        if release is not None:
            state = release(state, slot, i)
        if on_fail is not None:
            state = on_fail(state, slot, i, exc)
        self._slots[slot] = None
        return state

    def pending_indices(self) -> List[int]:
        """Request indices currently queued (including preempted ones
        waiting to resume), in no particular order."""
        return [k[-1] for k in self._pending]

    def find_slot(self, i: int) -> Optional[int]:
        """Slot currently held by request ``i`` (None if not running)."""
        for s, ev in enumerate(self._slots):
            if ev is not None and ev.request_index == i:
                return s
        return None

    # ------------------------------------------------------------------
    def run(
        self,
        state: dict,
        *,
        admit: Callable[[dict, int, int], dict],
        step: Callable[[dict], dict],
        t0: Optional[float] = None,
        can_admit: Optional[Callable[[int], bool]] = None,
        release: Optional[Callable[[dict, int, int], dict]] = None,
        preempt: Optional[Callable[[dict, int, int], dict]] = None,
        on_tokens: Optional[Callable[[int, np.ndarray], None]] = None,
        on_fail: Optional[
            Callable[[dict, Optional[int], int, BaseException], dict]] = None,
    ) -> tuple:
        """Drive the loop until the queue drains.

        Lifecycle hooks (all host-side callables):

        * ``admit(state, slot, request_index) -> state`` — **required**.
          Must return the state with ``slot`` prefilled for the request
          (every per-row slice reset; see
          ``SpecEngine.prefill_into_slot``).  Called whenever a slot is
          free and the pending queue is non-empty.
        * ``step(state) -> state`` — **required**.  Advances the whole
          batch one verify step (typically the jitted decode step, plus
          any host-side bookkeeping such as paged block appends).
        * ``can_admit(request_index) -> bool`` — optional admission
          gate, consulted for the *head* of the priority queue before
          each admission.  A ``False`` stops this wave's admissions
          (head-of-line blocking — a denied high-priority request is
          never overtaken by a cheaper one, so priority order and
          token-stream invariance are preserved).  The paged KV engine
          uses this to admit only requests whose worst-case block
          demand fits the pool.
        * ``release(state, slot, request_index) -> state`` — optional
          harvest hook, called after a finished request's result is
          recorded and before the slot is marked free.  The paged KV
          engine returns the request's cache blocks to the pool here
          **and resets the slot's block-table row to scratch** — an idle
          row keeps stepping, and its (discarded) window writes must not
          land in blocks the free list may hand to the next admission.
        * ``preempt(state, slot, request_index) -> state`` — optional
          eviction hook.  When the queue head is denied by
          ``can_admit``, running occupants whose admission key is
          *strictly worse* than the head's are evicted worst-first
          (``PagedGroup.preempt`` swaps their blocks to host memory)
          until the head fits; evicted requests re-enter the pending
          queue with their original keys and resume bit-exactly via
          ``admit``.  The strict-key rule guarantees progress: a
          request can only be displaced by a strictly better one, so
          preemption chains terminate.  In the batch :meth:`run` mode
          admissions already pop in key order, so every occupant's key
          is better than any pending head's and the hook structurally
          never fires — it exists for the open-loop front-end
          (``repro.serving.server``) where better-keyed requests arrive
          while worse ones hold slots.
        * ``on_tokens(request_index, tokens)`` — optional per-request
          streaming callback (see :meth:`tick`).

        ``t0`` is the arrival timestamp the requests' ``queue_s`` is
        measured from (``time.perf_counter`` clock) — callers serving
        several scheduler loops sequentially pass the call-level start so
        later loops report the full wait.  A request ``can_admit``
        permanently rejects while every slot is idle (one that can
        never be served) transitions to the terminal ``failed`` state —
        its entry in the returned results is ``None`` and ``failed``
        carries the reason.  Returns ``(state, results)`` with
        ``results`` in request order.
        """
        t0 = time.perf_counter() if t0 is None else t0
        self._arrival_t = [t0] * len(self.requests)
        # hard safety: every active row commits >= 1 token per step, so
        # the loop is bounded by the total token budget (+ slack per wave)
        max_steps = sum(r.max_new_tokens for r in self.requests) \
            + 8 * (len(self.requests) + self.batch_slots) + 8

        while self.busy:
            state, _ = self.tick(
                state, admit=admit, step=step, can_admit=can_admit,
                release=release, preempt=preempt, on_tokens=on_tokens,
                on_fail=on_fail)
            if self.steps > max_steps:
                stuck = [ev.request_index for ev in self._slots
                         if ev is not None]
                raise RuntimeError(
                    f"scheduler failed to drain: {len(self._pending)} "
                    f"pending, slots stuck on requests {stuck} after "
                    f"{self.steps} steps")
        return state, [self.results.get(i)
                       for i in range(len(self.requests))]
