"""Async streaming serving front-end: SLO-aware admission over the
continuous-batching scheduler.

The engine below this layer is batch-synchronous: ``generate_requests``
blocks until a fixed request list drains.  A server faces an *open* loop
— requests arrive continuously, each with its own latency SLO — and the
SD survey's (arXiv:2401.07851) deployment lesson applies: realized
speedup is decided by the serving loop, not the kernel.  This module
adds that loop as a layer **above** the engine, reusing the scheduler's
admit → step → harvest machinery unchanged:

* :class:`ServingLoop` — the single-threaded core.  An ingestion queue
  feeds per-(temperature, lane) :class:`Scheduler` instances
  (temperature is jit-static, so each lane owns one compiled decode
  step and one fixed-shape state pytree); :meth:`poll` routes arrivals,
  sheds queued work whose deadline already passed, and advances each
  busy lane one decode step, forwarding newly-committed tokens to the
  per-request :class:`StreamHandle` as they commit.  The clock is
  injectable, so load-replay benchmarks (``benchmarks/serve_load.py``)
  drive the identical code path on a deterministic virtual clock.
* :class:`StreamingServer` — the asynchronous front: a background
  thread polls the loop while callers ``submit()`` from any thread and
  consume ``handle.tokens()`` / ``handle.result()`` concurrently.

SLO-aware admission, in order of application:

1. **EDF within priority class** (``admission="edf"``): pending
   requests pop by ``(priority, absolute deadline, arrival)`` — the
   optimal single-machine order for deadline hit-rate.  Like priority,
   it only shifts *when* a request is admitted; per-request seed
   streams keep its tokens bit-identical to FIFO admission and to solo
   serving.
2. **Shedding** (``shed_late=True``): a queued request whose deadline
   has already passed (plus ``shed_slack_s``) is dropped instead of
   burning a slot on an answer nobody is waiting for — under overload
   the queue stays short and on-time work keeps meeting its SLO.
   Running requests are never shed.  ``completed + shed == submitted``
   is a checked invariant: nothing is lost silently.
3. **Degrade tree → chain** (``degrade_on_overload=True``): when the
   pending backlog exceeds ``overload_factor × batch_slots`` and the
   engine drafts token *trees*, new arrivals are routed to a chain-
   drafting lane instead — smaller verify windows, higher batch
   throughput, lower per-step latency.  At T=0 this is invisible in the
   tokens (speculative decoding is lossless: any drafter yields the
   target model's greedy stream); at T>0 the sampled stream may differ
   from the tree lane's (different PRNG consumption), which is why
   degrade is opt-in.

4. **Failure containment** (``docs/robustness.md``): every request
   ends in exactly one of ``done | shed | failed`` — a malformed
   submit, an admission fault, a decode-step fault, a timeout
   (``request_timeout_s``) or a client cancel fails *that request
   only*, with the terminal ``failed`` status carrying the exception
   (re-raised by ``handle.result()``) and the slot + KV blocks
   reclaimed through the same exactly-once release machinery as
   preemption.  ``completed + shed + failed == submitted`` is the
   checked conservation law.  The verify path runs under a NaN/Inf
   guardrail (retry, then a full-precision bf16 verification lane,
   then fail — see ``_Lane._guard``) plus an acceptance-collapse
   detector; the :class:`StreamingServer` thread runs under a
   supervisor that restarts the loop with capped backoff instead of
   dying silently.  ``repro.serving.faults`` injects deterministic
   faults at every seam above.

With ``SpecConfig(kv_layout="paged")`` each lane owns a block pool
sized for its slot count's worst-case demand, a prefix-cache index
(shared system prompts are stored once across requests,
``kv_prefix_sharing``) and a host-side swap pool: when the pool denies
the queue head, the scheduler preempts the lowest-priority running
occupant — its blocks are snapshotted to host ``numpy`` and freed — and
resumes it later bit-exactly (``kv_preempt``).  Worst-case reservation
thus stops being the admission ceiling (``serving/engine.PagedGroup``).

Restrictions (v1): attention-family archs only (the lane pads prompts
to ``max_prompt_len``; recurrent caches cannot right-pad) — enforced at
construction.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.paged_cache import (
    blocks_for_tokens,
    init_paged_cache,
    request_demand_tokens,
)
from repro.core.spec_engine import init_state
from repro.serving.faults import (
    NULL_FAULTS,
    InjectedFault,
    LaneCrashed,
    RequestCancelled,
    RequestFault,
    RequestTimeout,
    VerifierNaNError,
    poison_params,
)
from repro.serving.metrics import ServerMetrics
from repro.serving.request import GenerationRequest, RequestResult
from repro.serving.scheduler import Scheduler
from repro.serving.trace import NULL_TRACER

_MAX_LANES = 8          # distinct (temperature, degraded) decode loops


@dataclass(frozen=True)
class ServerConfig:
    """Serving front-end policy knobs (engine knobs live in SpecConfig)."""

    batch_slots: int = 4               # decode rows per lane
    max_prompt_len: int = 64           # admission caps: they fix the
    max_new_tokens: int = 64           # lane's jit-static buffer sizes
    admission: str = "edf"             # "edf" | "fifo"
    shed_late: bool = True             # drop queued past-deadline work
    shed_slack_s: float = 0.0          # pre-shed margin (est. min service)
    degrade_on_overload: bool = False  # tree -> chain lane under pressure
    degrade_drafter: str = "ngram"     # chain drafter for the degraded lane
    overload_factor: float = 2.0       # pending > factor*slots = overload
    max_events: Optional[int] = 1024   # scheduler audit-trail cap per lane
    request_timeout_s: Optional[float] = None  # end-to-end per-request cap
    #                                    (queued + running); None = no cap.
    #                                    Contains slow/hung ticks: a stalled
    #                                    lane fails its requests instead of
    #                                    wedging callers forever
    collapse_window: int = 0           # acceptance-collapse detector: steps
    #                                    in the sliding window (0 disables)
    collapse_threshold: float = 0.05   # mean accepted tokens/row-step below
    #                                    which a full window trips a lane
    #                                    repair (re-quantize the params)

    def __post_init__(self):
        if self.admission not in ("fifo", "edf"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.request_timeout_s is not None \
                and not self.request_timeout_s > 0.0:
            raise ValueError("request_timeout_s must be positive (or None)")
        if self.collapse_window < 0:
            raise ValueError("collapse_window must be >= 0")


_EOS = None                            # stream terminator sentinel


class StreamHandle:
    """Caller-side view of one in-flight request.

    * :meth:`tokens` — blocking iterator over newly-committed token
      deltas (``np.int32`` arrays); ends when the request reaches any
      terminal state.  Safe to consume from a different thread than the
      server's.
    * :attr:`chunks` — the deltas accumulated so far (non-blocking; the
      inline/virtual-clock driver reads this after :meth:`ServingLoop.
      drain`).  ``np.concatenate(chunks)`` is bit-identical to
      ``result().tokens`` — the streaming contract.
    * :meth:`result` — blocks until a terminal state; returns the
      :class:`RequestResult`, ``None`` if the request was shed, or
      **re-raises** the terminal exception if the request ``failed``
      (also carried on :attr:`error`).  The timeout path tells a
      still-working loop apart from a dead one.
    * :meth:`cancel` — thread-safe, idempotent, best-effort client
      cancellation; resolves to ``failed`` with
      :class:`~repro.serving.faults.RequestCancelled` unless the
      request already reached a terminal state.
    * :attr:`status` — ``queued | running | done | shed | failed``.
    """

    def __init__(self, rid: int, request: GenerationRequest,
                 submit_t: float, deadline_t: Optional[float],
                 loop: Optional["ServingLoop"] = None):
        self.rid = rid
        self.request = request
        self.submit_t = submit_t
        self.deadline_t = deadline_t
        self.status = "queued"
        self.degraded = False
        self.error: Optional[BaseException] = None
        self.chunks: List[np.ndarray] = []
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._done = threading.Event()
        self._result: Optional[RequestResult] = None
        self._loop = loop
        self._lane: Optional["_Lane"] = None   # routing target (loop thread)
        self._idx: Optional[int] = None        # scheduler-local index
        self._routed = False                   # metrics submit fired once
        self._reject: Optional[BaseException] = None  # submit validation
        self._cancelled = False

    def tokens(self):
        while True:
            item = self._q.get()
            if item is _EOS:
                return
            yield item

    def result(self, timeout: Optional[float] = None
               ) -> Optional[RequestResult]:
        if not self._done.wait(timeout):
            loop = self._loop
            if loop is not None and loop.dead is not None:
                raise TimeoutError(
                    f"request {self.rid} will never finish: the serving "
                    f"loop is dead ({type(loop.dead).__name__})"
                ) from loop.dead
            raise TimeoutError(
                f"request {self.rid} still {self.status} after {timeout}s")
        if self.error is not None:
            raise self.error
        return self._result

    def cancel(self) -> None:
        """Ask the loop to fail this request with ``RequestCancelled``
        at its next poll (no-op once terminal).  A running request's
        slot and KV blocks are reclaimed through the same exactly-once
        release machinery as preemption."""
        self._cancelled = True
        loop = self._loop
        if loop is not None:
            loop._control.put(self)

    def collected(self) -> np.ndarray:
        """All streamed tokens so far, concatenated (non-blocking)."""
        if not self.chunks:
            return np.zeros((0,), np.int32)
        return np.concatenate(self.chunks)

    # loop-side -------------------------------------------------------
    def _emit(self, toks: np.ndarray) -> None:
        self.chunks.append(toks)
        self._q.put(toks)

    def _finish(self, result: Optional[RequestResult], status: str,
                error: Optional[BaseException] = None) -> None:
        self._result = result
        self.error = error
        self.status = status
        self._q.put(_EOS)
        self._done.set()


class _Lane:
    """One compiled decode loop: a Scheduler + fixed-shape state pytree
    for a given (temperature, degraded?) combination."""

    def __init__(self, loop: "ServingLoop", engine, temperature: float,
                 tid: int = 0):
        cfg = loop.cfg
        self.loop = loop
        self.tid = tid                         # tracer track for this lane
        self.engine = engine
        self.temperature = temperature
        self.params = engine._prepare_cached(loop._raw_params)
        self.step, self.drafter = engine._step_for_temperature(temperature)
        self.key = f"{self.drafter.name}:{engine.verifier.name}"
        # guardrail state (docs/robustness.md): the raw (unprepared)
        # params feed the bf16 fallback step and lane repairs; the
        # fallback step itself compiles lazily on first trip
        self.fallback_params = loop._raw_params
        self.fallback_step = None
        self._bf16_streak = 0
        self._collapse_hist = (
            collections.deque(maxlen=cfg.collapse_window)
            if cfg.collapse_window else None)
        self.buf = (cfg.max_prompt_len + cfg.max_new_tokens
                    + self.drafter.gamma + 2)
        # one padded prompt length per lane => admission prefill compiles
        # once; requests shorter than the cap are right-padded exactly as
        # generate_requests pads a group to its maximum
        self.pmax = cfg.max_prompt_len
        slots = cfg.batch_slots

        def on_step_stats(accepted, step_s, n_tokens, _key=self.key):
            loop.metrics.on_decode_step(_key, accepted, step_s)
            engine.telemetry.on_decode_step(_key, accepted, step_s)
            if self._collapse_hist is not None and accepted:
                self._note_acceptance(sum(accepted) / len(accepted))

        self.sched = Scheduler(
            [], slots, policy=cfg.admission, max_events=cfg.max_events,
            on_event=loop.metrics.on_slot_event,
            tracer=loop.tracer, trace_tid=tid,
            on_step_stats=on_step_stats)
        self.ctx = None                        # paged: PagedGroup context
        cache = None
        scfg = engine.scfg
        if scfg.kv_layout == "paged":
            engine._check_paged_supported()
            bs = scfg.kv_block_size
            # every admitted request can demand at most the server caps'
            # worth of blocks; one pool per lane, sized so `slots`
            # worst-case requests co-reside (+1 COW headroom each when
            # prefix sharing may donate boundary blocks, +1 scratch)
            demand_cap = blocks_for_tokens(
                request_demand_tokens(cfg.max_prompt_len,
                                      cfg.max_new_tokens,
                                      self.drafter.gamma), bs)
            per = demand_cap + (1 if scfg.kv_prefix_sharing else 0)
            num_blocks = (scfg.kv_pool_blocks
                          if scfg.kv_pool_blocks is not None
                          else 1 + slots * per)
            if demand_cap > num_blocks - 1:
                raise ValueError(
                    f"kv_pool_blocks={num_blocks} cannot hold even one "
                    f"worst-case request ({demand_cap} blocks at the "
                    "server's prompt/budget caps)")
            max_blocks = blocks_for_tokens(self.buf, bs)
            cache = init_paged_cache(engine.model.cfg, slots, max_blocks,
                                     num_blocks, bs)
            self.ctx = engine.paged_group(num_blocks=num_blocks,
                                          block_size=bs,
                                          gamma=self.drafter.gamma,
                                          tracer=loop.tracer,
                                          trace_tid=tid,
                                          faults=loop.faults)
        self.state = init_state(
            engine.model, slots, self.buf,
            jnp.zeros((slots, 2), jnp.uint32),
            drafter_state=self.drafter.alloc_state(
                engine.model, self.params, slots, self.buf),
            target=jnp.zeros((slots,), jnp.int32),
            cache=cache)
        self.handles: Dict[int, StreamHandle] = {}   # lane index -> handle

    def on_submit(self, i: int, handle: StreamHandle) -> None:
        self.handles[i] = handle
        if self.ctx is not None:
            self.ctx.register(i, handle.request)

    def admit(self, state: dict, slot: int, i: int) -> dict:
        h = self.handles[i]
        h.status = "running"
        if self.ctx is not None:
            return self.ctx.admit(state, slot, i, params=self.params,
                                  pmax=self.pmax, drafter=self.drafter)
        return self.engine.prefill_into_slot(
            self.params, state, slot, h.request,
            pmax=self.pmax, drafter=self.drafter)

    def step_fn(self, state: dict) -> dict:
        loop = self.loop
        faults = loop.faults
        params = self.params
        if faults.enabled:
            if faults.fire("step", lane=self.tid):
                raise InjectedFault(
                    f"injected step failure (lane {self.tid})")
            if faults.fire("quant_corrupt", lane=self.tid):
                # sticky: the lane's *prepared* params are poisoned in
                # place, as a real quantization corruption would be —
                # every later step reproduces it until the guardrail
                # repairs the lane (re-prepare from the raw tree)
                self.params = params = poison_params(self.params)
            if faults.fire("nan_verify", lane=self.tid):
                # transient: poison only this step's local view
                params = poison_params(self.params)
        if self.ctx is not None:
            state = self.ctx.prepare_step(state)
        pre = state                    # pure step: intact on any failure
        state = self.step(params, state)
        # fires inside the scheduler's "decode" span: a virtual-clock
        # driver advances time here, so spans get real widths and the
        # per-step wall time equals the modeled step cost
        if loop.step_hook is not None:
            loop.step_hook()
        if faults.enabled:
            d = faults.delay("stall")
            if d > 0.0:
                # slow/hung tick: request_timeout_s is the containment
                loop._stall(d)
        return self._guard(pre, state)

    def _guard(self, pre: dict, state: dict) -> dict:
        """Verify-path NaN/Inf guardrail (docs/robustness.md).

        The fused step folds a per-row non-finite-logits flag into
        ``stats["bad"]``.  When any *occupied* row trips, escalate
        through a three-stage ladder, each stage re-running from the
        intact pre-step state (the decode step is pure):

        1. **same-precision retry** — a transient fault replays to the
           exact fault-free output: per-request PRNG streams make the
           retried step bit-identical to an untripped one;
        2. **full-precision fallback** — the bf16 twin of this lane's
           step on the *raw* params rescues persistent quantized-weight
           corruption losslessly (the bf16 verifier IS the target
           distribution).  Rescued rows merge back row-sparsely
           (``merge_state_rows``) and their requests are recorded in
           ``loop.affected``; three consecutive bf16-rescued steps
           trigger a lane repair — re-prepare (re-quantize) the params
           from the raw tree, restoring the fast path;
        3. rows still non-finite under bf16 (e.g. KV blocks corrupted
           by a faulty swap-in) are unrescuable: **fail exactly those
           requests** via :class:`RequestFault`, carrying the merged
           state so every other row's progress survives the tick.
        """
        bad = np.asarray(state["stats"]["bad"])
        sched = self.sched
        rows = [s for s in range(sched.batch_slots)
                if bad[s] and sched._slots[s] is not None]
        if not rows:
            self._bf16_streak = 0
            return state
        loop = self.loop
        loop.metrics.on_guardrail("verify_nan_trips")
        with loop.tracer.span("guardrail", tid=self.tid, rows=len(rows)):
            retry = self.step(self.params, pre)
            rbad = np.asarray(retry["stats"]["bad"])
            still = [s for s in rows if rbad[s]]
            if len(still) < len(rows):
                loop.metrics.on_guardrail("retry_rescued_rows",
                                          len(rows) - len(still))
            if not still:
                self._bf16_streak = 0
                return retry
            if self.fallback_step is None:
                self.fallback_step = self.engine.fallback_step_for(
                    self.temperature)
            fb = self.fallback_step(self.fallback_params, pre)
            fbad = np.asarray(fb["stats"]["bad"])
            saved = [s for s in still if not fbad[s]]
            doomed = [s for s in still if fbad[s]]
            out = retry
            if saved:
                from repro.serving.engine import merge_state_rows
                out = merge_state_rows(retry, fb, saved)
                loop.metrics.on_guardrail("bf16_rescued_rows", len(saved))
                for s in saved:
                    h = self.handles.get(sched._slots[s].request_index)
                    if h is not None:
                        loop.affected.add(h.rid)
                self._bf16_streak += 1
                if self._bf16_streak >= 3:
                    # the quantized weights themselves are the prime
                    # suspect: re-quantizing from the raw tree clears
                    # real and injected corruption alike
                    self.params = self.engine.prepare_params(
                        self.fallback_params)
                    self._bf16_streak = 0
                    loop.metrics.on_guardrail("reprepares")
            if doomed:
                loop.metrics.on_guardrail("unrescued_rows", len(doomed))
                raise RequestFault(
                    f"verifier logits non-finite for slots {doomed} even "
                    "through the full-precision fallback",
                    slots=doomed, state=out,
                    cause=VerifierNaNError(
                        "non-finite verifier logits survived retry and "
                        "bf16 fallback (suspect corrupted KV state)"))
        return out

    def _note_acceptance(self, mean_accept: float) -> None:
        """Acceptance-collapse detector: quantized-weight damage that
        does NOT produce NaNs still shows up as acceptance falling to
        ~zero (every draft rejected — the Table-1 signal inverted).
        When the whole sliding window sits below ``collapse_threshold``
        on a quantized-verifier lane, trip a lane repair and reset."""
        hist = self._collapse_hist
        hist.append(mean_accept)
        if len(hist) < hist.maxlen:
            return
        if sum(hist) / len(hist) >= self.loop.cfg.collapse_threshold:
            return
        self.loop.metrics.on_guardrail("collapse_trips")
        hist.clear()
        if self.engine.verifier.name != "bf16":
            self.params = self.engine.prepare_params(self.fallback_params)
            self.loop.metrics.on_guardrail("reprepares")


class ServingLoop:
    """Single-threaded serving core with an injectable clock.

    ``submit()`` is thread-safe (arrivals land on an ingestion queue);
    ``poll()`` must be called from one driving thread — either the
    :class:`StreamingServer` wrapper's background thread (real clock) or
    a benchmark's replay loop (virtual clock).
    """

    def __init__(self, engine, params, cfg: ServerConfig = ServerConfig(),
                 *, clock=time.perf_counter,
                 metrics: Optional[ServerMetrics] = None,
                 tracer=None, step_hook=None, faults=None,
                 stall_hook=None):
        if engine.model.cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(
                f"{engine.model.cfg.arch_type!r} caches are recurrent: "
                "the serving lane right-pads prompts to max_prompt_len, "
                "which recurrent state cannot mask")
        self.engine = engine
        self.cfg = cfg
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServerMetrics()
        # tracer clock should match `clock` for coherent timelines; the
        # caller constructs it (Tracer(clock=...)) so it can also carry
        # spans from outside the loop.  step_hook fires after every
        # jitted decode step (virtual-clock drivers advance time there).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.step_hook = step_hook
        # fault-injection plan (NULL_FAULTS = zero-overhead off, the
        # NULL_TRACER pattern) and the stall hook a virtual-clock driver
        # installs so injected slow ticks advance modeled time instead
        # of sleeping
        self.faults = faults if faults is not None else NULL_FAULTS
        self.stall_hook = stall_hook
        # rids whose tokens were (partly) produced by the bf16 fallback
        # lane: lossless w.r.t. the target distribution, but at T>0
        # possibly divergent from the fault-free quantized stream — the
        # chaos harness scopes its bit-identity assertion with this
        self.affected: Set[int] = set()
        # terminal error once the supervisor gives up; submit() fails
        # fast and handle.result() timeouts explain themselves
        self.dead: Optional[BaseException] = None
        self._raw_params = params
        self._ingress: "queue.SimpleQueue" = queue.SimpleQueue()
        self._control: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lanes: Dict[Tuple[float, bool], _Lane] = {}
        self._rid = 0
        self._rid_lock = threading.Lock()
        self.total_steps = 0
        # degraded lane: only meaningful when the primary drafter drafts
        # trees (template attr) — chain drafting IS the degraded mode
        self._degraded_engine = None
        if cfg.degrade_on_overload \
                and getattr(engine.drafter, "template", None) is not None:
            from repro.serving.engine import SpecEngine
            dscfg = dataclasses.replace(
                engine.scfg, tree_branches=None, drafter=cfg.degrade_drafter)
            self._degraded_engine = SpecEngine(
                engine.model, dscfg, drafter=cfg.degrade_drafter,
                verifier=engine.verifier)

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return not self._ingress.empty() or any(
            lane.sched.busy for lane in self._lanes.values())

    @property
    def pending(self) -> int:
        return sum(len(lane.sched._pending) for lane in self._lanes.values())

    def submit(self, request: GenerationRequest) -> StreamHandle:
        """Thread-safe ingestion; returns the request's stream handle.

        Never raises for a bad request: one violating the server caps
        comes back as a handle that terminally **fails** at the next
        poll (the ``ValueError`` rides on ``handle.error`` and
        re-raises from ``result()``), so a single malformed request
        cannot take down the submit path or the callers sharing it."""
        now = self.clock()
        with self._rid_lock:
            rid = self._rid
            self._rid += 1
        deadline_t = (None if request.deadline_s is None
                      else now + request.deadline_s)
        handle = StreamHandle(rid, request, now, deadline_t, loop=self)
        msg = request.violation(self.cfg.max_prompt_len,
                                self.cfg.max_new_tokens)
        if msg is not None:
            handle._reject = ValueError(msg)
        if self.dead is not None:
            # no poll will ever run again: resolve here, still counted
            # (the lock serializes concurrent submitters on metrics —
            # the loop thread that normally owns them is gone)
            err = LaneCrashed(
                f"serving loop is dead: {type(self.dead).__name__}")
            err.__cause__ = self.dead
            handle._routed = True
            with self._rid_lock:
                self.metrics.on_submit(rid, now, deadline_t=deadline_t)
                self.metrics.on_guardrail("rejected")
                self.metrics.on_failed(rid, now)
            handle._finish(None, "failed", error=err)
            return handle
        self._ingress.put(handle)
        return handle

    # ------------------------------------------------------------------
    def _overloaded(self) -> bool:
        return self.pending >= self.cfg.overload_factor * self.cfg.batch_slots

    def _lane(self, temperature: float, degraded: bool) -> _Lane:
        key = (temperature, degraded)
        lane = self._lanes.get(key)
        if lane is None:
            if len(self._lanes) >= _MAX_LANES:
                raise RuntimeError(
                    f"more than {_MAX_LANES} distinct (temperature, lane) "
                    "combinations — each pins a compiled decode step")
            engine = (self._degraded_engine if degraded else self.engine)
            tid = len(self._lanes)
            lane = _Lane(self, engine, temperature, tid)
            self._lanes[key] = lane
            label = (f"lane{tid} T={temperature:g} {lane.key}"
                     + (" degraded" if degraded else ""))
            self.tracer.thread_name(tid, label)
            if lane.ctx is not None:
                self.metrics.add_kv_source(f"lane{tid}", lane.ctx.snapshot)
        return lane

    def _reject_handle(self, handle: StreamHandle, exc: BaseException,
                       first: bool) -> None:
        """Resolve a handle as terminally failed before it ever reaches
        a scheduler (malformed submit, lane overflow, pre-route cancel).
        ``first`` guards the submitted count: a handle re-entering the
        ingress queue after a crash recovery is not re-counted."""
        if first:
            self.metrics.on_submit(handle.rid, handle.submit_t,
                                   deadline_t=handle.deadline_t)
        self.metrics.on_guardrail(
            "cancelled" if isinstance(exc, RequestCancelled)
            else "rejected")
        self.metrics.on_failed(handle.rid, self.clock())
        handle._finish(None, "failed", error=exc)

    def _route_ingress(self) -> int:
        routed = 0
        while True:
            try:
                handle = self._ingress.get_nowait()
            except queue.Empty:
                return routed
            routed += 1
            first = not handle._routed
            handle._routed = True
            if handle._reject is None \
                    and self.faults.fire("submit", rid=handle.rid):
                handle._reject = ValueError(
                    f"injected malformed request {handle.rid}")
            if handle._reject is not None:
                self._reject_handle(handle, handle._reject, first)
                continue
            if handle._cancelled:
                self._reject_handle(
                    handle,
                    RequestCancelled(
                        f"request {handle.rid} cancelled before routing"),
                    first)
                continue
            degraded = (self._degraded_engine is not None
                        and self._overloaded())
            handle.degraded = degraded
            t = (self.engine.scfg.temperature
                 if handle.request.temperature is None
                 else float(handle.request.temperature))
            try:
                lane = self._lane(t, degraded)
            except RuntimeError as exc:
                # _MAX_LANES overflow: the request asking for the novel
                # temperature fails alone; existing lanes keep serving
                self._reject_handle(handle, exc, first)
                continue
            idx = lane.sched.submit(
                handle.request, arrival_t=handle.submit_t,
                deadline=handle.deadline_t, trace_id=handle.rid)
            handle._lane = lane
            handle._idx = idx
            lane.on_submit(idx, handle)
            if first:
                self.metrics.on_submit(handle.rid, handle.submit_t,
                                       deadline_t=handle.deadline_t,
                                       degraded=degraded)

    def poll(self) -> bool:
        """One serving iteration: route arrivals, apply cancels and
        request timeouts, shed late queued work, advance every busy lane
        one decode step (streaming tokens as they commit), harvest.
        Returns True if any lane did work."""
        if self.faults.fire("poll"):
            raise InjectedFault("injected poll failure (supervisor seam)")
        self._route_ingress()
        # client cancels land on a control queue (thread-safe); apply
        # them before admission so a cancelled queued request never
        # takes a slot
        while True:
            try:
                h = self._control.get_nowait()
            except queue.Empty:
                break
            self._cancel_now(h)
        worked = False
        now = self.clock()
        if self.cfg.request_timeout_s is not None:
            self._check_timeouts(now)
        for lane in self._lanes.values():
            if self.cfg.shed_late:
                for i in lane.sched.shed_pending(
                        now, slack=self.cfg.shed_slack_s):
                    h = lane.handles.pop(i)
                    if lane.ctx is not None:
                        # a preempted request re-enters the pending queue
                        # and may be shed while swapped out — drop its
                        # host snapshot and swap marker (its blocks were
                        # already freed exactly once at eviction)
                        lane.ctx.drop(i)
                    self.metrics.on_shed(h.rid, now)
                    h._finish(None, "shed")
            if not lane.sched.busy:
                continue
            worked = True

            def on_tokens(i, toks, _lane=lane):
                h = _lane.handles[i]
                t_emit = self.clock()
                h._emit(toks)
                self.metrics.on_tokens(h.rid, t_emit, toks.size)

            def admit(st, slot, i, _lane=lane):
                st = _lane.admit(st, slot, i)
                self.metrics.on_admit(_lane.handles[i].rid, self.clock())
                return st

            can_admit = release = preempt = None
            if lane.ctx is not None:
                can_admit = lane.ctx.can_admit
                release = lane.ctx.release
                if self.engine.scfg.kv_preempt:
                    preempt = lane.ctx.preempt
            lane.state, harvested = lane.sched.tick(
                lane.state, admit=admit, step=lane.step_fn,
                can_admit=can_admit, release=release, preempt=preempt,
                on_tokens=on_tokens, on_fail=self._make_on_fail(lane),
                clock=self.clock)
            self.total_steps += 1
            busy = sum(ev is not None for ev in lane.sched._slots)
            self.metrics.on_step(self.clock(), busy, lane.sched.batch_slots)
            self.tracer.counter("occupancy", busy, tid=lane.tid)
            if lane.ctx is not None:
                self.tracer.counter("free_blocks",
                                    lane.ctx.pool.free_blocks, tid=lane.tid)
            for i in harvested:
                h = lane.handles.pop(i)
                self.metrics.on_finish(h.rid, self.clock())
                h._finish(lane.sched.results[i], "done")
        return worked

    def drain(self, max_polls: int = 10_000_000) -> None:
        """Poll until every submitted request reached a terminal state."""
        polls = 0
        while self.busy:
            self.poll()
            polls += 1
            if polls > max_polls:
                raise RuntimeError("ServingLoop.drain: poll budget exhausted")

    # -- failure containment (docs/robustness.md) ----------------------
    def _stall(self, seconds: float) -> None:
        """Model a slow/hung tick: virtual-clock drivers advance their
        clock via ``stall_hook``; a real server genuinely sleeps."""
        if self.stall_hook is not None:
            self.stall_hook(seconds)
        else:
            time.sleep(seconds)

    def _make_on_fail(self, lane: _Lane):
        """Scheduler ``on_fail`` hook for ``lane``: by the time it
        fires, the scheduler has recorded the terminal state and run
        ``release`` (blocks returned exactly once) — this closure idles
        the engine row, drops paged bookkeeping, and resolves the
        caller-facing handle."""
        def on_fail(st, slot, i, exc, _lane=lane):
            h = _lane.handles.pop(i, None)
            if slot is not None:
                # a dead request must stop decoding: zero the row's
                # length/target (the next admit re-prefills both)
                st = dict(st)
                st["length"] = st["length"].at[slot].set(0)
                st["target"] = st["target"].at[slot].set(0)
            if _lane.ctx is not None:
                _lane.ctx.drop(i)
            if h is not None:
                if isinstance(exc, RequestCancelled):
                    self.metrics.on_guardrail("cancelled")
                elif isinstance(exc, RequestTimeout):
                    self.metrics.on_guardrail("timeouts")
                else:
                    self.metrics.on_guardrail("request_faults")
                self.metrics.on_failed(h.rid, self.clock())
                h._finish(None, "failed", error=exc)
            return st
        return on_fail

    def _cancel_now(self, h: StreamHandle) -> None:
        """Apply a queued cancel request (loop thread only)."""
        if h.status in ("done", "shed", "failed"):
            return
        lane = h._lane
        if lane is None:
            # still in the ingress queue: the _cancelled flag makes
            # routing fail it on arrival
            return
        i = h._idx
        exc = RequestCancelled(f"request {h.rid} cancelled by client")
        onf = self._make_on_fail(lane)
        slot = lane.sched.find_slot(i)
        if slot is not None:
            release = lane.ctx.release if lane.ctx is not None else None
            lane.state = lane.sched.fail_running(
                lane.state, slot, exc, release=release, on_fail=onf)
        elif i in lane.sched.pending_indices():
            lane.state = lane.sched.fail_pending(
                lane.state, i, exc, on_fail=onf)

    def _check_timeouts(self, now: float) -> None:
        """Fail every request older end-to-end than
        ``request_timeout_s`` — queued or running.  This is what turns
        a slow/hung lane (injected stalls, a wedged device) into
        per-request failures instead of callers blocked forever."""
        cut = self.cfg.request_timeout_s
        for lane in self._lanes.values():
            onf = self._make_on_fail(lane)
            for i in lane.sched.pending_indices():
                h = lane.handles.get(i)
                if h is not None and now - h.submit_t > cut:
                    lane.state = lane.sched.fail_pending(
                        lane.state, i,
                        RequestTimeout(
                            f"request {h.rid} exceeded "
                            f"request_timeout_s={cut} while queued"),
                        on_fail=onf)
            release = lane.ctx.release if lane.ctx is not None else None
            for s in range(lane.sched.batch_slots):
                ev = lane.sched._slots[s]
                if ev is None:
                    continue
                h = lane.handles.get(ev.request_index)
                if h is not None and now - h.submit_t > cut:
                    lane.state = lane.sched.fail_running(
                        lane.state, s,
                        RequestTimeout(
                            f"request {h.rid} exceeded "
                            f"request_timeout_s={cut} while running"),
                        release=release, on_fail=onf)

    def recover(self, exc: BaseException) -> None:
        """Containment after an exception escaped :meth:`poll` (the
        supervisor path): running requests fail (their lane state can no
        longer be trusted), queued handles re-enter the ingress queue,
        and all lanes are torn down — the next poll rebuilds them
        (compiled steps are cached on the engine, so a rebuild does not
        retrace).  Conservation holds: requeued work is not re-counted
        as submitted."""
        requeue: List[StreamHandle] = []
        for lane in self._lanes.values():
            for i, h in list(lane.handles.items()):
                if h.status == "running":
                    err = LaneCrashed(
                        f"serving lane crashed under request {h.rid}: "
                        f"{type(exc).__name__}")
                    err.__cause__ = exc
                    self.metrics.on_guardrail("request_faults")
                    self.metrics.on_failed(h.rid, self.clock())
                    h._finish(None, "failed", error=err)
                else:
                    # still queued: nothing of it lives on-device yet
                    requeue.append(h)
            lane.handles.clear()
        self._lanes.clear()
        for h in requeue:
            h._lane = h._idx = None
            self._ingress.put(h)

    def abort(self, exc: BaseException) -> None:
        """Terminal failure: mark the loop dead and fail everything in
        flight.  Nothing hangs; conservation still holds."""
        self.dead = exc
        while True:
            try:
                h = self._ingress.get_nowait()
            except queue.Empty:
                break
            if not h._routed:
                h._routed = True
                self.metrics.on_submit(h.rid, h.submit_t,
                                       deadline_t=h.deadline_t)
            self.metrics.on_guardrail("request_faults")
            self.metrics.on_failed(h.rid, self.clock())
            h._finish(None, "failed", error=exc)
        for lane in self._lanes.values():
            for i, h in list(lane.handles.items()):
                self.metrics.on_guardrail("request_faults")
                self.metrics.on_failed(h.rid, self.clock())
                h._finish(None, "failed", error=exc)
            lane.handles.clear()
        self._lanes.clear()

    def shutdown(self) -> None:
        """Deterministic non-drain teardown: everything already
        submitted resolves now — queued work is shed
        (``shed_pending(inf)`` takes every pending request: no-deadline
        requests carry an ``inf`` deadline), running work fails with
        ``RequestCancelled``.  The loop ends idle with conservation
        intact; it is NOT dead (submit keeps working)."""
        self._route_ingress()
        now = self.clock()
        for lane in self._lanes.values():
            onf = self._make_on_fail(lane)
            for i in lane.sched.shed_pending(math.inf):
                h = lane.handles.pop(i)
                if lane.ctx is not None:
                    lane.ctx.drop(i)
                self.metrics.on_shed(h.rid, now)
                h._finish(None, "shed")
            release = lane.ctx.release if lane.ctx is not None else None
            for s in range(lane.sched.batch_slots):
                if lane.sched._slots[s] is not None:
                    lane.state = lane.sched.fail_running(
                        lane.state, s,
                        RequestCancelled("server shutdown"),
                        release=release, on_fail=onf)


class StreamingServer:
    """Background-thread front over :class:`ServingLoop`.

    ::

        server = StreamingServer(engine, params, ServerConfig(...))
        with server:                       # starts the serving thread
            h = server.submit(GenerationRequest(prompt, 32, deadline_s=2.0))
            for delta in h.tokens():       # per-token streaming
                emit(delta)
            result = h.result()            # None if the request was shed
        print(server.metrics.summary())
    """

    def __init__(self, engine, params, cfg: ServerConfig = ServerConfig(),
                 *, poll_idle_s: float = 0.002, tracer=None,
                 metrics: Optional[ServerMetrics] = None, faults=None,
                 restart_backoff_s: float = 0.05, max_restarts: int = 3):
        self.loop = ServingLoop(engine, params, cfg, tracer=tracer,
                                metrics=metrics, faults=faults)
        self.poll_idle_s = poll_idle_s
        self.restart_backoff_s = restart_backoff_s
        self.max_restarts = max_restarts       # consecutive, then abort
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def metrics(self) -> ServerMetrics:
        return self.loop.metrics

    def start(self) -> "StreamingServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="repro-serving-loop", daemon=True)
        self._thread.start()
        return self

    def submit(self, request: GenerationRequest) -> StreamHandle:
        if self._thread is None:
            raise RuntimeError("server not started (use `with server:` "
                               "or server.start())")
        handle = self.loop.submit(request)
        self._wake.set()
        return handle

    def _run(self) -> None:
        """Serving-thread body: poll under a supervisor.

        An exception escaping ``poll()`` used to kill this thread
        silently — in-flight requests hung forever while the server
        looked healthy.  Now each crash is contained
        (``ServingLoop.recover``: running requests fail loudly, queued
        work requeues, lanes rebuild) and the loop restarts with capped
        exponential backoff; ``max_restarts`` *consecutive* crashes
        abort the loop — every in-flight request fails with the
        terminal error, which also re-raises from :meth:`stop`."""
        crashes = 0
        backoff = self.restart_backoff_s
        while not self._stop.is_set():
            try:
                worked = self.loop.poll()
            except Exception as exc:  # noqa: BLE001 — supervisor seam
                crashes += 1
                self.metrics.on_guardrail("lane_restarts")
                if crashes > self.max_restarts:
                    err = LaneCrashed(
                        f"serving loop crashed {crashes} consecutive "
                        f"times; giving up: {type(exc).__name__}: {exc}")
                    err.__cause__ = exc
                    self.loop.abort(err)
                    return
                self.loop.recover(exc)
                self._stop.wait(backoff)
                backoff = min(backoff * 2.0, 5.0)
                continue
            crashes = 0
            backoff = self.restart_backoff_s
            if not worked:
                # idle: sleep until a submit wakes us (bounded, so
                # deadline shedding still fires for queued work)
                self._wake.wait(self.poll_idle_s)
                self._wake.clear()

    def stop(self, *, drain: bool = True, timeout: float = 600.0) -> None:
        """Stop the serving thread (draining first by default).

        If the supervisor gave up (``loop.dead``), the terminal error
        re-raises here — a crashed server is loud at shutdown, never
        silent."""
        if self._thread is None:
            if self.loop.dead is not None:
                raise self.loop.dead
            return
        if drain:
            t0 = time.monotonic()
            while self.loop.busy and self.loop.dead is None:
                if time.monotonic() - t0 > timeout:
                    raise RuntimeError("StreamingServer.stop: drain timeout")
                time.sleep(self.poll_idle_s)
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=timeout)
        self._thread = None
        if self.loop.dead is not None:
            raise self.loop.dead

    def __enter__(self) -> "StreamingServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        try:
            self.stop(drain=exc_type is None)
        except BaseException:
            if exc_type is None:
                raise
            # an exception is already in flight from the with-body:
            # don't mask it with the teardown's
