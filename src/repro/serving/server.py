"""Async streaming serving front-end: SLO-aware admission over the
continuous-batching scheduler.

The engine below this layer is batch-synchronous: ``generate_requests``
blocks until a fixed request list drains.  A server faces an *open* loop
— requests arrive continuously, each with its own latency SLO — and the
SD survey's (arXiv:2401.07851) deployment lesson applies: realized
speedup is decided by the serving loop, not the kernel.  This module
adds that loop as a layer **above** the engine, reusing the scheduler's
admit → step → harvest machinery unchanged:

* :class:`ServingLoop` — the single-threaded core.  An ingestion queue
  feeds per-(temperature, lane) :class:`Scheduler` instances
  (temperature is jit-static, so each lane owns one compiled decode
  step and one fixed-shape state pytree); :meth:`poll` routes arrivals,
  sheds queued work whose deadline already passed, and advances each
  busy lane one decode step, forwarding newly-committed tokens to the
  per-request :class:`StreamHandle` as they commit.  The clock is
  injectable, so load-replay benchmarks (``benchmarks/serve_load.py``)
  drive the identical code path on a deterministic virtual clock.
* :class:`StreamingServer` — the asynchronous front: a background
  thread polls the loop while callers ``submit()`` from any thread and
  consume ``handle.tokens()`` / ``handle.result()`` concurrently.

SLO-aware admission, in order of application:

1. **EDF within priority class** (``admission="edf"``): pending
   requests pop by ``(priority, absolute deadline, arrival)`` — the
   optimal single-machine order for deadline hit-rate.  Like priority,
   it only shifts *when* a request is admitted; per-request seed
   streams keep its tokens bit-identical to FIFO admission and to solo
   serving.
2. **Shedding** (``shed_late=True``): a queued request whose deadline
   has already passed (plus ``shed_slack_s``) is dropped instead of
   burning a slot on an answer nobody is waiting for — under overload
   the queue stays short and on-time work keeps meeting its SLO.
   Running requests are never shed.  ``completed + shed == submitted``
   is a checked invariant: nothing is lost silently.
3. **Degrade tree → chain** (``degrade_on_overload=True``): when the
   pending backlog exceeds ``overload_factor × batch_slots`` and the
   engine drafts token *trees*, new arrivals are routed to a chain-
   drafting lane instead — smaller verify windows, higher batch
   throughput, lower per-step latency.  At T=0 this is invisible in the
   tokens (speculative decoding is lossless: any drafter yields the
   target model's greedy stream); at T>0 the sampled stream may differ
   from the tree lane's (different PRNG consumption), which is why
   degrade is opt-in.

With ``SpecConfig(kv_layout="paged")`` each lane owns a block pool
sized for its slot count's worst-case demand, a prefix-cache index
(shared system prompts are stored once across requests,
``kv_prefix_sharing``) and a host-side swap pool: when the pool denies
the queue head, the scheduler preempts the lowest-priority running
occupant — its blocks are snapshotted to host ``numpy`` and freed — and
resumes it later bit-exactly (``kv_preempt``).  Worst-case reservation
thus stops being the admission ceiling (``serving/engine.PagedGroup``).

Restrictions (v1): attention-family archs only (the lane pads prompts
to ``max_prompt_len``; recurrent caches cannot right-pad) — enforced at
construction.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core.paged_cache import (
    blocks_for_tokens,
    init_paged_cache,
    request_demand_tokens,
)
from repro.core.spec_engine import init_state
from repro.serving.metrics import ServerMetrics
from repro.serving.request import GenerationRequest, RequestResult
from repro.serving.scheduler import Scheduler
from repro.serving.trace import NULL_TRACER

_MAX_LANES = 8          # distinct (temperature, degraded) decode loops


@dataclass(frozen=True)
class ServerConfig:
    """Serving front-end policy knobs (engine knobs live in SpecConfig)."""

    batch_slots: int = 4               # decode rows per lane
    max_prompt_len: int = 64           # admission caps: they fix the
    max_new_tokens: int = 64           # lane's jit-static buffer sizes
    admission: str = "edf"             # "edf" | "fifo"
    shed_late: bool = True             # drop queued past-deadline work
    shed_slack_s: float = 0.0          # pre-shed margin (est. min service)
    degrade_on_overload: bool = False  # tree -> chain lane under pressure
    degrade_drafter: str = "ngram"     # chain drafter for the degraded lane
    overload_factor: float = 2.0       # pending > factor*slots = overload
    max_events: Optional[int] = 1024   # scheduler audit-trail cap per lane

    def __post_init__(self):
        if self.admission not in ("fifo", "edf"):
            raise ValueError(f"unknown admission {self.admission!r}")
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")


_EOS = None                            # stream terminator sentinel


class StreamHandle:
    """Caller-side view of one in-flight request.

    * :meth:`tokens` — blocking iterator over newly-committed token
      deltas (``np.int32`` arrays); ends when the request finishes or is
      shed.  Safe to consume from a different thread than the server's.
    * :attr:`chunks` — the deltas accumulated so far (non-blocking; the
      inline/virtual-clock driver reads this after :meth:`ServingLoop.
      drain`).  ``np.concatenate(chunks)`` is bit-identical to
      ``result().tokens`` — the streaming contract.
    * :meth:`result` — blocks until completion; returns the
      :class:`RequestResult`, or ``None`` if the request was shed.
    * :attr:`status` — ``queued | running | done | shed``.
    """

    def __init__(self, rid: int, request: GenerationRequest,
                 submit_t: float, deadline_t: Optional[float]):
        self.rid = rid
        self.request = request
        self.submit_t = submit_t
        self.deadline_t = deadline_t
        self.status = "queued"
        self.degraded = False
        self.chunks: List[np.ndarray] = []
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._done = threading.Event()
        self._result: Optional[RequestResult] = None

    def tokens(self):
        while True:
            item = self._q.get()
            if item is _EOS:
                return
            yield item

    def result(self, timeout: Optional[float] = None
               ) -> Optional[RequestResult]:
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request {self.rid} still {self.status} after {timeout}s")
        return self._result

    def collected(self) -> np.ndarray:
        """All streamed tokens so far, concatenated (non-blocking)."""
        if not self.chunks:
            return np.zeros((0,), np.int32)
        return np.concatenate(self.chunks)

    # loop-side -------------------------------------------------------
    def _emit(self, toks: np.ndarray) -> None:
        self.chunks.append(toks)
        self._q.put(toks)

    def _finish(self, result: Optional[RequestResult], status: str) -> None:
        self._result = result
        self.status = status
        self._q.put(_EOS)
        self._done.set()


class _Lane:
    """One compiled decode loop: a Scheduler + fixed-shape state pytree
    for a given (temperature, degraded?) combination."""

    def __init__(self, loop: "ServingLoop", engine, temperature: float,
                 tid: int = 0):
        cfg = loop.cfg
        self.loop = loop
        self.tid = tid                         # tracer track for this lane
        self.engine = engine
        self.params = engine._prepare_cached(loop._raw_params)
        self.step, self.drafter = engine._step_for_temperature(temperature)
        self.key = f"{self.drafter.name}:{engine.verifier.name}"
        self.buf = (cfg.max_prompt_len + cfg.max_new_tokens
                    + self.drafter.gamma + 2)
        # one padded prompt length per lane => admission prefill compiles
        # once; requests shorter than the cap are right-padded exactly as
        # generate_requests pads a group to its maximum
        self.pmax = cfg.max_prompt_len
        slots = cfg.batch_slots

        def on_step_stats(accepted, step_s, n_tokens, _key=self.key):
            loop.metrics.on_decode_step(_key, accepted, step_s)
            engine.telemetry.on_decode_step(_key, accepted, step_s)

        self.sched = Scheduler(
            [], slots, policy=cfg.admission, max_events=cfg.max_events,
            on_event=loop.metrics.on_slot_event,
            tracer=loop.tracer, trace_tid=tid,
            on_step_stats=on_step_stats)
        self.ctx = None                        # paged: PagedGroup context
        cache = None
        scfg = engine.scfg
        if scfg.kv_layout == "paged":
            engine._check_paged_supported()
            bs = scfg.kv_block_size
            # every admitted request can demand at most the server caps'
            # worth of blocks; one pool per lane, sized so `slots`
            # worst-case requests co-reside (+1 COW headroom each when
            # prefix sharing may donate boundary blocks, +1 scratch)
            demand_cap = blocks_for_tokens(
                request_demand_tokens(cfg.max_prompt_len,
                                      cfg.max_new_tokens,
                                      self.drafter.gamma), bs)
            per = demand_cap + (1 if scfg.kv_prefix_sharing else 0)
            num_blocks = (scfg.kv_pool_blocks
                          if scfg.kv_pool_blocks is not None
                          else 1 + slots * per)
            if demand_cap > num_blocks - 1:
                raise ValueError(
                    f"kv_pool_blocks={num_blocks} cannot hold even one "
                    f"worst-case request ({demand_cap} blocks at the "
                    "server's prompt/budget caps)")
            max_blocks = blocks_for_tokens(self.buf, bs)
            cache = init_paged_cache(engine.model.cfg, slots, max_blocks,
                                     num_blocks, bs)
            self.ctx = engine.paged_group(num_blocks=num_blocks,
                                          block_size=bs,
                                          gamma=self.drafter.gamma,
                                          tracer=loop.tracer,
                                          trace_tid=tid)
        self.state = init_state(
            engine.model, slots, self.buf,
            jnp.zeros((slots, 2), jnp.uint32),
            drafter_state=self.drafter.alloc_state(
                engine.model, self.params, slots, self.buf),
            target=jnp.zeros((slots,), jnp.int32),
            cache=cache)
        self.handles: Dict[int, StreamHandle] = {}   # lane index -> handle

    def on_submit(self, i: int, handle: StreamHandle) -> None:
        self.handles[i] = handle
        if self.ctx is not None:
            self.ctx.register(i, handle.request)

    def admit(self, state: dict, slot: int, i: int) -> dict:
        h = self.handles[i]
        h.status = "running"
        if self.ctx is not None:
            return self.ctx.admit(state, slot, i, params=self.params,
                                  pmax=self.pmax, drafter=self.drafter)
        return self.engine.prefill_into_slot(
            self.params, state, slot, h.request,
            pmax=self.pmax, drafter=self.drafter)

    def step_fn(self, state: dict) -> dict:
        if self.ctx is not None:
            state = self.ctx.prepare_step(state)
        state = self.step(self.params, state)
        # fires inside the scheduler's "decode" span: a virtual-clock
        # driver advances time here, so spans get real widths and the
        # per-step wall time equals the modeled step cost
        if self.loop.step_hook is not None:
            self.loop.step_hook()
        return state


class ServingLoop:
    """Single-threaded serving core with an injectable clock.

    ``submit()`` is thread-safe (arrivals land on an ingestion queue);
    ``poll()`` must be called from one driving thread — either the
    :class:`StreamingServer` wrapper's background thread (real clock) or
    a benchmark's replay loop (virtual clock).
    """

    def __init__(self, engine, params, cfg: ServerConfig = ServerConfig(),
                 *, clock=time.perf_counter,
                 metrics: Optional[ServerMetrics] = None,
                 tracer=None, step_hook=None):
        if engine.model.cfg.arch_type in ("ssm", "hybrid"):
            raise ValueError(
                f"{engine.model.cfg.arch_type!r} caches are recurrent: "
                "the serving lane right-pads prompts to max_prompt_len, "
                "which recurrent state cannot mask")
        self.engine = engine
        self.cfg = cfg
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServerMetrics()
        # tracer clock should match `clock` for coherent timelines; the
        # caller constructs it (Tracer(clock=...)) so it can also carry
        # spans from outside the loop.  step_hook fires after every
        # jitted decode step (virtual-clock drivers advance time there).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.step_hook = step_hook
        self._raw_params = params
        self._ingress: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lanes: Dict[Tuple[float, bool], _Lane] = {}
        self._rid = 0
        self._rid_lock = threading.Lock()
        self.total_steps = 0
        # degraded lane: only meaningful when the primary drafter drafts
        # trees (template attr) — chain drafting IS the degraded mode
        self._degraded_engine = None
        if cfg.degrade_on_overload \
                and getattr(engine.drafter, "template", None) is not None:
            from repro.serving.engine import SpecEngine
            dscfg = dataclasses.replace(
                engine.scfg, tree_branches=None, drafter=cfg.degrade_drafter)
            self._degraded_engine = SpecEngine(
                engine.model, dscfg, drafter=cfg.degrade_drafter,
                verifier=engine.verifier)

    # ------------------------------------------------------------------
    @property
    def busy(self) -> bool:
        return not self._ingress.empty() or any(
            lane.sched.busy for lane in self._lanes.values())

    @property
    def pending(self) -> int:
        return sum(len(lane.sched._pending) for lane in self._lanes.values())

    def submit(self, request: GenerationRequest) -> StreamHandle:
        """Thread-safe ingestion; returns the request's stream handle."""
        if request.prompt.size > self.cfg.max_prompt_len:
            raise ValueError(
                f"prompt length {request.prompt.size} exceeds the server's "
                f"max_prompt_len={self.cfg.max_prompt_len}")
        if request.max_new_tokens > self.cfg.max_new_tokens:
            raise ValueError(
                f"max_new_tokens {request.max_new_tokens} exceeds the "
                f"server's cap {self.cfg.max_new_tokens}")
        now = self.clock()
        with self._rid_lock:
            rid = self._rid
            self._rid += 1
        deadline_t = (None if request.deadline_s is None
                      else now + request.deadline_s)
        handle = StreamHandle(rid, request, now, deadline_t)
        self._ingress.put(handle)
        return handle

    # ------------------------------------------------------------------
    def _overloaded(self) -> bool:
        return self.pending >= self.cfg.overload_factor * self.cfg.batch_slots

    def _lane(self, temperature: float, degraded: bool) -> _Lane:
        key = (temperature, degraded)
        lane = self._lanes.get(key)
        if lane is None:
            if len(self._lanes) >= _MAX_LANES:
                raise RuntimeError(
                    f"more than {_MAX_LANES} distinct (temperature, lane) "
                    "combinations — each pins a compiled decode step")
            engine = (self._degraded_engine if degraded else self.engine)
            tid = len(self._lanes)
            lane = _Lane(self, engine, temperature, tid)
            self._lanes[key] = lane
            label = (f"lane{tid} T={temperature:g} {lane.key}"
                     + (" degraded" if degraded else ""))
            self.tracer.thread_name(tid, label)
            if lane.ctx is not None:
                self.metrics.add_kv_source(f"lane{tid}", lane.ctx.snapshot)
        return lane

    def _route_ingress(self) -> int:
        routed = 0
        while True:
            try:
                handle = self._ingress.get_nowait()
            except queue.Empty:
                return routed
            degraded = (self._degraded_engine is not None
                        and self._overloaded())
            handle.degraded = degraded
            t = (self.engine.scfg.temperature
                 if handle.request.temperature is None
                 else float(handle.request.temperature))
            lane = self._lane(t, degraded)
            idx = lane.sched.submit(
                handle.request, arrival_t=handle.submit_t,
                deadline=handle.deadline_t, trace_id=handle.rid)
            lane.on_submit(idx, handle)
            self.metrics.on_submit(handle.rid, handle.submit_t,
                                   deadline_t=handle.deadline_t,
                                   degraded=degraded)
            routed += 1

    def poll(self) -> bool:
        """One serving iteration: route arrivals, shed late queued work,
        advance every busy lane one decode step (streaming tokens as
        they commit), harvest.  Returns True if any lane did work."""
        self._route_ingress()
        worked = False
        now = self.clock()
        for lane in self._lanes.values():
            if self.cfg.shed_late:
                for i in lane.sched.shed_pending(
                        now, slack=self.cfg.shed_slack_s):
                    h = lane.handles.pop(i)
                    if lane.ctx is not None:
                        # a preempted request re-enters the pending queue
                        # and may be shed while swapped out — drop its
                        # host snapshot and swap marker (its blocks were
                        # already freed exactly once at eviction)
                        lane.ctx.drop(i)
                    self.metrics.on_shed(h.rid, now)
                    h._finish(None, "shed")
            if not lane.sched.busy:
                continue
            worked = True

            def on_tokens(i, toks, _lane=lane):
                h = _lane.handles[i]
                t_emit = self.clock()
                h._emit(toks)
                self.metrics.on_tokens(h.rid, t_emit, toks.size)

            def admit(st, slot, i, _lane=lane):
                st = _lane.admit(st, slot, i)
                self.metrics.on_admit(_lane.handles[i].rid, self.clock())
                return st

            can_admit = release = preempt = None
            if lane.ctx is not None:
                can_admit = lane.ctx.can_admit
                release = lane.ctx.release
                if self.engine.scfg.kv_preempt:
                    preempt = lane.ctx.preempt
            lane.state, harvested = lane.sched.tick(
                lane.state, admit=admit, step=lane.step_fn,
                can_admit=can_admit, release=release, preempt=preempt,
                on_tokens=on_tokens, clock=self.clock)
            self.total_steps += 1
            busy = sum(ev is not None for ev in lane.sched._slots)
            self.metrics.on_step(self.clock(), busy, lane.sched.batch_slots)
            self.tracer.counter("occupancy", busy, tid=lane.tid)
            if lane.ctx is not None:
                self.tracer.counter("free_blocks",
                                    lane.ctx.pool.free_blocks, tid=lane.tid)
            for i in harvested:
                h = lane.handles.pop(i)
                self.metrics.on_finish(h.rid, self.clock())
                h._finish(lane.sched.results[i], "done")
        return worked

    def drain(self, max_polls: int = 10_000_000) -> None:
        """Poll until every submitted request is finished or shed."""
        polls = 0
        while self.busy:
            self.poll()
            polls += 1
            if polls > max_polls:
                raise RuntimeError("ServingLoop.drain: poll budget exhausted")


class StreamingServer:
    """Background-thread front over :class:`ServingLoop`.

    ::

        server = StreamingServer(engine, params, ServerConfig(...))
        with server:                       # starts the serving thread
            h = server.submit(GenerationRequest(prompt, 32, deadline_s=2.0))
            for delta in h.tokens():       # per-token streaming
                emit(delta)
            result = h.result()            # None if the request was shed
        print(server.metrics.summary())
    """

    def __init__(self, engine, params, cfg: ServerConfig = ServerConfig(),
                 *, poll_idle_s: float = 0.002, tracer=None,
                 metrics: Optional[ServerMetrics] = None):
        self.loop = ServingLoop(engine, params, cfg, tracer=tracer,
                                metrics=metrics)
        self.poll_idle_s = poll_idle_s
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def metrics(self) -> ServerMetrics:
        return self.loop.metrics

    def start(self) -> "StreamingServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="repro-serving-loop", daemon=True)
        self._thread.start()
        return self

    def submit(self, request: GenerationRequest) -> StreamHandle:
        if self._thread is None:
            raise RuntimeError("server not started (use `with server:` "
                               "or server.start())")
        handle = self.loop.submit(request)
        self._wake.set()
        return handle

    def _run(self) -> None:
        while not self._stop.is_set():
            if not self.loop.poll():
                # idle: sleep until a submit wakes us (bounded, so
                # deadline shedding still fires for queued work)
                self._wake.wait(self.poll_idle_s)
                self._wake.clear()

    def stop(self, *, drain: bool = True, timeout: float = 600.0) -> None:
        if self._thread is None:
            return
        if drain:
            t0 = time.monotonic()
            while self.loop.busy:
                if time.monotonic() - t0 > timeout:
                    raise RuntimeError("StreamingServer.stop: drain timeout")
                time.sleep(self.poll_idle_s)
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=timeout)
        self._thread = None

    def __enter__(self) -> "StreamingServer":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop(drain=exc_type is None)
