"""Zero-dependency span tracer with Chrome trace-event / Perfetto export.

The serving stack (``serving/server.py`` lanes, ``serving/scheduler.py``
ticks, ``serving/engine.py`` paged admission/swap) emits structured
spans through one :class:`Tracer` so a whole request's life — queued →
admitted → running → finish/shed, with preempt/resume and swap-out/in
sub-spans — renders on one timeline in Perfetto / ``chrome://tracing``.

Design constraints, in priority order:

1. **Determinism.**  The clock is *injected* (``Tracer(clock=...)``), so
   the virtual-clock load replay (``benchmarks/serve_load.py``) produces
   byte-identical trace JSON across runs: same workload + same seed ⇒
   same bytes (asserted in tests/test_observability.py).  ``export()``
   serialises with sorted keys and fixed separators, and nothing
   non-deterministic (wall time, object ids, dict order) ever reaches an
   event.
2. **Zero dependencies.**  Pure stdlib — the scheduler stays
   array-framework-agnostic.  The optional ``annotate_device=True`` mode
   lazily imports ``jax.profiler.TraceAnnotation`` so host spans also
   appear on the device timeline when a TPU/XLA profile is being taken;
   when jax is absent (or the import fails) it degrades to host-only.
3. **Zero cost when off.**  ``NULL_TRACER`` is a shared no-op whose
   ``span()`` returns a reusable null context; every call site does
   ``tracer or NULL_TRACER`` once and never branches again.  Generated
   tokens are bit-identical with tracing enabled vs disabled because the
   tracer only *observes* host control flow (asserted end-to-end).

Event model (Chrome trace-event JSON, ``ts`` in microseconds):

* :meth:`Tracer.span` — synchronous duration events (``ph: B/E``) on a
  per-lane track (``tid``); they must nest, which the serving loop's
  tick → admit/decode/harvest structure guarantees.
* :meth:`Tracer.begin_async` / :meth:`Tracer.end_async` — async events
  (``ph: b/e``) keyed by ``(cat, id)`` for request lifecycle phases
  that overlap arbitrarily across requests.
* :meth:`Tracer.instant` (``ph: i``) for point events (shed),
  :meth:`Tracer.counter` (``ph: C``) for gauges (occupancy, free
  blocks), :meth:`Tracer.thread_name` (``ph: M``) to label tracks.

``tools/check_trace.py`` validates the structural invariants (matched
B/E nesting per track, non-decreasing timestamps, balanced async
begin/end per id, required attrs) and CI runs it on the smoke-replay
artifact.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional


class _NullSpan:
    """Reusable no-op context manager (shared instance, zero alloc)."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: every ``Tracer`` method exists and does nothing.

    Call sites hold ``tracer or NULL_TRACER`` so the hot path never
    branches on "is tracing on?" — it just calls through.
    """

    __slots__ = ()

    def span(self, name: str, *, tid: int = 0, **args):
        return _NULL_SPAN

    def begin_async(self, name: str, aid: int, *, cat: str = "request",
                    **args) -> None:
        pass

    def end_async(self, name: str, aid: int, *, cat: str = "request",
                  **args) -> None:
        pass

    def instant(self, name: str, *, tid: int = 0, **args) -> None:
        pass

    def counter(self, name: str, values, *, tid: int = 0) -> None:
        pass

    def thread_name(self, tid: int, name: str) -> None:
        pass

    @property
    def enabled(self) -> bool:
        return False


NULL_TRACER = NullTracer()


class _Span:
    """Context manager emitting a ``B``/``E`` pair (plus an optional
    device-side ``TraceAnnotation``)."""

    __slots__ = ("_tr", "_name", "_tid", "_args", "_recorded", "_device")

    def __init__(self, tracer: "Tracer", name: str, tid: int,
                 args: Optional[dict]):
        self._tr = tracer
        self._name = name
        self._tid = tid
        self._args = args
        self._recorded = False
        self._device = None

    def __enter__(self):
        self._recorded = self._tr._emit(
            {"ph": "B", "name": self._name, "tid": self._tid,
             **({"args": self._args} if self._args else {})})
        ann = self._tr._annotation
        if ann is not None:
            self._device = ann(self._name)
            self._device.__enter__()
        return self

    def __exit__(self, *exc):
        if self._device is not None:
            self._device.__exit__(*exc)
        if self._recorded:
            # E must pair with its B: only emit if the B made it in
            # (the max_events cap can drop the B but never orphan an E)
            ev = {"ph": "E", "name": self._name, "tid": self._tid}
            if exc and exc[0] is not None:
                # span ended by an exception — tag the closing event so
                # fault-containment paths are visible in the trace
                ev["args"] = {"error": exc[0].__name__}
            self._tr._emit(ev, force=True)
        return False


class Tracer:
    """Collects trace events against an injectable clock.

    Parameters
    ----------
    clock:
        Seconds-valued monotone callable.  Inject a virtual clock for
        deterministic traces; defaults to ``time.perf_counter``.
    pid:
        Process id stamped on every event (one serving process = one
        pid track group).
    max_events:
        Optional bound on retained events — a long-lived server caps
        memory.  New ``B``/async-begin/instant/counter events are
        *dropped* once full (counted in ``dropped``); ``E``/async-end
        events whose begin was recorded always land so the trace stays
        structurally valid.
    annotate_device:
        When True, each :meth:`span` additionally enters a
        ``jax.profiler.TraceAnnotation`` so the span shows up in XLA
        device profiles.  Lazily imported; silently off if unavailable.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter, *,
                 pid: int = 1, max_events: Optional[int] = None,
                 annotate_device: bool = False):
        if max_events is not None and max_events < 0:
            raise ValueError("max_events must be >= 0 (or None)")
        self._clock = clock
        self.pid = int(pid)
        self.max_events = max_events
        self.dropped = 0
        self.events: List[Dict[str, Any]] = []
        self._open_async: Dict[tuple, int] = {}   # (cat, id, name) -> depth
        self._annotation = None
        if annotate_device:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation = TraceAnnotation
            except Exception:                      # pragma: no cover
                self._annotation = None

    @property
    def enabled(self) -> bool:
        return True

    # ------------------------------------------------------------------
    def _now_us(self) -> float:
        return self._clock() * 1e6

    def _emit(self, ev: Dict[str, Any], *, force: bool = False) -> bool:
        """Stamp + append ``ev``; returns False when dropped by the cap."""
        if (not force and self.max_events is not None
                and len(self.events) >= self.max_events):
            self.dropped += 1
            return False
        ev.setdefault("tid", 0)
        ev["pid"] = self.pid
        if ev["ph"] != "M":
            ev["ts"] = self._now_us()
        self.events.append(ev)
        return True

    # ------------------------------------------------------------------
    def span(self, name: str, *, tid: int = 0, **args):
        """Synchronous duration span (``with tracer.span("decode", ...):``).

        Spans on one ``tid`` must nest (LIFO) — the Chrome duration-event
        contract, validated by ``tools/check_trace.py``.
        """
        return _Span(self, name, tid, args or None)

    def begin_async(self, name: str, aid: int, *, cat: str = "request",
                    **args) -> None:
        """Open an async phase ``name`` for id ``aid`` (e.g. one request's
        ``queued`` / ``running`` / ``preempted`` lifecycle phase)."""
        key = (cat, aid, name)
        ev = {"ph": "b", "cat": cat, "id": aid, "name": name,
              **({"args": args} if args else {})}
        if self._emit(ev):
            self._open_async[key] = self._open_async.get(key, 0) + 1

    def end_async(self, name: str, aid: int, *, cat: str = "request",
                  **args) -> None:
        """Close the async phase opened by :meth:`begin_async`.

        A close with no recorded open (possible only under the
        ``max_events`` cap) is skipped so begins/ends stay balanced.
        """
        key = (cat, aid, name)
        depth = self._open_async.get(key, 0)
        if depth <= 0:
            return
        self._open_async[key] = depth - 1
        self._emit({"ph": "e", "cat": cat, "id": aid, "name": name,
                    **({"args": args} if args else {})}, force=True)

    def instant(self, name: str, *, tid: int = 0, **args) -> None:
        """Point event (``ph: i``, thread-scoped)."""
        self._emit({"ph": "i", "s": "t", "name": name, "tid": tid,
                    **({"args": args} if args else {})})

    def counter(self, name: str, values, *, tid: int = 0) -> None:
        """Counter sample: ``values`` is a number or a {series: number}
        dict (Perfetto stacks multi-series counters)."""
        if not isinstance(values, dict):
            values = {name: values}
        self._emit({"ph": "C", "name": name, "tid": tid,
                    "args": {k: float(v) for k, v in values.items()}})

    def thread_name(self, tid: int, name: str) -> None:
        """Label track ``tid`` (metadata event, no timestamp)."""
        self._emit({"ph": "M", "name": "thread_name", "tid": tid,
                    "args": {"name": name}}, force=True)

    # ------------------------------------------------------------------
    def export(self) -> dict:
        """Trace-event JSON object (``{"traceEvents": [...], ...}``)."""
        return {"traceEvents": list(self.events),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped}}

    def dumps(self) -> str:
        """Deterministic serialisation: sorted keys, fixed separators —
        identical inputs produce byte-identical output."""
        return json.dumps(self.export(), sort_keys=True,
                          separators=(",", ":"))

    def save(self, path: str) -> str:
        """Write the trace to ``path``; open the file in Perfetto
        (https://ui.perfetto.dev) or ``chrome://tracing``."""
        with open(path, "w") as f:
            f.write(self.dumps())
            f.write("\n")
        return path
