from repro.train.optimizer import adamw_init, adamw_update, cosine_lr  # noqa: F401
from repro.train.trainer import Trainer, make_train_step  # noqa: F401
