"""Checkpointing: param/optimizer pytrees ↔ a single ``.npz`` file.

Pickle-free: the pytree is flattened with string key-paths; structure is
rebuilt from the paths on restore (lists/dicts only — which is all the
framework uses for params and optimizer state).
"""
from __future__ import annotations

import os
import re
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            assert "\x1f" not in str(k)
            out.update(_flatten(v, f"{prefix}{k}\x1f"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}\x1f"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("\x1f")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        if node and all(re.fullmatch(r"#\d+", k) for k in node):
            return [fix(node[f"#{i}"]) for i in range(len(node))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_checkpoint(path: str, tree) -> None:
    flat = _flatten(jax.device_get(tree))
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    # atomic write: tmp + rename
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    try:
        np.savez(tmp, **flat)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)
    finally:
        for t in (tmp, tmp + ".npz"):
            if os.path.exists(t):
                os.remove(t)


def load_checkpoint(path: str):
    with np.load(path) as data:
        return _unflatten({k: data[k] for k in data.files})
