"""AdamW + cosine schedule, hand-rolled (no optax in this environment).

Moments are stored f32 regardless of param dtype (mixed-precision training:
bf16 params / f32 optimizer state is the production-standard layout and is
what the dry-run memory analysis should account for).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * jnp.clip(t, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    step = opt_state["step"] + 1
    lr = cosine_lr(cfg, step)

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_state["nu"], grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias excluded), standard
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "step": step}, {"lr": lr, "grad_norm": gnorm}
