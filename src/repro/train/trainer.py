"""Training loop: CE loss (+ MoE load-balance aux), AdamW, remat policy."""
from __future__ import annotations

import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import scan as scan_mod
from repro.models import transformer
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def loss_fn(params, cfg, tokens, labels, aux_embeds=None, remat_scan=False):
    """Next-token CE over valid labels (label = -1 masks).

    Routes to the scanned stack when params are in scan layout (the remat
    policy then lives on the scan body instead of the whole loss).
    """
    B = tokens.shape[0]
    start = jnp.zeros((B,), jnp.int32)
    if "scan" in params:
        logits, _, aux = scan_mod.forward(
            params, cfg, tokens, start, aux_embeds=aux_embeds, remat=remat_scan
        )
    else:
        logits, _, aux = transformer.forward(
            params, cfg, tokens, start, aux_embeds=aux_embeds
        )
    V = logits.shape[-1]
    valid = labels >= 0
    lab = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    ce = jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1)
    return ce + aux, (ce, aux)


def make_train_step(cfg, opt_cfg: AdamWConfig, remat: bool = True, scan: bool = False):
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``remat=True`` enables activation recomputation — per scan-body for the
    scan layout, whole-loss ``jax.checkpoint`` for the canonical layout.
    """
    if scan:
        lfn = functools.partial(loss_fn, remat_scan=remat)
    elif remat:
        lfn = jax.checkpoint(loss_fn, static_argnums=(1,))
    else:
        lfn = loss_fn

    def train_step(params, opt_state, batch):
        (loss, (ce, aux)), grads = jax.value_and_grad(lfn, has_aux=True)(
            params, cfg, batch["tokens"], batch["labels"], batch.get("aux_embeds")
        )
        params, opt_state, om = adamw_update(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return params, opt_state, metrics

    return train_step


class Trainer:
    def __init__(self, model, opt_cfg: AdamWConfig = AdamWConfig(), remat: bool = False):
        self.model = model
        self.opt_cfg = opt_cfg
        self._step = jax.jit(make_train_step(model.cfg, opt_cfg, remat))

    def init(self, key):
        params = self.model.init_params(key)
        return params, adamw_init(params)

    def fit(self, params, opt_state, data_iter, steps: int, log_every: int = 10,
            log_fn=print):
        history = []
        t0 = time.perf_counter()
        for i in range(steps):
            batch = next(data_iter)
            params, opt_state, m = self._step(params, opt_state, batch)
            if (i + 1) % log_every == 0 or i == 0:
                m = {k: float(v) for k, v in m.items()}
                m["step"] = i + 1
                m["wall_s"] = time.perf_counter() - t0
                history.append(m)
                if log_fn:
                    log_fn(f"step {i+1:5d}  loss {m['loss']:.4f}  ce {m['ce']:.4f} "
                           f"lr {m['lr']:.2e}  gnorm {m['grad_norm']:.2f}")
        return params, opt_state, history
