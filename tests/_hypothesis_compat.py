"""Import shim so property-based test modules stay collectible when
``hypothesis`` is not installed (offline containers).

Use ``from _hypothesis_compat import given, settings, st`` instead of
importing hypothesis directly: with hypothesis present this re-exports
the real API; without it, ``@given``-decorated tests are skipped while
every plain test in the module still collects and runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install .[test])")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy-building call and returns None — the
        decorated tests are skipped, so strategies are never drawn."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _StrategyStub()
