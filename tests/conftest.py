import os
import sys

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the single real CPU device (the 512-device override is
# private to repro.launch.dryrun, which is run as its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
