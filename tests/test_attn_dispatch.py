"""Kernel dispatch for the verification attention hot path.

* ``attend()`` routes exactly the flash-eligible calls (contiguous
  cache-read decode/verify) to ``ops.flash_attend`` — ring buffers,
  sliding windows, cross-attn and train/prefill stay jnp;
* forced-kernel generation (``attn_impl="pallas"``, interpret mode on
  CPU) is bit-identical to the jnp path end to end, for every
  drafter × verifier at T=0 and T>0, including the int8 KV cache;
* the chunk-padding fix: non-KV_CHUNK-aligned long caches take the
  online-softmax path and still match the direct oracle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.kernels import ops as kops
from repro.models import Model
from repro.models import attention as attn_mod
from repro.models.attention import _attend_direct, _mask, _quant_kv, attend
from repro.serving.engine import SpecEngine


# ---------------------------------------------------------------------------
# Routing: exactly the eligible calls reach the kernel
# ---------------------------------------------------------------------------

@pytest.fixture
def spy(monkeypatch):
    calls = []
    real = kops.flash_decode

    def counted(*a, **kw):
        calls.append(kw)
        return real(*a, **kw)

    monkeypatch.setattr(kops, "flash_decode", counted)
    return calls


def _qkv(s=24, t=3, b=2, hkv=2, g=2, dh=8, seed=0):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (b, t, hkv * g, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    qpos = jnp.tile(jnp.arange(s - t, s)[None], (b, 1))
    return q, k, v, qpos


def test_attend_routes_eligible_call_to_kernel(spy):
    q, k, v, qpos = _qkv()
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    kops.set_use_pallas(True)
    try:
        o = attend(q, k, v, qpos, kpos)
    finally:
        kops.set_use_pallas(False)
    assert len(spy) == 1 and spy[0].get("interpret") is True
    o_ref = attend(q, k, v, qpos, kpos, impl="jnp")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_attend_impl_pallas_forces_kernel_without_env(spy):
    """attn_impl="pallas" dispatches the kernel even when the backend
    policy would pick jnp (interpret mode off-TPU)."""
    assert kops.attn_backend() == "jnp"  # CPU container, env var unset
    q, k, v, qpos = _qkv()
    kpos = jnp.arange(k.shape[1], dtype=jnp.int32)
    o = attend(q, k, v, qpos, kpos, impl="pallas")
    assert len(spy) == 1
    o_ref = attend(q, k, v, qpos, kpos, impl="jnp")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_attend_ineligible_calls_stay_jnp(spy):
    """Ring buffers (2-D kpos), sliding windows, non-causal cross-attn and
    the CPU-default auto mode never reach the kernel — even forced."""
    q, k, v, qpos = _qkv()
    kpos1 = jnp.arange(k.shape[1], dtype=jnp.int32)
    kpos2 = jnp.tile(kpos1[None], (q.shape[0], 1))
    kops.set_use_pallas(True)
    try:
        attend(q, k, v, qpos, kpos2)                     # ring layout
        attend(q, k, v, qpos, kpos1, window=8)           # sliding window
        attend(q, k, v, qpos, kpos1, causal=False)       # cross-attn
        attend(q, k, v, qpos, kpos1, impl="jnp")         # forced jnp
    finally:
        kops.set_use_pallas(False)
    attend(q, k, v, qpos, kpos1)                         # auto on CPU
    assert spy == []
    attend(q, k, v, qpos, kpos2, impl="pallas")          # forced but ineligible
    assert spy == []


def test_attend_rejects_unknown_impl():
    q, k, v, qpos = _qkv()
    with pytest.raises(ValueError, match="attn impl"):
        attend(q, k, v, qpos, jnp.arange(k.shape[1]), impl="triton")


def test_flash_attend_cpu_default_is_jnp_oracle():
    """Direct flash_attend calls fall back to the numerically identical
    jnp path on the CPU default backend (w8a8_matmul policy mirror)."""
    q, k, v, qpos = _qkv(seed=1)
    o = kops.flash_attend(q, k, v, qpos)
    o_ref = attend(q, k, v, qpos, jnp.arange(k.shape[1], dtype=jnp.int32),
                   impl="jnp")
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_ref))


# ---------------------------------------------------------------------------
# Chunk padding: non-aligned long caches keep the online-softmax path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("int8", [False, True])
def test_attend_chunked_padding_non_aligned(monkeypatch, int8):
    """S > CHUNK_THRESHOLD with S % KV_CHUNK != 0 must take the chunked
    path (it used to fall back silently to the O(B·H·T·S) direct path)
    and still match the direct-softmax oracle — bf16 and int8 caches."""
    calls = []
    real = attn_mod._attend_chunked

    def counted(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(attn_mod, "_attend_chunked", counted)
    b, t, s, hkv, dh = 1, 3, 4360, 1, 8
    assert s > attn_mod.CHUNK_THRESHOLD and s % attn_mod.KV_CHUNK != 0
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (b, t, hkv, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    ks = vs = None
    if int8:
        k, ks = _quant_kv(k)
        v, vs = _quant_kv(v)
    qpos = jnp.tile(jnp.arange(s - t, s)[None], (b, 1))
    kpos = jnp.arange(s, dtype=jnp.int32)
    o = attend(q, k, v, qpos, kpos, k_scale=ks, v_scale=vs, impl="jnp")
    assert calls == [1]
    valid = _mask(qpos, kpos, None, True)
    o_ref = _attend_direct(q, k, v, valid, ks, vs)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# End-to-end: forced-kernel generation ≡ jnp generation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def base_cfg():
    return get_config("smollm-135m").reduced()


@pytest.fixture(scope="module")
def base_params(base_cfg):
    return Model(base_cfg).init_params(jax.random.PRNGKey(0))


def _generate(cfg, params, drafter, verifier, temperature, kv="bf16"):
    cfg = dataclasses.replace(cfg, kv_cache_dtype=kv)
    scfg = SpecConfig(gamma=3, temperature=temperature, pruned_retention=0.5,
                      tree_branches=(2, 1, 1) if drafter == "ngram-tree"
                      else None)
    rng = np.random.default_rng(13)
    prompt = jnp.asarray(np.tile(rng.integers(0, cfg.vocab_size, 6), 4)
                         [None].repeat(2, 0).astype(np.int32))
    eng = SpecEngine(Model(cfg), scfg, drafter=drafter, verifier=verifier)
    r = eng.generate(params, prompt, 6, key=jax.random.PRNGKey(42))
    return prompt.shape[1], r


@pytest.mark.parametrize("drafter", ["ngram", "vanilla", "pruned",
                                     "ngram-tree"])
@pytest.mark.parametrize("verifier", ["bf16", "w8a8"])
def test_forced_kernel_generation_bit_identical(base_cfg, base_params,
                                                drafter, verifier):
    """attn_impl="pallas" (interpret-mode kernel) generation is
    bit-identical to the jnp path for every drafter × verifier at T=0
    and T>0 — the dispatch is a perf decision, never a semantic one."""
    for temperature in (0.0, 1.0):
        P, r_jnp = _generate(
            dataclasses.replace(base_cfg, attn_impl="jnp"), base_params,
            drafter, verifier, temperature)
        _, r_pal = _generate(
            dataclasses.replace(base_cfg, attn_impl="pallas"), base_params,
            drafter, verifier, temperature)
        np.testing.assert_array_equal(
            np.asarray(r_jnp.tokens[:, : P + 6]),
            np.asarray(r_pal.tokens[:, : P + 6]),
            err_msg=f"T={temperature}")
        assert r_jnp.steps == r_pal.steps


def test_forced_kernel_generation_bit_identical_int8_kv(base_cfg,
                                                        base_params):
    """The quantized cache composes: int8-KV flash verification commits
    the same stream as the int8-KV jnp path."""
    P, r_jnp = _generate(dataclasses.replace(base_cfg, attn_impl="jnp"),
                         base_params, "ngram", "w8a8", 0.0, kv="int8")
    _, r_pal = _generate(dataclasses.replace(base_cfg, attn_impl="pallas"),
                         base_params, "ngram", "w8a8", 0.0, kv="int8")
    np.testing.assert_array_equal(np.asarray(r_jnp.tokens[:, : P + 6]),
                                  np.asarray(r_pal.tokens[:, : P + 6]))
    assert r_jnp.steps == r_pal.steps
