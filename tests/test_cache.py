"""KV/SSM cache correctness: cached incremental decoding must match the
full (uncached) forward, including speculative rollback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models.ssm import apply_ssm, init_ssm, init_ssm_cache, commit_ssm_cache

FAMS = ["smollm-135m", "phi3.5-moe-42b-a6.6b", "mamba2-370m", "zamba2-2.7b",
        "llama-3.2-vision-90b", "whisper-small", "codeqwen1.5-7b"]


def _aux(cfg, B):
    n = cfg.num_image_tokens or cfg.num_audio_frames
    if not n:
        return None
    return jax.random.normal(jax.random.PRNGKey(9), (B, n, cfg.d_model), cfg.dtype)


@pytest.mark.parametrize("arch", FAMS)
def test_cached_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab_size)
    aux = _aux(cfg, B)

    full, _ = m.forward(params, toks, aux_embeds=aux)

    cache = m.init_cache(B, 64)
    cache = m.prefill(params, cache, toks[:, :P - 1], aux_embeds=aux)
    logits, _ = m.decode_step(params, cache, toks[:, -1:],
                              jnp.full((B,), P - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", FAMS)
def test_verify_window_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P, G = 2, 10, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, P + G), 0, cfg.vocab_size)
    aux = _aux(cfg, B)

    full, _ = m.forward(params, toks, aux_embeds=aux)

    cache = m.init_cache(B, 64)
    cache = m.prefill(params, cache, toks[:, :P - 1], aux_embeds=aux)
    window = toks[:, P - 1 : P + G]                # (B, G+1)
    logits, _ = m.verify_step(params, cache, window,
                              jnp.full((B,), P - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, P - 1 : P + G]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-370m", "zamba2-2.7b"])
def test_rollback_equivalence(arch):
    """Committing n<γ tokens then re-verifying must equal a fresh context."""
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P, G = 2, 8, 4
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, P + 8), 0, cfg.vocab_size)

    cache = m.init_cache(B, 64)
    cache = m.prefill(params, cache, toks[:, :P - 1])
    # verify window with garbage tail (simulating rejected drafts)
    garbage = jnp.concatenate(
        [toks[:, P - 1 : P + 1], jnp.zeros((B, G - 1), jnp.int32) + 3], axis=1)
    _, cand = m.verify_step(params, cache, garbage, jnp.full((B,), P - 1, jnp.int32))
    # commit window indices 0,1 (positions P-1, P) -> roll back the rest;
    # cache/state now covers tokens [0, P+1), so the next window starts at
    # position P+1
    cache = m.commit(cand, jnp.full((B,), 1, jnp.int32))

    window2 = toks[:, P + 1 : P + G + 2]
    logits2, _ = m.verify_step(params, cache, window2,
                               jnp.full((B,), P + 1, jnp.int32))

    full, _ = m.forward(params, toks[:, : P + G + 2])
    np.testing.assert_allclose(np.asarray(logits2),
                               np.asarray(full[:, P + 1 : P + G + 2]),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_cache_matches_windowed_attention():
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(), sliding_window=8)
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, P), 0, cfg.vocab_size)
    full, _ = m.forward(params, toks)   # windowed mask, no cache
    cache = m.init_cache(B, 64)
    cache = m.prefill(params, cache, toks[:, :P - 1])
    logits, _ = m.decode_step(params, cache, toks[:, -1:],
                              jnp.full((B,), P - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_ssm_sequential_matches_chunked():
    cfg = get_config("mamba2-370m").reduced()
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    B, T = 2, 24  # > 16 → chunked;  compare against manual sequential
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model), cfg.dtype)
    y_chunk, _ = apply_ssm(p, cfg, u)
    # sequential: run step-by-step through a cache
    cache = init_ssm_cache(cfg, B)
    outs = []
    for t in range(T):
        y, cache = apply_ssm(p, cfg, u[:, t : t + 1], cache=cache)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk, np.float32),
                               np.asarray(y_seq, np.float32), rtol=2e-3, atol=2e-3)


def test_ssm_commit_gathers_correct_state():
    cfg = get_config("mamba2-370m").reduced()
    p = init_ssm(jax.random.PRNGKey(0), cfg)
    B, T = 2, 5
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model), cfg.dtype)
    cache = init_ssm_cache(cfg, B)
    _, cand = apply_ssm(p, cfg, u, cache=cache, collect_states=True)
    n_last = jnp.array([2, 4], jnp.int32)
    committed = commit_ssm_cache(cand, n_last)
    # reference: run only the first n+1 tokens sequentially
    for b, n in enumerate([2, 4]):
        c = init_ssm_cache(cfg, 1)
        _, c = apply_ssm(p, cfg, u[b : b + 1, : n + 1], cache=c)
        np.testing.assert_allclose(np.asarray(committed["state"][b]),
                                   np.asarray(c["state"][0]), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(committed["conv"][b], np.float32),
            np.asarray(c["conv"][0], np.float32), rtol=1e-4, atol=1e-5)
