"""Continuous batching: the scheduler must be an invisible throughput
optimisation — every scheduling path stays token-identical to solo
decoding (losslessness at the serving-loop level), admission never
recompiles the decode step, slots never leak state between occupants,
and the scheduler's conservation laws hold for arbitrary request mixes.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import SpecConfig
from repro.core.prng import request_key
from repro.models import Model
from repro.serving import GenerationRequest, SpecEngine
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


def _requests(cfg, *, seed=3, spec=((5, 6, 11), (4, 9, 22), (3, 7, 33),
                                    (2, 5, 44), (4, 3, 55), (3, 8, 66))):
    """Heterogeneous request mix: (pattern reps, budget, seed) triples."""
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6)
    return [GenerationRequest(np.tile(pat, k), max_new_tokens=n, seed=s)
            for k, n, s in spec]


def _solo(engine, params, req):
    """Serve one request alone (its own single-slot scheduler loop)."""
    alone = GenerationRequest(req.prompt, req.max_new_tokens,
                              temperature=req.temperature, seed=req.seed)
    return engine.generate_requests(params, [alone], batch_slots=1)[0]


# ---------------------------------------------------------------------------
# Solo-vs-scheduled token equality: every drafter x verifier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter,verifier", [
    ("ngram", "bf16"), ("ngram", "w8a8"),
    ("vanilla", "bf16"), ("vanilla", "w8a8"),
    ("pruned", "bf16"), ("pruned", "w8a8"),
])
def test_scheduled_matches_solo_all_combos(model, params, drafter, verifier):
    """6 requests through 2 slots (3x oversubscription, adversarial budget
    mix): every harvested stream must be bit-identical to serving that
    request solo, for every registered drafter x verifier pair."""
    scfg = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5)
    eng = SpecEngine(model, scfg, drafter=drafter, verifier=verifier)
    reqs = _requests(model.cfg)
    results = eng.generate_requests(params, reqs, batch_slots=2)
    assert all(r is not None for r in results)
    for req, res in zip(reqs, results):
        assert res.new_tokens == req.max_new_tokens
        np.testing.assert_array_equal(
            res.tokens, _solo(eng, params, req).tokens)


@pytest.mark.parametrize("drafter,temperature", [
    ("ngram", 1.0),        # deterministic drafts, stochastic verification
    ("pruned", 0.7),       # stochastic drafts (per-row q streams) too
])
def test_scheduled_matches_solo_sampling(model, params, drafter, temperature):
    """At T>0 the per-request seed streams carry the invariance: scheduled
    sampling must consume exactly the bits solo sampling would."""
    scfg = SpecConfig(temperature=temperature, gamma=3, pruned_retention=0.5)
    eng = SpecEngine(model, scfg, drafter=drafter, verifier="bf16")
    reqs = _requests(model.cfg, spec=((5, 6, 1), (4, 9, 2), (3, 7, 3),
                                      (2, 5, 4)))
    results = eng.generate_requests(params, reqs, batch_slots=2)
    for req, res in zip(reqs, results):
        np.testing.assert_array_equal(
            res.tokens, _solo(eng, params, req).tokens)


# ---------------------------------------------------------------------------
# Fixed-shape guarantee: admission never recompiles the decode step
# ---------------------------------------------------------------------------

def test_admission_does_not_retrace_decode_step(model, params):
    """A queue 3x deeper than the slot count forces repeated mid-loop
    admissions; the decode step must compile exactly once for the whole
    run (shape-stable state pytree)."""
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                     verifier="bf16")
    assert eng.step_traces == 0
    results = eng.generate_requests(params, _requests(model.cfg),
                                    batch_slots=2)
    assert all(r.new_tokens == r.request.max_new_tokens for r in results)
    assert eng.step_traces == 1, (
        f"decode step retraced {eng.step_traces - 1} times during admission")


# ---------------------------------------------------------------------------
# Admission-order permutation invariance
# ---------------------------------------------------------------------------

def test_admission_order_permutation_invariance(model, params):
    """Serving the same requests in a different order must produce the
    same per-request tokens (streams depend on the request, not the
    schedule)."""
    eng = SpecEngine(model, SpecConfig(temperature=1.0, gamma=3),
                     verifier="bf16")
    reqs = _requests(model.cfg, spec=((5, 6, 1), (4, 9, 2), (3, 7, 3),
                                      (2, 5, 4), (4, 4, 5)))
    base = eng.generate_requests(params, reqs, batch_slots=2)
    perm = [3, 1, 4, 0, 2]
    permuted = eng.generate_requests(
        params, [reqs[j] for j in perm], batch_slots=2)
    for new_i, old_i in enumerate(perm):
        np.testing.assert_array_equal(permuted[new_i].tokens,
                                      base[old_i].tokens)


# ---------------------------------------------------------------------------
# Slot-reuse isolation
# ---------------------------------------------------------------------------

def test_slot_reuse_does_not_leak_state(model, params):
    """One slot serves three very different requests back-to-back; each
    stream must match its solo run — a recycled row may not carry KV,
    drafter state, PRNG state or token-buffer junk from its predecessor."""
    cfg = model.cfg
    rng = np.random.default_rng(9)
    prompts = [np.tile(rng.integers(0, cfg.vocab_size, 6), k)
               for k in (6, 3, 4)]
    reqs = [GenerationRequest(p, max_new_tokens=n, seed=s)
            for p, n, s in zip(prompts, (8, 6, 7), (1, 2, 3))]
    # pruned drafter: the most stateful path (own KV cache + PRNG stream)
    scfg = SpecConfig(temperature=0.7, gamma=3, pruned_retention=0.5)
    eng = SpecEngine(model, scfg, drafter="pruned", verifier="bf16")
    results = eng.generate_requests(params, reqs, batch_slots=1)
    for req, res in zip(reqs, results):
        np.testing.assert_array_equal(
            res.tokens, _solo(eng, params, req).tokens)


# ---------------------------------------------------------------------------
# Queue-drain stress: 3x oversubscription, adversarial budget mix
# ---------------------------------------------------------------------------

def test_queue_drain_stress(model, params):
    """~3x more requests than slots, budgets from 1 token to 4x the mean:
    the loop must drain, serve every request its exact budget, and keep
    rows independent (spot-checked against solo)."""
    spec = ((5, 1, 1), (2, 16, 2), (4, 2, 3), (3, 12, 4), (2, 1, 5),
            (5, 9, 6), (3, 4, 7), (4, 14, 8), (2, 3, 9))
    reqs = _requests(model.cfg, spec=spec)
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                     verifier="bf16")
    results = eng.generate_requests(params, reqs, batch_slots=3)
    assert len(results) == len(reqs)
    for req, res in zip(reqs, results):
        assert res.new_tokens == req.max_new_tokens
        assert res.steps >= 1
        np.testing.assert_array_equal(res.sequence[: req.prompt.size],
                                      req.prompt)
    # spot-check the extremes (budget 1 and the largest budget)
    for i in (0, 1, 7):
        np.testing.assert_array_equal(
            results[i].tokens, _solo(eng, params, reqs[i]).tokens)


# ---------------------------------------------------------------------------
# Per-request seed streams
# ---------------------------------------------------------------------------

def test_seed_streams_reproducible_and_distinct(model, params):
    """Same seed -> same tokens; different seed -> (almost surely)
    different tokens; and the stream is a pure function of the seed
    (request_key), not of batch composition."""
    cfg = model.cfg
    rng = np.random.default_rng(7)
    prompt = np.tile(rng.integers(0, cfg.vocab_size, 6), 4)
    eng = SpecEngine(model, SpecConfig(temperature=1.0, gamma=3),
                     verifier="bf16")
    mk = lambda seed: GenerationRequest(prompt, max_new_tokens=10, seed=seed)
    a1 = eng.generate_requests(params, [mk(5)], batch_slots=1)[0]
    a2 = eng.generate_requests(params, [mk(5)], batch_slots=1)[0]
    b = eng.generate_requests(params, [mk(6)], batch_slots=1)[0]
    np.testing.assert_array_equal(a1.tokens, a2.tokens)
    assert not np.array_equal(a1.tokens, b.tokens)
    # co-batched with arbitrary neighbours: unchanged
    noise = _requests(cfg, spec=((3, 5, 90), (2, 7, 91)))
    co = eng.generate_requests(params, noise + [mk(5)], batch_slots=2)[-1]
    np.testing.assert_array_equal(co.tokens, a1.tokens)
    # the derivation is batch-shape-free
    assert request_key(5).shape == (2,)


# ---------------------------------------------------------------------------
# Per-request timing (RequestResult queue_s / service_s)
# ---------------------------------------------------------------------------

def test_request_result_timing_fields(model, params):
    """queue_s / service_s are per-request: first-wave requests have ~zero
    queueing, overflow requests wait strictly longer than zero, and
    wall_s is their sum."""
    reqs = _requests(model.cfg, spec=((4, 5, 1), (3, 5, 2), (2, 5, 3),
                                      (4, 5, 4)))
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                     verifier="bf16")
    results = eng.generate_requests(params, reqs, batch_slots=2)
    for res in results:
        assert res.queue_s >= 0.0 and res.service_s > 0.0
        assert res.wall_s == pytest.approx(res.queue_s + res.service_s)
        assert res.steps >= 1
        assert res.accept_len >= 1.0          # >= 1 commit per active step
    # requests 2 and 3 only got a slot after a first-wave row finished:
    # their queueing time includes at least one decode step
    first_wave_q = max(results[0].queue_s, results[1].queue_s)
    for res in results[2:]:
        assert res.queue_s > first_wave_q
    # sequential temperature groups share the call-level arrival clock: a
    # request in the second group queues through the whole first group
    mixed = [GenerationRequest(reqs[0].prompt, 4, temperature=0.0, seed=1),
             GenerationRequest(reqs[1].prompt, 4, temperature=1.0, seed=2)]
    mr = eng.generate_requests(params, mixed, batch_slots=1)
    assert mr[1].queue_s > mr[0].service_s


# ---------------------------------------------------------------------------
# Scheduler conservation laws (model-free: a synthetic decode loop)
# ---------------------------------------------------------------------------

def _fake_loop(prompt_lens, budgets, batch_slots, accept_seed=0,
               priorities=None):
    """Drive Scheduler with a synthetic numpy 'decode step' that commits
    1..3 tokens per active row per step.  Returns (scheduler, results)."""
    priorities = priorities if priorities is not None else [0] * len(budgets)
    reqs = [GenerationRequest(np.arange(2 + p) % 7, max_new_tokens=b,
                              seed=i, priority=pr)
            for i, (p, b, pr) in enumerate(zip(prompt_lens, budgets,
                                               priorities))]
    buf = max(r.prompt.size + r.max_new_tokens for r in reqs) + 4
    state = {
        "tokens": np.zeros((batch_slots, buf), np.int32),
        "length": np.zeros((batch_slots,), np.int32),
        "target": np.zeros((batch_slots,), np.int32),
        "stats": {"commits": np.zeros((batch_slots,), np.int32),
                  "row_steps": np.zeros((batch_slots,), np.int32)},
    }
    rng = np.random.default_rng(accept_seed)

    def admit(st, slot, i):
        r = reqs[i]
        st["tokens"][slot] = 0
        st["tokens"][slot, : r.prompt.size] = r.prompt
        st["length"][slot] = r.prompt.size
        st["target"][slot] = r.prompt.size + r.max_new_tokens
        st["stats"]["commits"][slot] = 0
        st["stats"]["row_steps"][slot] = 0
        return st

    def step(st):
        for s in range(batch_slots):
            if st["length"][s] < st["target"][s]:
                n = min(int(rng.integers(1, 4)),
                        int(st["target"][s] - st["length"][s]))
                pos = int(st["length"][s])
                st["tokens"][s, pos: pos + n] = 1 + (s % 5)
                st["length"][s] += n
                st["stats"]["commits"][s] += n
                st["stats"]["row_steps"][s] += 1
        return st

    sched = Scheduler(reqs, batch_slots)
    _, results = sched.run(state, admit=admit, step=step)
    return sched, results


def _assert_conservation(sched, results, n_requests):
    # every request served exactly once
    served = sorted(ev.request_index for ev in sched.events)
    assert served == list(range(n_requests))
    assert all(r is not None for r in results)
    # exact budgets
    for r in results:
        assert r.new_tokens == r.request.max_new_tokens
        assert r.steps >= 1
    # no slot serves two requests at once: occupancy intervals disjoint
    by_slot = {}
    for ev in sched.events:
        assert ev.admit_step < ev.harvest_step
        by_slot.setdefault(ev.slot, []).append(ev)
    for evs in by_slot.values():
        evs.sort(key=lambda e: e.admit_step)
        for a, b in zip(evs, evs[1:]):
            assert a.harvest_step <= b.admit_step


def test_scheduler_conservation_fixed_mix():
    sched, results = _fake_loop(
        prompt_lens=[4, 1, 9, 2, 6, 3, 5, 0, 7],
        budgets=[3, 1, 12, 5, 2, 9, 1, 7, 4], batch_slots=3)
    _assert_conservation(sched, results, 9)
    assert sched.steps > 0


def test_scheduler_rejects_bad_slot_count(model, params):
    with pytest.raises(ValueError, match="batch_slots"):
        Scheduler([], 0)
    # and the engine propagates an explicit bad count instead of
    # silently falling back to the default
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                     verifier="bf16")
    with pytest.raises(ValueError, match="batch_slots"):
        eng.generate_requests(params, _requests(model.cfg), batch_slots=0)


@given(
    mix=st.lists(
        st.tuples(st.integers(min_value=0, max_value=12),    # extra prompt len
                  st.integers(min_value=1, max_value=20)),   # budget
        min_size=1, max_size=24),
    batch_slots=st.integers(min_value=1, max_value=6),
    accept_seed=st.integers(min_value=0, max_value=2 ** 16),
)
@settings(max_examples=40, deadline=None)
def test_scheduler_conservation_property(mix, batch_slots, accept_seed):
    """Property: for ANY request mix (prompt lengths, budgets) and slot
    count, the scheduler serves every request exactly once, delivers the
    exact budget, and never double-books a slot."""
    prompt_lens = [p for p, _ in mix]
    budgets = [b for _, b in mix]
    sched, results = _fake_loop(prompt_lens, budgets, batch_slots,
                                accept_seed=accept_seed)
    _assert_conservation(sched, results, len(mix))


# ---------------------------------------------------------------------------
# Priority-aware admission
# ---------------------------------------------------------------------------

def test_scheduler_priority_admission():
    """Pending requests pop by (priority, arrival): through one slot,
    low-priority-value requests are admitted first, FIFO inside a class,
    and conservation still holds."""
    priorities = [2, 0, 1, 0, 2, 1]
    sched, results = _fake_loop([3] * 6, [4] * 6, batch_slots=1,
                                priorities=priorities)
    _assert_conservation(sched, results, 6)
    order = [ev.request_index for ev in
             sorted(sched.events, key=lambda e: e.admit_step)]
    assert order == [1, 3, 2, 5, 0, 4]
    # queueing time is monotone in admission order
    waits = [results[i].queue_s for i in order]
    assert waits == sorted(waits)


def test_scheduler_default_priority_is_fifo():
    """All-default priorities keep the pre-priority FIFO admission."""
    sched, _ = _fake_loop([2, 4, 1, 3, 5], [3, 2, 4, 1, 2], batch_slots=2)
    first_wave = sorted(ev.request_index for ev in sched.events
                       if ev.admit_step == 0)
    assert first_wave == [0, 1]


def test_priority_never_changes_tokens(model, params):
    """Priority reorders admission only: the harvested streams stay
    bit-identical to the all-default-priority run (per-request seed
    streams make tokens independent of admission order)."""
    scfg = SpecConfig(temperature=0.0, gamma=3)
    eng = SpecEngine(model, scfg, verifier="bf16")
    base = _requests(model.cfg)
    flipped = [GenerationRequest(r.prompt, r.max_new_tokens,
                                 temperature=r.temperature, seed=r.seed,
                                 priority=-i)
               for i, r in enumerate(base)]
    r0 = eng.generate_requests(params, base, batch_slots=2)
    r1 = eng.generate_requests(params, flipped, batch_slots=2)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a.tokens, b.tokens)
