"""Pluggable decoding API: registries, golden equivalence with the legacy
step builders, losslessness across all registered drafters, verifier-driven
quantization, and request-level serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (
    BF16Verifier,
    DraftProposal,
    Drafter,
    NgramDrafter,
    PrunedDrafter,
    SpecConfig,
    VanillaDrafter,
    W8A8Verifier,
    available_drafters,
    available_verifiers,
    get_drafter,
    get_verifier,
    init_state,
    make_decode_step,
)
from repro.core.drafting import draft_tokens
from repro.core.verification import verify
from repro.models import Model
from repro.quant import quantize_params
from repro.serving import GenerationRequest, SpecEngine


def _model():
    cfg = get_config("smollm-135m").reduced()
    return Model(cfg)


def _prompt(cfg, B=2, reps=5, seed=0):
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6)
    return jnp.array(np.tile(pat, reps)[None, :].repeat(B, 0).astype(np.int32))


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"ngram", "vanilla", "pruned"} <= set(available_drafters())
    assert {"bf16", "w8a8", "w4a8"} <= set(available_verifiers())


def test_registry_roundtrip_all():
    scfg = SpecConfig(gamma=3, k_min=1, k_max=2, pruned_retention=0.5)
    for name in available_drafters():
        d = get_drafter(name, scfg)
        assert isinstance(d, Drafter) and d.name == name
        if name != "vanilla":
            assert d.gamma == scfg.gamma
        assert get_drafter(d) is d                  # instance passthrough
    for name in available_verifiers():
        v = get_verifier(name, scfg)
        assert v.name == name
        assert get_verifier(v) is v


def test_registry_lookup_types():
    scfg = SpecConfig(gamma=4)
    assert isinstance(get_drafter("ngram", scfg), NgramDrafter)
    assert isinstance(get_drafter("vanilla", scfg), VanillaDrafter)
    d = get_drafter("pruned", dataclasses.replace(scfg, pruned_retention=0.5))
    assert isinstance(d, PrunedDrafter) and d.retention == 0.5
    assert isinstance(get_verifier("bf16"), BF16Verifier)
    assert isinstance(get_verifier("w8a8"), W8A8Verifier)


def test_unknown_names_raise():
    with pytest.raises(ValueError, match="unknown drafter"):
        get_drafter("treebeard")
    with pytest.raises(ValueError, match="unknown verifier"):
        get_verifier("fp4")


# ---------------------------------------------------------------------------
# Golden equivalence vs the legacy (seed-era) serve step
# ---------------------------------------------------------------------------

def _legacy_commit_tokens(tokens, length, drafts, next_token, n_accept):
    """Frozen copy of the seed-era ``_commit_tokens``."""
    B, S = tokens.shape
    gamma = drafts.shape[1]
    i = jnp.arange(gamma + 1)[None, :]
    vals = jnp.concatenate([drafts, next_token[:, None]], axis=1)
    vals = jnp.where(i == n_accept[:, None], next_token[:, None], vals)
    pos = jnp.clip(length[:, None] + i, 0, S - 1)
    keep = i <= n_accept[:, None]
    cur = jnp.take_along_axis(tokens, pos, axis=1)
    vals = jnp.where(keep, vals, cur)
    bidx = jnp.arange(B)[:, None]
    return tokens.at[bidx, pos].set(vals)


def _legacy_make_serve_step(model, scfg):
    """Frozen copy of the seed-era ``make_serve_step`` (pre-protocols)."""
    gamma = scfg.gamma

    def serve_step(params, state):
        tokens, length = state["tokens"], state["length"]
        drafts = draft_tokens(tokens, length, gamma=gamma,
                              k_min=scfg.k_min, k_max=scfg.k_max)
        last = jnp.take_along_axis(
            tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        window = jnp.concatenate([last, drafts], axis=1)
        start = jnp.maximum(length - 1, 0)

        logits, cand = model.verify_step(params, state["cache"], window, start)
        key, sub = jax.random.split(state["key"])
        res = verify(logits, drafts, scfg.temperature, sub)

        cache = model.commit(cand, res.n_accept)
        tokens = _legacy_commit_tokens(tokens, length, drafts,
                                       res.next_token, res.n_accept)
        return {
            "tokens": tokens,
            "length": length + res.n_commit,
            "cache": cache,
            "key": key,
            "stats": {
                "commits": state["stats"]["commits"] + res.n_commit,
                "steps": state["stats"]["steps"] + 1,
            },
        }

    return serve_step


@pytest.mark.parametrize("temperature", [0.0, 1.0])
def test_golden_equivalence_ngram_vs_legacy(temperature):
    """make_decode_step(ngram, bf16) reproduces the seed-era serve step
    bit-exactly: same tokens, lengths, commit counts, every step."""
    m = _model()
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(cfg)
    B, P = prompt.shape
    scfg = SpecConfig(gamma=4, temperature=temperature)
    buf = P + 40

    def mk_state(with_drafter_slot):
        key = jax.random.PRNGKey(42)
        if with_drafter_slot:
            state = init_state(m, B, buf, key)
        else:   # seed-era state layout
            state = {
                "tokens": jnp.zeros((B, buf), jnp.int32),
                "length": jnp.zeros((B,), jnp.int32),
                "cache": m.init_cache(B, buf),
                "key": key,
                "stats": {"commits": jnp.zeros((B,), jnp.int32),
                          "steps": jnp.zeros((), jnp.int32)},
            }
        state["tokens"] = state["tokens"].at[:, :P].set(prompt)
        state["length"] = jnp.full((B,), P, jnp.int32)
        state["cache"] = m.prefill(params, state["cache"], prompt[:, :-1])
        return state

    new_step = jax.jit(make_decode_step(m, "ngram", "bf16", scfg))
    old_step = jax.jit(_legacy_make_serve_step(m, scfg))
    s_new, s_old = mk_state(True), mk_state(False)
    for _ in range(6):
        s_new = new_step(params, s_new)
        s_old = old_step(params, s_old)
        assert bool(jnp.all(s_new["tokens"] == s_old["tokens"]))
        assert bool(jnp.all(s_new["length"] == s_old["length"]))
        assert bool(jnp.all(
            s_new["stats"]["commits"] == s_old["stats"]["commits"]))


# ---------------------------------------------------------------------------
# Losslessness across every registered drafter
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter", sorted(available_drafters()))
def test_all_drafters_lossless_greedy(drafter):
    """At T=0 every registered drafter commits exactly the autoregressive
    stream of the same verifier — the losslessness guarantee is drafting-
    strategy independent."""
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(m.cfg)
    N, P = 10, prompt.shape[1]
    scfg = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5)
    rv = SpecEngine(m, scfg, drafter="vanilla", verifier="bf16").generate(
        params, prompt, N)
    rd = SpecEngine(m, scfg, drafter=drafter, verifier="bf16").generate(
        params, prompt, N)
    assert bool(jnp.all(rv.tokens[:, : P + N] == rd.tokens[:, : P + N]))
    assert rd.mean_accept_len >= 1.0


def test_legacy_mode_shim_matches_new_api():
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(m.cfg)
    scfg = SpecConfig(temperature=0.0, gamma=4)
    r_old = SpecEngine(m, scfg, mode="spec").generate(params, prompt, 10)
    r_new = SpecEngine(m, scfg, drafter="ngram", verifier="bf16").generate(
        params, prompt, 10)
    assert bool(jnp.all(r_old.tokens == r_new.tokens))
    assert r_old.steps == r_new.steps


# ---------------------------------------------------------------------------
# Verifier-driven quantization (SpecConfig.verifier is live)
# ---------------------------------------------------------------------------

def test_w8a8_verifier_field_drives_quantization():
    """``verifier="w8a8"`` alone must produce quantized verification:
    identical stream to manually quantizing and serving BF16-passthrough."""
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(m.cfg)
    N, P = 10, prompt.shape[1]
    scfg = SpecConfig(temperature=0.0, gamma=4, verifier="w8a8")

    auto = SpecEngine(m, scfg).generate(params, prompt, N)
    qparams = quantize_params(params, None)
    manual = SpecEngine(m, scfg, drafter="ngram", verifier="bf16").generate(
        qparams, prompt, N)
    assert bool(jnp.all(auto.tokens[:, : P + N] == manual.tokens[:, : P + N]))

    # and it differs from unquantized BF16 params at least in param bytes:
    prepared = SpecEngine(m, scfg).prepare_params(params)
    int8_leaves = [x for x in jax.tree.leaves(prepared)
                   if hasattr(x, "dtype") and x.dtype == jnp.int8]
    assert int8_leaves, "w8a8 prepare produced no int8 weights"


def test_prepare_params_idempotent():
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    eng = SpecEngine(m, SpecConfig(verifier="w8a8"))
    q1 = eng.prepare_params(params)
    q2 = eng.prepare_params(q1)
    assert jax.tree.structure(q1) == jax.tree.structure(q2)


# ---------------------------------------------------------------------------
# Request-level serving
# ---------------------------------------------------------------------------

def test_generate_requests_heterogeneous_matches_solo():
    """Heterogeneous prompt lengths + budgets + seeds in one batched loop:
    each request's stream equals its solo single-row run (T=0)."""
    m = _model()
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 6)
    scfg = SpecConfig(temperature=0.0, gamma=4)
    requests = [
        GenerationRequest(np.tile(pat, 5), max_new_tokens=6, seed=1),
        GenerationRequest(np.tile(pat, 4), max_new_tokens=14, seed=2),
        GenerationRequest(np.tile(pat, 3), max_new_tokens=9, seed=3),
    ]
    eng = SpecEngine(m, scfg, verifier="bf16")
    results = eng.generate_requests(params, requests)
    assert len(results) == len(requests)
    for req, res in zip(requests, results):
        assert res.new_tokens == req.max_new_tokens      # early-exit masking
        solo = SpecEngine(m, scfg, verifier="bf16").generate(
            params, jnp.asarray(req.prompt)[None], req.max_new_tokens)
        solo_new = np.asarray(solo.tokens)[
            0, req.prompt.size: req.prompt.size + req.max_new_tokens]
        np.testing.assert_array_equal(res.tokens, solo_new)
        assert res.accept_len >= 0.0
        np.testing.assert_array_equal(res.sequence[: req.prompt.size],
                                      req.prompt)


def test_generate_requests_temperature_groups():
    m = _model()
    cfg = m.cfg
    params = m.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    pat = rng.integers(0, cfg.vocab_size, 6)
    requests = [
        GenerationRequest(np.tile(pat, 4), max_new_tokens=5, temperature=0.0),
        GenerationRequest(np.tile(pat, 4), max_new_tokens=7, temperature=1.0,
                          seed=9),
    ]
    eng = SpecEngine(m, SpecConfig(gamma=3), verifier="bf16")
    results = eng.generate_requests(params, requests)
    for req, res in zip(requests, results):
        assert res.new_tokens == req.max_new_tokens
        toks = np.asarray(res.tokens)
        assert toks.min() >= 0 and toks.max() < cfg.vocab_size


def test_generate_requests_validation():
    with pytest.raises(ValueError, match="prompt"):
        GenerationRequest(np.array([1]), max_new_tokens=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        GenerationRequest(np.array([1, 2, 3]), max_new_tokens=0)
    m = _model()
    assert SpecEngine(m, SpecConfig(), verifier="bf16").generate_requests(
        m.init_params(jax.random.PRNGKey(0)), []) == []


# ---------------------------------------------------------------------------
# Custom (unregistered) drafter plugs straight in
# ---------------------------------------------------------------------------

class _LastTokenDrafter(Drafter):
    """Toy custom strategy: always propose the last committed token."""

    name = "last-token"

    def __init__(self, gamma):
        self.gamma = gamma

    def propose(self, model, params, tokens, length, dstate, key):
        last = jnp.take_along_axis(
            tokens, jnp.maximum(length - 1, 0)[:, None], axis=1)
        drafts = jnp.repeat(last, self.gamma, axis=1)
        return DraftProposal(tokens=drafts, probs=None), dstate, key


def test_custom_drafter_instance_lossless():
    m = _model()
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(m.cfg)
    N, P = 8, prompt.shape[1]
    scfg = SpecConfig(temperature=0.0, gamma=3)
    rv = SpecEngine(m, scfg, drafter="vanilla", verifier="bf16").generate(
        params, prompt, N)
    rc = SpecEngine(m, scfg, drafter=_LastTokenDrafter(3),
                    verifier="bf16").generate(params, prompt, N)
    assert bool(jnp.all(rv.tokens[:, : P + N] == rc.tokens[:, : P + N]))
