"""Prompt-lookup drafter properties."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.drafting import draft_tokens


def _np_reference(tokens, length, gamma, k_min, k_max):
    """Straightforward numpy PLD: longest k wins, most recent match."""
    out = []
    for b in range(tokens.shape[0]):
        row, l = tokens[b], int(length[b])
        best = None
        for k in range(k_min, k_max + 1):
            if l < 2 * k:
                continue
            tail = row[l - k : l].tolist()
            for j in range(l - k - 1, -1, -1):  # most recent first
                if row[j : j + k].tolist() == tail:
                    best = j + k
                    break
        if best is None:
            out.append([row[l - 1]] * gamma)
        else:
            d = []
            for i in range(gamma):
                idx = best + i
                d.append(row[idx] if idx < l else row[l - 1])
            out.append(d)
    return np.array(out, np.int32)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    vocab=st.integers(2, 8),
    length=st.integers(8, 40),
    gamma=st.integers(1, 6),
)
def test_draft_matches_numpy_reference(seed, vocab, length, gamma):
    rng = np.random.default_rng(seed)
    S = 48
    toks = rng.integers(0, vocab, (2, S)).astype(np.int32)
    lens = np.array([length, max(2, length - 3)], np.int32)
    got = draft_tokens(jnp.array(toks), jnp.array(lens), gamma=gamma,
                       k_min=1, k_max=4)
    want = _np_reference(toks, lens, gamma, 1, 4)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_perfect_repetition_drafts_continuation():
    pat = np.array([5, 9, 2, 7], np.int32)
    row = np.tile(pat, 8)
    toks = jnp.array(row[None, :])
    lens = jnp.array([row.size], jnp.int32)
    drafts = draft_tokens(toks, lens, gamma=4, k_min=1, k_max=4)
    # the continuation of the repeating pattern
    np.testing.assert_array_equal(np.asarray(drafts)[0], pat)


def test_no_match_falls_back_to_last_token():
    toks = jnp.array(np.arange(32, dtype=np.int32)[None, :])  # all distinct
    lens = jnp.array([32], jnp.int32)
    drafts = draft_tokens(toks, lens, gamma=3, k_min=1, k_max=4)
    np.testing.assert_array_equal(np.asarray(drafts)[0], [31, 31, 31])
