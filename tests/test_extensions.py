"""Beyond-paper extensions: int8 KV cache and shard_map expert parallelism."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.models import Model
from repro.serving.engine import SpecEngine

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_int8_kv_decode_close_to_bf16():
    base = get_config("smollm-135m").reduced()
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")
    m, m8 = Model(base), Model(cfg8)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, base.vocab_size)
    full, _ = m.forward(params, toks)
    cache = m8.init_cache(B, 64)
    cache = m8.prefill(params, cache, toks[:, :P - 1])
    logits, _ = m8.decode_step(params, cache, toks[:, -1:],
                               jnp.full((B,), P - 1, jnp.int32))
    rel = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))
                / jnp.max(jnp.abs(full[:, -1])))
    assert rel < 0.05, rel


def test_int8_kv_scale_folding_exact():
    """Folding the per-(token,head) scales into scores/probs must equal
    explicit dequantization."""
    from repro.models.attention import _quant_kv, attend

    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, T, S, H, dh = 2, 3, 16, 4, 8
    q = jax.random.normal(kq, (B, T, H, dh))
    k = jax.random.normal(kk, (B, S, H, dh))
    v = jax.random.normal(kv, (B, S, H, dh))
    qpos = jnp.tile(jnp.arange(8, 8 + T)[None], (B, 1))
    kpos = jnp.arange(S, dtype=jnp.int32)

    k8, ks = _quant_kv(k)
    v8, vs = _quant_kv(v)
    o_folded = attend(q, k8, v8, qpos, kpos, k_scale=ks, v_scale=vs)
    o_deq = attend(q, k8.astype(jnp.float32) * ks[..., None],
                   v8.astype(jnp.float32) * vs[..., None], qpos, kpos)
    np.testing.assert_allclose(np.asarray(o_folded), np.asarray(o_deq),
                               rtol=1e-5, atol=1e-5)


def test_int8_kv_spec_lossless():
    cfg8 = dataclasses.replace(get_config("smollm-135m").reduced(),
                               kv_cache_dtype="int8")
    m8 = Model(cfg8)
    params = m8.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.array(np.tile(rng.integers(0, cfg8.vocab_size, 6), 5)
                       [None].repeat(2, 0).astype(np.int32))
    scfg = SpecConfig(gamma=4)
    rv = SpecEngine(m8, scfg, mode="vanilla").generate(params, prompt, 12)
    rs = SpecEngine(m8, scfg, mode="spec").generate(params, prompt, 12)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, :P + 12] == rs.tokens[:, :P + 12]))


def test_shard_map_moe_matches_gspmd():
    """shard_map expert-parallel path == auto-partitioned path (2×2 mesh,
    subprocess for device-count isolation)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_mod
from repro.models.moe import init_moe, apply_moe

cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), cfg.dtype)
y0, _ = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, x)
mesh = make_mesh((2, 2), ("data", "model"))
for fsdp in (False, True):
    moe_mod.set_shard_map(mesh, ("data",), fsdp)
    with mesh:
        y1, _ = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, x)
    moe_mod.set_shard_map(None, (), False)
    d = float(jnp.max(jnp.abs(y0 - y1)))
    assert d < 1e-4, (fsdp, d)
print("OK")
""" % (os.path.join(ROOT, "src"),)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]
