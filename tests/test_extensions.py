"""Beyond-paper extensions: int8 KV cache and shard_map expert parallelism."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.models import Model
from repro.serving.engine import SpecEngine

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_int8_kv_decode_close_to_bf16():
    base = get_config("smollm-135m").reduced()
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")
    m, m8 = Model(base), Model(cfg8)
    params = m.init_params(jax.random.PRNGKey(0))
    B, P = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, base.vocab_size)
    full, _ = m.forward(params, toks)
    cache = m8.init_cache(B, 64)
    cache = m8.prefill(params, cache, toks[:, :P - 1])
    logits, _ = m8.decode_step(params, cache, toks[:, -1:],
                               jnp.full((B,), P - 1, jnp.int32))
    rel = float(jnp.max(jnp.abs(logits[:, 0] - full[:, -1]))
                / jnp.max(jnp.abs(full[:, -1])))
    assert rel < 0.05, rel


def test_int8_kv_scale_folding_exact():
    """Folding the per-(token,head) scales into scores/probs must equal
    explicit dequantization."""
    from repro.models.attention import _quant_kv, attend

    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    B, T, S, H, dh = 2, 3, 16, 4, 8
    q = jax.random.normal(kq, (B, T, H, dh))
    k = jax.random.normal(kk, (B, S, H, dh))
    v = jax.random.normal(kv, (B, S, H, dh))
    qpos = jnp.tile(jnp.arange(8, 8 + T)[None], (B, 1))
    kpos = jnp.arange(S, dtype=jnp.int32)

    k8, ks = _quant_kv(k)
    v8, vs = _quant_kv(v)
    o_folded = attend(q, k8, v8, qpos, kpos, k_scale=ks, v_scale=vs)
    o_deq = attend(q, k8.astype(jnp.float32) * ks[..., None],
                   v8.astype(jnp.float32) * vs[..., None], qpos, kpos)
    np.testing.assert_allclose(np.asarray(o_folded), np.asarray(o_deq),
                               rtol=1e-5, atol=1e-5)


def test_int8_kv_spec_lossless():
    cfg8 = dataclasses.replace(get_config("smollm-135m").reduced(),
                               kv_cache_dtype="int8")
    m8 = Model(cfg8)
    params = m8.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.array(np.tile(rng.integers(0, cfg8.vocab_size, 6), 5)
                       [None].repeat(2, 0).astype(np.int32))
    scfg = SpecConfig(gamma=4)
    rv = SpecEngine(m8, scfg, mode="vanilla").generate(params, prompt, 12)
    rs = SpecEngine(m8, scfg, mode="spec").generate(params, prompt, 12)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, :P + 12] == rs.tokens[:, :P + 12]))


def test_degenerate_tree_bit_equals_chain_int8_kv():
    """Tree speculation composes with the int8 KV cache: any chain drafter
    through the tree route (depth positions, ancestor mask, path-compacting
    commit — including the k_scale/v_scale rows) reproduces the chain
    route bit-for-bit at T=0 and T>0."""
    from repro.core import ChainTreeAdapter, get_drafter
    from repro.serving import GenerationRequest

    cfg8 = dataclasses.replace(get_config("smollm-135m").reduced(),
                               kv_cache_dtype="int8")
    m8 = Model(cfg8)
    params = m8.init_params(jax.random.PRNGKey(0))
    scfg = SpecConfig(gamma=3, temperature=0.0)
    rng = np.random.default_rng(21)
    pat = rng.integers(0, cfg8.vocab_size, 6)
    requests = [
        GenerationRequest(np.tile(pat, 4), max_new_tokens=8, seed=5),
        GenerationRequest(np.tile(pat, 5), max_new_tokens=10, seed=6,
                          temperature=1.0),
    ]
    chain_eng = SpecEngine(m8, scfg, drafter="ngram", verifier="w8a8")
    tree_eng = SpecEngine(
        m8, scfg, drafter=ChainTreeAdapter(get_drafter("ngram", scfg)),
        verifier="w8a8")
    r_chain = chain_eng.generate_requests(params, requests, batch_slots=2)
    r_tree = tree_eng.generate_requests(params, requests, batch_slots=2)
    for rc, rt in zip(r_chain, r_tree):
        np.testing.assert_array_equal(rc.tokens, rt.tokens)
        assert rc.steps == rt.steps and rc.accept_len == rt.accept_len


def test_wide_tree_lossless_greedy_int8_kv():
    """Whatever a wide template proposes over an int8 cache, T=0
    verification commits exactly the int8 autoregressive stream."""
    cfg8 = dataclasses.replace(get_config("smollm-135m").reduced(),
                               kv_cache_dtype="int8")
    m8 = Model(cfg8)
    params = m8.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(np.tile(rng.integers(0, cfg8.vocab_size, 6), 5)
                         [None].repeat(2, 0).astype(np.int32))
    P = prompt.shape[1]
    van = SpecEngine(m8, SpecConfig(gamma=0, temperature=0.0),
                     drafter="vanilla", verifier="bf16").generate(
        params, prompt, 10)
    tree = SpecEngine(m8, SpecConfig(temperature=0.0,
                                     tree_branches=(2, 2)),
                      drafter="ngram-tree", verifier="bf16").generate(
        params, prompt, 10)
    assert bool(jnp.all(van.tokens[:, : P + 10] == tree.tokens[:, : P + 10]))


def test_tree_commit_compacts_scale_rows_int8():
    """commit_cache_tree must move the accepted path's k_scale/v_scale
    rows together with their int8 K/V rows (and leave rejected-depth
    rows untouched)."""
    from repro.models.transformer import _compact_attn_rows

    B, S, H, dh, D = 2, 16, 2, 4, 3
    rng = np.random.default_rng(0)
    lcache = {
        "k": jnp.asarray(rng.integers(-127, 127, (B, S, H, dh)), jnp.int8),
        "v": jnp.asarray(rng.integers(-127, 127, (B, S, H, dh)), jnp.int8),
        "k_scale": jnp.asarray(rng.random((B, S, H)), jnp.float32),
        "v_scale": jnp.asarray(rng.random((B, S, H)), jnp.float32),
    }
    # accepted path: root=0, then packed node ordinals per depth
    path_nodes = jnp.asarray([[0, 2, 5, 6], [0, 1, 3, 7]], jnp.int32)
    start = jnp.asarray([3, 8], jnp.int32)
    n_accept = jnp.asarray([2, 3], jnp.int32)
    new = _compact_attn_rows(lcache, start, path_nodes, n_accept)
    old = {k: np.asarray(v) for k, v in lcache.items()}
    for b in range(B):
        for d in range(1, D + 1):
            dst = int(start[b]) + d
            src = int(start[b]) + int(path_nodes[b, d])
            for name in ("k", "v", "k_scale", "v_scale"):
                expect = old[name][b, src] if d <= int(n_accept[b]) \
                    else old[name][b, dst]
                np.testing.assert_array_equal(
                    np.asarray(new[name])[b, dst], expect,
                    err_msg=f"{name} b={b} d={d}")


def test_int8_ring_buffer_matches_masked_recompute():
    """Sliding-window decode through the int8 ring buffer (wrapping it
    several times) ≡ a from-scratch masked recompute over the same
    quantized K/V rows."""
    from repro.models.attention import (
        RING_PAD, attend, init_attn_cache, write_cache)

    class _Cfg:
        num_kv_heads = 2
        head_dim = 8
        kv_cache_dtype = "int8"
        dtype = jnp.float32

    B, W, Hq = 2, 8, 4
    T_total = W + RING_PAD + 32   # > ring size W + RING_PAD ⇒ wraps
    cfg = _Cfg()
    cache = init_attn_cache(cfg, B, max_len=64, window=W)
    R = cache["k"].shape[1]
    assert T_total > R  # the ring must actually wrap
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    qs = jax.random.normal(kq, (B, T_total, Hq, cfg.head_dim))
    ks = jax.random.normal(kk, (B, T_total, cfg.num_kv_heads, cfg.head_dim))
    vs = jax.random.normal(kv, (B, T_total, cfg.num_kv_heads, cfg.head_dim))
    from repro.models.attention import _quant_kv
    k8f, ksf = _quant_kv(ks)
    v8f, vsf = _quant_kv(vs)

    for t in range(T_total):
        qpos = jnp.full((B, 1), t, jnp.int32)
        cache = write_cache(cache, ks[:, t:t + 1], vs[:, t:t + 1], qpos, W)
        o = attend(qs[:, t:t + 1], cache["k"], cache["v"], qpos,
                   cache["kpos"], window=W,
                   k_scale=cache["k_scale"], v_scale=cache["v_scale"])
        if t % 17 != 0 and t != T_total - 1:
            continue  # spot-check (full check at every wrap boundary cost)
        o_ref = attend(qs[:, t:t + 1], k8f[:, :t + 1], v8f[:, :t + 1],
                       qpos, jnp.arange(t + 1, dtype=jnp.int32), window=W,
                       k_scale=ksf[:, :t + 1], v_scale=vsf[:, :t + 1])
        np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                                   rtol=1e-5, atol=1e-5, err_msg=f"t={t}")


def test_int8_kv_sliding_window_spec_lossless():
    """Speculative serving over an int8 ring buffer commits exactly the
    int8 autoregressive stream (model-level end-to-end)."""
    cfg8 = dataclasses.replace(get_config("smollm-135m").reduced(),
                               kv_cache_dtype="int8", sliding_window=16)
    m8 = Model(cfg8)
    params = m8.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.array(np.tile(rng.integers(0, cfg8.vocab_size, 6), 5)
                       [None].repeat(2, 0).astype(np.int32))
    scfg = SpecConfig(gamma=4)
    rv = SpecEngine(m8, scfg, mode="vanilla").generate(params, prompt, 12)
    rs = SpecEngine(m8, scfg, mode="spec").generate(params, prompt, 12)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, :P + 12] == rs.tokens[:, :P + 12]))


def test_shard_map_moe_matches_gspmd():
    """shard_map expert-parallel path == auto-partitioned path (2×2 mesh,
    subprocess for device-count isolation)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_mod
from repro.models.moe import init_moe, apply_moe

cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), cfg.dtype)
y0, _ = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, x)
mesh = make_mesh((2, 2), ("data", "model"))
for fsdp in (False, True):
    moe_mod.set_shard_map(mesh, ("data",), fsdp)
    with mesh:
        y1, _ = jax.jit(lambda p, x: apply_moe(p, cfg, x))(p, x)
    moe_mod.set_shard_map(None, (), False)
    d = float(jnp.max(jnp.abs(y0 - y1)))
    assert d < 1e-4, (fsdp, d)
print("OK")
""" % (os.path.join(ROOT, "src"),)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0 and "OK" in out.stdout, out.stderr[-2000:]
