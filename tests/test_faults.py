"""Fault-injection matrix for the serving stack (docs/robustness.md).

The bar, for every scenario in the seeded fault matrix:

* the serving loop **never deadlocks or crashes** — it drains to a
  terminal state for every submitted request within a bounded number of
  polls;
* **conservation holds** at both levels:
  ``completed + shed + failed == submitted`` (``ServerMetrics`` and
  every lane's ``Scheduler``);
* **all KV blocks come back** — paged pools end with
  ``unique_allocated == 0`` and intact invariants;
* requests the faults did not touch are **bit-identical** to the
  fault-free twin run (``loop.affected`` names the touched ones);
* the paper-tied guardrail (W8A8 verification producing non-finite
  logits, Quasar's quantized-verifier risk) **rescues losslessly**
  through retry/bf16 fallback, with the trips visible in
  ``summary()["robustness"]`` and the Prometheus exposition.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.core import SpecConfig
from repro.models import Model
from repro.serving import (
    FaultPlan,
    GenerationRequest,
    InjectedFault,
    LaneCrashed,
    RequestCancelled,
    RequestTimeout,
    ServerConfig,
    ServingLoop,
    SpecEngine,
    StreamingServer,
    VerifierNaNError,
)

COMBOS = [("ngram", "bf16"), ("ngram", "w8a8"), ("ngram-tree", "w8a8")]

SCENARIOS = {
    # seam spec                      what it models
    "step_exception": "step@1",      # arbitrary exception inside the step
    "nan_transient": "nan_verify@1",  # one-step numerics glitch / bitflip
    "quant_sticky": "quant_corrupt@1",  # corrupted quantized weights
    "alloc_failure": "alloc@0",      # BlockPool admission alloc fails
    "malformed_submit": "submit@1",  # malformed request at ingestion
}


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


_ENGINES = {}


def _engine(model, drafter, verifier, **scfg_kw):
    key = (drafter, verifier, tuple(sorted(scfg_kw.items())))
    if key not in _ENGINES:
        scfg = SpecConfig(temperature=0.0, gamma=3, tree_branches=(2, 1, 1),
                          kv_layout="paged", kv_block_size=8,
                          kv_pool_blocks=24, **scfg_kw)
        _ENGINES[key] = SpecEngine(model, scfg, drafter=drafter,
                                   verifier=verifier)
    return _ENGINES[key]


def _requests(cfg, seed=3):
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6)
    spec = [(2, 8, 11), (1, 10, 22), (2, 6, 33), (1, 8, 44)]
    return [GenerationRequest(np.tile(pat, k), max_new_tokens=m, seed=s)
            for k, m, s in spec]


def _run(model, params, drafter, verifier, *, faults=None, cfg_kw=None,
         reqs=None, max_polls=2000):
    """Drive a virtual-clock ServingLoop to drain; the poll bound is the
    no-deadlock assertion."""
    eng = _engine(model, drafter, verifier)
    reqs = _requests(model.cfg) if reqs is None else reqs
    clock = [0.0]
    cfg = ServerConfig(batch_slots=2, max_prompt_len=16, max_new_tokens=16,
                       **(cfg_kw or {}))
    loop = ServingLoop(eng, params, cfg, clock=lambda: clock[0],
                       faults=faults,
                       stall_hook=lambda s: clock.__setitem__(0, clock[0] + s))
    handles = [loop.submit(r) for r in reqs]
    polls = 0
    while loop.busy:
        before = loop.total_steps
        loop.poll()
        clock[0] += (loop.total_steps - before) * 0.25
        polls += 1
        assert polls < max_polls, "serving loop did not drain (deadlock?)"
    return loop, handles


_BASELINES = {}


def _baseline(model, params, drafter, verifier):
    """Fault-free twin tokens, per combo (cached across the matrix)."""
    key = (drafter, verifier)
    if key not in _BASELINES:
        loop, handles = _run(model, params, drafter, verifier)
        assert all(h.status == "done" for h in handles)
        loop.metrics.check_conservation()
        _BASELINES[key] = [np.asarray(h.result(timeout=0.0).tokens)
                           for h in handles]
    return _BASELINES[key]


def _check_contained(loop, handles, baseline):
    """The universal post-conditions: conservation at both levels, pool
    fully returned, untouched requests bit-identical to the twin."""
    loop.metrics.check_conservation()
    c = loop.metrics.counters
    assert c["completed"] + c["shed"] + c["failed"] == c["submitted"] \
        == len(handles)
    for lane in loop._lanes.values():
        lane.sched.check_conservation()
        if lane.ctx is not None:
            lane.ctx.pool.check_invariants()
            assert lane.ctx.pool.unique_allocated == 0
    for h in handles:
        assert h.status in ("done", "shed", "failed")
    for h, base in zip(handles, baseline):
        if h.status == "done" and h.rid not in loop.affected:
            np.testing.assert_array_equal(
                np.asarray(h.result(timeout=0.0).tokens), base)


# ---------------------------------------------------------------------------
# The seeded fault matrix: scenario x (drafter, verifier)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter,verifier", COMBOS,
                         ids=[f"{d}-{v}" for d, v in COMBOS])
@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_fault_matrix_contains_and_conserves(model, params, scenario,
                                             drafter, verifier):
    base = _baseline(model, params, drafter, verifier)
    plan = FaultPlan.parse(SCENARIOS[scenario], seed=7)
    loop, handles = _run(model, params, drafter, verifier, faults=plan)
    _check_contained(loop, handles, base)
    rb = loop.metrics.summary()["robustness"]
    c = loop.metrics.counters

    if scenario == "step_exception":
        # unattributable step failure: every then-occupied slot fails,
        # queued work survives and completes bit-identically
        assert c["failed"] >= 1 and rb["request_faults"] >= 1
        failed = [h for h in handles if h.status == "failed"]
        with pytest.raises(InjectedFault):
            failed[0].result(timeout=0.0)

    elif scenario == "nan_transient":
        # one-step glitch: the same-precision retry replays the step
        # from the intact pre-step state — every request completes and
        # every token is bit-identical (checked in _check_contained via
        # an empty `affected` set)
        assert all(h.status == "done" for h in handles)
        assert not loop.affected
        assert rb["verify_nan_trips"] >= 1
        assert rb["retry_rescued_rows"] >= 1
        assert rb["bf16_rescued_rows"] == 0

    elif scenario == "quant_sticky":
        # sticky corruption of the prepared (quantized) params: retry
        # sees the same poison, the bf16 fallback lane rescues the rows,
        # and three consecutive rescues re-prepare (re-quantize) the
        # lane.  NO request fails — graceful degradation, not an outage.
        assert all(h.status == "done" for h in handles)
        assert rb["verify_nan_trips"] >= 1
        assert rb["bf16_rescued_rows"] >= 1
        assert rb["reprepares"] >= 1
        if verifier == "bf16":
            # the "fallback" runs the same bf16 weights: rescued rows
            # are bit-identical too, affected or not
            for h, b in zip(handles, base):
                np.testing.assert_array_equal(
                    np.asarray(h.result(timeout=0.0).tokens), b)
        # the trips are scrapeable
        text = loop.metrics.expose_text()
        assert 'serve_robustness_total{event="verify_nan_trips"}' in text

    elif scenario == "alloc_failure":
        # pool alloc failed during the first admission: that request
        # fails alone, everyone else is served
        assert c["failed"] == 1 and rb["request_faults"] == 1
        failed = [h for h in handles if h.status == "failed"]
        with pytest.raises(InjectedFault, match="alloc failure"):
            failed[0].result(timeout=0.0)

    elif scenario == "malformed_submit":
        # corrupted request at ingestion: rejected terminally, never
        # reaches a scheduler
        assert c["failed"] == 1 and rb["rejected"] == 1
        failed = [h for h in handles if h.status == "failed"]
        with pytest.raises(ValueError, match="injected malformed"):
            failed[0].result(timeout=0.0)


# ---------------------------------------------------------------------------
# Swap-in corruption: the unrescuable end of the guardrail ladder
# ---------------------------------------------------------------------------

def test_swap_in_corruption_fails_only_resumed_request(model, params):
    """A preempted request resumes through a corrupted host snapshot:
    its KV blocks are NaN, so retry AND the bf16 fallback both fail —
    exactly that request dies (``VerifierNaNError``), the requests that
    caused the preemption finish bit-identically, and the pool ends
    clean."""
    scfg = SpecConfig(temperature=0.0, gamma=3, kv_layout="paged",
                      kv_block_size=8, kv_pool_blocks=8)
    eng = SpecEngine(model, scfg, drafter="ngram", verifier="bf16")
    rng = np.random.default_rng(17)
    pat = rng.integers(0, model.cfg.vocab_size, 6)
    other = rng.integers(0, model.cfg.vocab_size, 18)
    victim = GenerationRequest(other, max_new_tokens=10, seed=1, priority=2)
    fam = [GenerationRequest(np.tile(pat, 2), max_new_tokens=4, seed=2),
           GenerationRequest(np.concatenate([np.tile(pat, 2), pat[:3]]),
                             max_new_tokens=5, seed=3)]

    def drive(faults):
        clock = [0.0]
        loop = ServingLoop(eng, params,
                           ServerConfig(batch_slots=2, max_prompt_len=24,
                                        max_new_tokens=16),
                           clock=lambda: clock[0], faults=faults)
        handles = [loop.submit(victim)]
        for _ in range(2):                  # victim admitted + decoding
            loop.poll()
            clock[0] += 0.25
        handles += [loop.submit(r) for r in fam]
        polls = 0
        while loop.busy:
            loop.poll()
            clock[0] += 0.25
            polls += 1
            assert polls < 500
        return loop, handles

    clean_loop, clean_handles = drive(None)
    lane = next(iter(clean_loop._lanes.values()))
    assert lane.sched.preemptions >= 1      # the scenario really preempts
    assert all(h.status == "done" for h in clean_handles)

    plan = FaultPlan({"swap_in": {"p": 1.0}}, seed=0)
    loop, handles = drive(plan)
    h_victim, h_fam = handles[0], handles[1:]
    assert h_victim.status == "failed"
    assert isinstance(h_victim.error, VerifierNaNError)
    for h, ref in zip(h_fam, clean_handles[1:]):
        assert h.status == "done"
        np.testing.assert_array_equal(
            np.asarray(h.result(timeout=0.0).tokens),
            np.asarray(ref.result(timeout=0.0).tokens))
    loop.metrics.check_conservation()
    rb = loop.metrics.summary()["robustness"]
    assert rb["verify_nan_trips"] >= 1 and rb["unrescued_rows"] >= 1
    lane = next(iter(loop._lanes.values()))
    lane.ctx.pool.check_invariants()
    assert lane.ctx.pool.unique_allocated == 0


# ---------------------------------------------------------------------------
# Slow/hung ticks -> per-request timeouts (never blocked callers)
# ---------------------------------------------------------------------------

def test_stalled_lane_times_out_requests_not_callers(model, params):
    """Injected stalls wedge the lane (every step burns 3 virtual
    seconds); with ``request_timeout_s`` set, the poll loop converts the
    wedge into per-request ``RequestTimeout`` failures — the loop still
    drains, conservation holds, nothing waits forever."""
    plan = FaultPlan({"stall": {"p": 1.0, "delay_s": 3.0}}, seed=0)
    loop, handles = _run(model, params, "ngram", "bf16", faults=plan,
                         cfg_kw={"request_timeout_s": 5.0})
    loop.metrics.check_conservation()
    rb = loop.metrics.summary()["robustness"]
    assert rb["timeouts"] >= 1
    timed_out = [h for h in handles if h.status == "failed"]
    assert timed_out
    with pytest.raises(RequestTimeout, match="request_timeout_s"):
        timed_out[0].result(timeout=0.0)
    for lane in loop._lanes.values():
        lane.sched.check_conservation()
        assert lane.ctx.pool.unique_allocated == 0


# ---------------------------------------------------------------------------
# Client cancellation (queued and running)
# ---------------------------------------------------------------------------

def test_cancel_queued_and_running(model, params):
    """``StreamHandle.cancel()`` fails the request with
    ``RequestCancelled`` wherever it is: a running occupant releases its
    slot and blocks through the preemption machinery, a queued request
    never takes a slot, and the survivor's tokens are untouched."""
    eng = _engine(model, "ngram", "bf16")
    reqs = _requests(model.cfg)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=16,
                                    max_new_tokens=16),
                       clock=lambda: clock[0])
    handles = [loop.submit(r) for r in reqs[:3]]
    handles[2].cancel()                  # still in the ingress queue
    loop.poll()                          # admits request 0
    clock[0] += 0.25
    handles[0].cancel()                  # running occupant
    polls = 0
    while loop.busy:
        loop.poll()
        clock[0] += 0.25
        polls += 1
        assert polls < 500
    assert handles[0].status == "failed"
    assert handles[2].status == "failed"
    for h in (handles[0], handles[2]):
        with pytest.raises(RequestCancelled):
            h.result(timeout=0.0)
    assert handles[1].status == "done"
    ref = eng.generate_requests(params, [reqs[1]], batch_slots=1)[0]
    np.testing.assert_array_equal(handles[1].result(timeout=0.0).tokens,
                                  ref.tokens)
    loop.metrics.check_conservation()
    assert loop.metrics.summary()["robustness"]["cancelled"] == 2
    lane = next(iter(loop._lanes.values()))
    assert lane.ctx.pool.unique_allocated == 0


# ---------------------------------------------------------------------------
# Crash recovery: requeue-queued / fail-running, then the supervisor
# ---------------------------------------------------------------------------

def test_recover_requeues_queued_and_fails_running(model, params):
    """``ServingLoop.recover`` after a poll-escaping crash: running
    requests fail loudly with ``LaneCrashed`` (their lane state is
    untrusted), queued handles silently requeue and complete
    bit-identically — and are NOT double-counted as submitted."""
    base = _baseline(model, params, "ngram", "bf16")
    eng = _engine(model, "ngram", "bf16")
    reqs = _requests(model.cfg)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=16,
                                    max_new_tokens=16),
                       clock=lambda: clock[0])
    handles = [loop.submit(r) for r in reqs]
    loop.poll()                          # request 0 admitted + running
    clock[0] += 0.25
    loop.recover(RuntimeError("simulated worker crash"))
    assert handles[0].status == "failed"
    assert isinstance(handles[0].error, LaneCrashed)
    assert isinstance(handles[0].error.__cause__, RuntimeError)
    polls = 0
    while loop.busy:
        loop.poll()
        clock[0] += 0.25
        polls += 1
        assert polls < 500
    for h, b in zip(handles[1:], base[1:]):
        assert h.status == "done"
        np.testing.assert_array_equal(
            np.asarray(h.result(timeout=0.0).tokens), b)
    loop.metrics.check_conservation()
    c = loop.metrics.counters
    assert c["submitted"] == len(reqs)   # requeue did not re-count
    assert (c["completed"], c["failed"]) == (3, 1)


def test_supervisor_restarts_lane_after_poll_crash(model, params):
    """Threaded StreamingServer under an injected poll crash: the
    supervisor contains it (no silent worker death), restarts the lane,
    and every request reaches a terminal state — crashed-over requests
    carry ``LaneCrashed``, the rest complete."""
    eng = _engine(model, "ngram", "bf16")
    plan = FaultPlan.parse("poll@1", seed=0)
    srv = StreamingServer(eng, params,
                          ServerConfig(batch_slots=2, max_prompt_len=16,
                                       max_new_tokens=16),
                          faults=plan, restart_backoff_s=0.01)
    reqs = _requests(model.cfg)
    # submit through the loop before the thread starts so poll call #1
    # deterministically has work in flight when it crashes
    handles = [srv.loop.submit(r) for r in reqs]
    with srv:
        for h in handles:
            try:
                h.result(timeout=120.0)
            except Exception:
                pass
    m = srv.loop.metrics
    m.check_conservation()
    assert m.summary()["robustness"]["lane_restarts"] == 1
    assert all(h.status in ("done", "failed") for h in handles)
    assert any(h.status == "done" for h in handles)
    for h in handles:
        if h.status == "failed":
            assert isinstance(h.error, LaneCrashed)


def test_supervisor_gives_up_and_aborts(model, params):
    """Every poll crashing: after ``max_restarts`` consecutive failures
    the supervisor aborts — in-flight requests fail with the terminal
    ``LaneCrashed``, ``stop()`` re-raises it (a crashed server is loud),
    and later submits fail fast instead of hanging."""
    eng = _engine(model, "ngram", "bf16")
    plan = FaultPlan.parse("poll~1.0", seed=0)
    srv = StreamingServer(eng, params,
                          ServerConfig(batch_slots=2, max_prompt_len=16,
                                       max_new_tokens=16),
                          faults=plan, restart_backoff_s=0.001,
                          max_restarts=2)
    reqs = _requests(model.cfg)
    h = srv.loop.submit(reqs[0])
    srv.start()
    with pytest.raises(LaneCrashed):
        h.result(timeout=120.0)
    with pytest.raises(LaneCrashed):
        srv.stop(drain=False)
    # the loop is terminally dead: submits resolve immediately
    h2 = srv.loop.submit(reqs[1])
    assert h2.status == "failed"
    with pytest.raises(LaneCrashed):
        h2.result(timeout=0.0)
    srv.loop.metrics.check_conservation()
    assert srv.loop.metrics.counters["submitted"] == 2


def test_result_timeout_distinguishes_live_from_dead(model, params):
    """``result(timeout)`` on a live loop says the request is still
    queued/running; once the loop is dead, waiting resolves immediately
    with the terminal error instead of burning the full timeout."""
    eng = _engine(model, "ngram", "bf16")
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=16,
                                    max_new_tokens=16),
                       clock=lambda: 0.0)
    h = loop.submit(_requests(model.cfg)[0])
    with pytest.raises(TimeoutError, match="still queued"):
        h.result(timeout=0.01)
    crash = RuntimeError("terminal crash")
    loop.abort(crash)
    with pytest.raises(RuntimeError, match="terminal crash"):
        h.result(timeout=0.0)            # resolved by abort, not hanging
    loop.metrics.check_conservation()


# ---------------------------------------------------------------------------
# Graceful shutdown: deterministic resolution, loop stays alive
# ---------------------------------------------------------------------------

def test_shutdown_resolves_everything_deterministically(model, params):
    """``ServingLoop.shutdown``: queued work sheds, running work fails
    with ``RequestCancelled``, blocks all return — and the loop is NOT
    dead (a later submit is served normally)."""
    eng = _engine(model, "ngram", "bf16")
    reqs = _requests(model.cfg)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=16,
                                    max_new_tokens=16),
                       clock=lambda: clock[0])
    handles = [loop.submit(r) for r in reqs]
    for _ in range(2):
        loop.poll()
        clock[0] += 0.25
    loop.shutdown()
    assert not loop.busy and loop.dead is None
    assert handles[0].status == "failed"
    with pytest.raises(RequestCancelled, match="shutdown"):
        handles[0].result(timeout=0.0)
    assert all(h.status == "shed" for h in handles[1:])
    assert all(h.result(timeout=0.0) is None for h in handles[1:])
    loop.metrics.check_conservation()
    lane = next(iter(loop._lanes.values()))
    assert lane.ctx.pool.unique_allocated == 0
    # the loop survives shutdown: serve one more request normally
    h_new = loop.submit(reqs[0])
    polls = 0
    while loop.busy:
        loop.poll()
        clock[0] += 0.25
        polls += 1
        assert polls < 500
    assert h_new.status == "done"
    loop.metrics.check_conservation()
