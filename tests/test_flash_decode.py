"""Flash-decode Pallas kernel vs the attend() oracle, swept with hypothesis
— bf16/f32 and int8-KV (per-(token, head) scales folded in-kernel), chain
and tree-masked windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.tree import TreeTemplate
from repro.kernels.flash_decode import flash_decode
from repro.models.attention import _quant_kv, attend


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(1, 6),
    s=st.integers(8, 160),
    hkv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_decode_matches_attend(b, t, s, hkv, g, dh, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    hq = hkv * g
    q = jax.random.normal(kq, (b, t, hq, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + jnp.arange(t)[None, :]
    o_flash = flash_decode(q, k, v, qpos, block_s=32, interpret=True)
    # impl="jnp" pins the oracle: under REPRO_USE_PALLAS=1 (CI parity
    # step) auto mode would dispatch the oracle to the kernel itself
    o_ref = attend(q, k, v, qpos, jnp.arange(s, dtype=jnp.int32),
                   impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(1, 6),
    s=st.integers(8, 160),
    hkv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_decode_int8_matches_attend(b, t, s, hkv, g, dh, seed):
    """int8 K/V + streamed scales ≡ the jnp int8 oracle (f32 accumulation),
    across a shape sweep including non-block-multiple S (block_s=32)."""
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    hq = hkv * g
    q = jax.random.normal(kq, (b, t, hq, dh))
    k8, ks = _quant_kv(jax.random.normal(kk, (b, s, hkv, dh)))
    v8, vs = _quant_kv(jax.random.normal(kv, (b, s, hkv, dh)))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + jnp.arange(t)[None, :]
    o_flash = flash_decode(q, k8, v8, qpos, k_scale=ks, v_scale=vs,
                           block_s=32, interpret=True)
    o_ref = attend(q, k8, v8, qpos, jnp.arange(s, dtype=jnp.int32),
                   k_scale=ks, v_scale=vs, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("branches", [(1, 1, 1), (2, 2), (3, 1), (2, 1, 2)])
def test_flash_decode_int8_tree_matches_attend(branches):
    """int8 KV composes with the tree-mask route: quantized tree-masked
    flash_decode ≡ the jnp oracle at a non-block-multiple cache length."""
    tpl = TreeTemplate(branches)
    t = tpl.num_nodes
    b, s, hkv, g, dh = 2, 53, 2, 2, 8
    key = jax.random.PRNGKey(sum(branches))
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, t, hkv * g, dh))
    k8, ks = _quant_kv(jax.random.normal(kk, (b, s, hkv, dh)))
    v8, vs = _quant_kv(jax.random.normal(kv, (b, s, hkv, dh)))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + tpl.depths_dev[None, :]
    o_flash = flash_decode(q, k8, v8, qpos, k_scale=ks, v_scale=vs,
                           tree_mask=tpl.mask_dev, win_start=start,
                           block_s=16, interpret=True)
    o_ref = attend(q, k8, v8, qpos, jnp.arange(s, dtype=jnp.int32),
                   k_scale=ks, v_scale=vs, tree_mask=tpl.mask_dev,
                   win_start=start, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [
    # (b, t, s, hkv, g, dh, block_s) — s spans non-block-multiples
    (2, 4, 50, 2, 2, 8, 16),
    (1, 6, 33, 1, 4, 16, 32),
    (3, 1, 128, 3, 1, 8, 32),
    (2, 3, 97, 2, 2, 16, 64),
])
def test_flash_decode_int8_shape_sweep(shape):
    """Deterministic int8 sweep (runs with or without hypothesis),
    including cache lengths that are not block-size multiples."""
    b, t, s, hkv, g, dh, bs = shape
    key = jax.random.PRNGKey(s)
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, t, hkv * g, dh))
    k8, ks = _quant_kv(jax.random.normal(kk, (b, s, hkv, dh)))
    v8, vs = _quant_kv(jax.random.normal(kv, (b, s, hkv, dh)))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + jnp.arange(t)[None, :]
    o_flash = flash_decode(q, k8, v8, qpos, k_scale=ks, v_scale=vs,
                           block_s=bs, interpret=True)
    o_ref = attend(q, k8, v8, qpos, jnp.arange(s, dtype=jnp.int32),
                   k_scale=ks, v_scale=vs, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_int8_vs_dequantized_reference():
    """The in-kernel scale fold must equal explicit dequantization."""
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    b, t, s, hkv, g, dh = 2, 4, 40, 2, 2, 16
    q = jax.random.normal(kq, (b, t, hkv * g, dh))
    k8, ks = _quant_kv(jax.random.normal(kk, (b, s, hkv, dh)))
    v8, vs = _quant_kv(jax.random.normal(kv, (b, s, hkv, dh)))
    qpos = jnp.tile(jnp.arange(20, 20 + t)[None], (b, 1))
    o = flash_decode(q, k8, v8, qpos, k_scale=ks, v_scale=vs,
                     block_s=16, interpret=True)
    o_deq = attend(q, k8.astype(jnp.float32) * ks[..., None],
                   v8.astype(jnp.float32) * vs[..., None], qpos,
                   jnp.arange(s, dtype=jnp.int32), impl="jnp")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_deq),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_scale_args_must_pair():
    q = jnp.zeros((1, 1, 1, 8))
    k = jnp.zeros((1, 8, 1, 8), jnp.int8)
    ks = jnp.ones((1, 8, 1))
    with pytest.raises(ValueError, match="together"):
        flash_decode(q, k, k, jnp.zeros((1, 1), jnp.int32),
                     k_scale=ks, interpret=True)


def test_flash_decode_bf16():
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 4, 8, 32), jnp.bfloat16)
    k = jax.random.normal(kk, (2, 256, 4, 32), jnp.bfloat16)
    v = jax.random.normal(kv, (2, 256, 4, 32), jnp.bfloat16)
    qpos = jnp.tile(jnp.arange(100, 104)[None], (2, 1))
    o = flash_decode(q, k, v, qpos, block_s=128, interpret=True)
    o_ref = attend(q, k, v, qpos, jnp.arange(256, dtype=jnp.int32),
                   impl="jnp")
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
