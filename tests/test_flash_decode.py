"""Flash-decode Pallas kernel vs the attend() oracle, swept with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.kernels.flash_decode import flash_decode
from repro.models.attention import attend


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    t=st.integers(1, 6),
    s=st.integers(8, 160),
    hkv=st.sampled_from([1, 2, 3]),
    g=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_flash_decode_matches_attend(b, t, s, hkv, g, dh, seed):
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    hq = hkv * g
    q = jax.random.normal(kq, (b, t, hq, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + jnp.arange(t)[None, :]
    o_flash = flash_decode(q, k, v, qpos, block_s=32, interpret=True)
    o_ref = attend(q, k, v, qpos, jnp.arange(s, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_bf16():
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 4, 8, 32), jnp.bfloat16)
    k = jax.random.normal(kk, (2, 256, 4, 32), jnp.bfloat16)
    v = jax.random.normal(kv, (2, 256, 4, 32), jnp.bfloat16)
    qpos = jnp.tile(jnp.arange(100, 104)[None], (2, 1))
    o = flash_decode(q, k, v, qpos, block_s=128, interpret=True)
    o_ref = attend(q, k, v, qpos, jnp.arange(256, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
