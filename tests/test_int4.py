"""W4A8 packed-weight verification (beyond-paper extension)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.configs import get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.models import Model
from repro.quant import quantize_params
from repro.quant.int4 import (
    pack_int4,
    quantize_symmetric_int4,
    unpack_int4,
    w4a8_matmul,
)
from repro.serving.engine import SpecEngine


@settings(max_examples=20, deadline=None)
@given(din=st.integers(1, 64), dout=st.integers(1, 48),
       seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(din, dout, seed):
    din = din * 2  # even
    q = jax.random.randint(jax.random.PRNGKey(seed), (din, dout), -7, 8,
                           dtype=jnp.int32).astype(jnp.int8)
    rt = unpack_int4(pack_int4(q))
    np.testing.assert_array_equal(np.asarray(rt), np.asarray(q))


def test_w4a8_matmul_error_bounded():
    k0, k1 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k0, (32, 128))
    w = jax.random.normal(k1, (128, 64))
    q, scale = quantize_symmetric_int4(w, axis=0)
    y = w4a8_matmul(x, pack_int4(q), scale, jnp.ones((128,)))
    rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
    assert rel < 0.12, rel       # int4 ≈ 2-8% typical on gaussian weights


def test_w4a8_model_fidelity_and_losslessness():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    q4 = quantize_params(params, None, QuantConfig(w_bits=4))
    # packed weights present and ~4x smaller than f32 source
    l0 = q4["layers"][0]["attn"]["q"]
    assert "w_int4" in l0 and l0["w_int4"].shape[0] == cfg.d_model // 2

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab_size)
    lf, _ = m.forward(params, toks)
    l4, _ = m.forward(q4, toks)
    p = jax.nn.softmax(lf, -1)
    kl = float(jnp.mean(jnp.sum(
        p * (jnp.log(p + 1e-9) - jax.nn.log_softmax(l4, -1)), -1)))
    assert kl < 0.05, kl         # noticeably worse than int8 but usable

    # losslessness w.r.t. the W4A8 verifier itself still holds
    rng = np.random.default_rng(0)
    prompt = jnp.array(np.tile(rng.integers(0, cfg.vocab_size, 6), 5)
                       [None].repeat(2, 0).astype(np.int32))
    scfg = SpecConfig(gamma=4)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(q4, prompt, 10)
    rs = SpecEngine(m, scfg, mode="spec").generate(q4, prompt, 10)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, :P + 10] == rs.tokens[:, :P + 10]))
