"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.kernels import ref
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.smooth_quant import smooth_quant
from repro.kernels import ops as kops


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 300),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_int8_matmul_matches_ref(m, k, n, seed):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = _rand(k0, (m, k))
    w = _rand(k1, (k, n))
    s = jnp.abs(_rand(k2, (k,))) + 0.3
    w_int8, w_scale = ref.quantize_symmetric(w / s[:, None], axis=0)
    xq, dx = ref.smooth_quant_ref(x, s)
    y_ref = ref.int8_matmul_ref(xq, w_int8, dx, w_scale, jnp.float32)
    y_pal = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=jnp.float32,
                        block_m=32, block_n=32, block_k=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 260),
    seed=st.integers(0, 2**31 - 1),
)
def test_smooth_quant_matches_ref(m, k, seed):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k0, (m, k), scale=3.0)
    s = jnp.abs(_rand(k1, (k,))) + 0.2
    q_pal, dx_pal = smooth_quant(x, s, block_m=16, interpret=True)
    q_ref, dx_ref = ref.smooth_quant_ref(x, s)
    assert bool(jnp.all(q_pal == q_ref))
    np.testing.assert_allclose(np.asarray(dx_pal), np.asarray(dx_ref), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mkn", [(128, 512, 128), (37, 130, 65), (1, 64, 256)])
def test_w8a8_pipeline_dtypes(dtype, mkn):
    m, k, n = mkn
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = _rand(k0, (m, k), dtype)
    w = _rand(k1, (k, n))
    s = jnp.abs(_rand(k2, (k,))) + 0.5
    w_int8, w_scale = ref.quantize_symmetric(w / s[:, None], axis=0)
    xq, dx = smooth_quant(x, s, interpret=True)
    y = int8_matmul(xq, w_int8, dx, w_scale, out_dtype=dtype, interpret=True)
    y_ref = ref.w8a8_matmul_ref(x, w_int8, w_scale, s, out_dtype=dtype)
    np.testing.assert_allclose(
        np.asarray(y, np.float32), np.asarray(y_ref, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_w8a8_quantization_error_small():
    """The W8A8 GEMM must approximate the true matmul well (paper §3.2)."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(7), 3)
    x = _rand(k0, (64, 512))
    w = _rand(k1, (512, 256))
    s = jnp.ones((512,))
    w_int8, w_scale = ref.quantize_symmetric(w, axis=0)
    y = ref.w8a8_matmul_ref(x, w_int8, w_scale, s, out_dtype=jnp.float32)
    y_true = x @ w
    rel = float(jnp.linalg.norm(y - y_true) / jnp.linalg.norm(y_true))
    assert rel < 0.05, rel


def test_ops_dispatch_batched_shapes():
    """Public wrapper handles leading batch dims."""
    k0, k1 = jax.random.split(jax.random.PRNGKey(1))
    x = _rand(k0, (2, 3, 5, 96))
    w = _rand(k1, (96, 64))
    s = jnp.ones((96,))
    w_int8, w_scale = ref.quantize_symmetric(w, axis=0)
    y = kops.w8a8_matmul(x, w_int8, w_scale, s)
    assert y.shape == (2, 3, 5, 64)
    y2 = ref.w8a8_matmul_ref(x.reshape(-1, 96), w_int8, w_scale, s, jnp.float32)
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 64), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 120),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_int4_matmul_matches_unpacked(m, k, n, seed):
    from repro.kernels.int4_matmul import int4_matmul
    from repro.quant.int4 import pack_int4, quantize_symmetric_int4

    k = k * 2  # even K for packing
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k0, (m, k))
    w = _rand(k1, (k, n))
    q, dw = quantize_symmetric_int4(w, axis=0)
    xq, dx = ref.smooth_quant_ref(x, jnp.ones((k,)))
    y = int4_matmul(xq, pack_int4(q), dx, dw, out_dtype=jnp.float32,
                    block_m=16, block_n=32, block_k=64, interpret=True)
    acc = jax.lax.dot_general(xq, q, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y_ref = acc.astype(jnp.float32) * dx[:, None] * dw[None, :]
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_pallas_path_forced(monkeypatch):
    """REPRO_USE_PALLAS routes the public op through interpret-mode Pallas."""
    kops.set_use_pallas(True)
    try:
        k0, k1 = jax.random.split(jax.random.PRNGKey(2))
        x = _rand(k0, (17, 48))
        w = _rand(k1, (48, 32))
        s = jnp.ones((48,))
        w_int8, w_scale = ref.quantize_symmetric(w, axis=0)
        y = kops.w8a8_matmul(x, w_int8, w_scale, s)
        y_ref = ref.w8a8_matmul_ref(x, w_int8, w_scale, s, jnp.float32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-5, atol=1e-5)
    finally:
        kops.set_use_pallas(False)
