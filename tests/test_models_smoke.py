"""Per-assigned-architecture smoke tests (reduced configs: ≤2 layers,
d_model ≤ 512, ≤4 experts): one forward + one train step on CPU, asserting
output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, REGISTRY, get_config
from repro.data import lm_batches
from repro.models import Model
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer

ARCHS = [c.name for c in ASSIGNED]


def _aux(cfg, B, key):
    n = cfg.num_image_tokens or cfg.num_audio_frames
    if not n:
        return None
    return jax.random.normal(key, (B, n, cfg.d_model), cfg.dtype)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.num_experts <= 4
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    logits, aux = m.forward(params, toks, aux_embeds=_aux(cfg, B, jax.random.PRNGKey(2)))
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    tr = Trainer(m, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4))
    params, opt = tr.init(jax.random.PRNGKey(0))
    batch = next(lm_batches(2, 16, cfg.vocab_size, seed=0))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    if cfg.num_image_tokens or cfg.num_audio_frames:
        n = cfg.num_image_tokens or cfg.num_audio_frames
        batch["aux_embeds"] = jnp.ones((2, n, cfg.d_model), cfg.dtype)
    p2, o2, metrics = tr._step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2)
    assert max(jax.tree.leaves(d)) > 0


def test_registry_complete():
    assert len(ASSIGNED) == 10
    kinds = {c.arch_type for c in ASSIGNED}
    assert kinds == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}
    # every config cites its source
    for c in REGISTRY.values():
        assert c.source


def test_full_configs_match_assignment():
    c = get_config("phi3.5-moe-42b-a6.6b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (32, 4096, 32, 8)
    assert (c.d_ff, c.vocab_size, c.num_experts, c.experts_per_token) == (6400, 32064, 16, 2)
    c = get_config("arctic-480b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_experts) == (35, 7168, 56, 128)
    assert c.dense_residual
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (54, 2560, 64)
    c = get_config("llama-3.2-vision-90b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (100, 8192, 28672, 128256)
    c = get_config("stablelm-12b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (40, 5120, 13824, 100352)
    c = get_config("smollm-135m")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (30, 576, 9, 3)
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.num_experts, c.experts_per_token, c.vocab_size) == (64, 6, 163840)
    c = get_config("mamba2-370m")
    assert (c.num_layers, c.d_model, c.ssm_state, c.vocab_size) == (48, 1024, 128, 50280)
    assert c.arch_type == "ssm" and c.num_heads == 0
    c = get_config("codeqwen1.5-7b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (32, 4096, 13440, 92416)
    c = get_config("whisper-small")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.vocab_size) == (12, 12, 768, 51865)
