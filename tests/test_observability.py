"""Observability harness: tracing determinism, bounded histograms,
acceptance/KV-cache telemetry (docs/observability.md).

Four layers:

1. **Units** — ceil-based nearest-rank ``percentile`` pins; histogram
   exactness at the edges (single sample, min/max/mean) and input
   validation; tracer event-cap discipline (a capped trace stays
   structurally valid) and deterministic serialisation.
2. **Properties** (hypothesis) — histogram ``merge`` is exactly
   equivalent to single-pass ingestion of the concatenated samples, and
   quantile estimates stay within one bucket's relative width
   (``growth``) of the exact nearest-rank value.
3. **Validator** — ``tools/check_trace.py`` accepts every trace the
   serving stack emits and rejects unmatched/misnested/retrograde
   structures.
4. **End-to-end** — two identical virtual-clock ``serve_load`` replays
   over a preempting paged lane serialize **byte-identical** Perfetto
   traces containing request-lifecycle, decode, and preempt/swap spans;
   ``ServerMetrics.summary()`` carries populated ``acceptance`` and
   ``kv_cache`` sections with memory bounded in the request count; and
   generated tokens are bit-identical with tracing enabled vs disabled.
"""
import math
import os
import sys

import numpy as np
import pytest

import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from _hypothesis_compat import given, settings, st
from repro.serving import GenerationRequest, ServerMetrics, Tracer
from repro.serving.histogram import Histogram
from repro.serving.metrics import percentile
from repro.serving.trace import NULL_TRACER
from tools.check_trace import validate


# ---------------------------------------------------------------------------
# percentile: explicit ceil-based nearest-rank
# ---------------------------------------------------------------------------

def test_percentile_nearest_rank_pins():
    # p50 of an even-length list is the n/2-th order statistic — the
    # banker's-rounding bug returned 3 here
    assert percentile([1, 2, 3, 4], 50) == 2.0
    assert percentile([4, 3, 2, 1], 50) == 2.0          # order-free
    assert percentile([1, 2, 3, 4], 99) == 4.0
    assert percentile([1, 2, 3, 4], 100) == 4.0
    assert percentile([1, 2, 3, 4], 0) == 1.0           # k clamps to 1
    assert percentile([1, 2, 3], 50) == 2.0
    assert percentile([5], 50) == 5.0
    assert percentile([1, 2], 50) == 1.0                # ceil(0.5*2)=1
    assert math.isnan(percentile([], 50))


# ---------------------------------------------------------------------------
# Histogram units
# ---------------------------------------------------------------------------

def test_histogram_single_sample_exact():
    for v in (1e-9, 0.0017, 1.0, 3.14, 9e6, 1e12):   # incl. under/overflow
        h = Histogram()
        h.add(v)
        s = h.summary()
        assert s["n"] == 1
        assert s["mean"] == pytest.approx(v)
        assert s["p50"] == pytest.approx(v)          # clamped to [vmin,vmax]
        assert s["p99"] == pytest.approx(v)
        assert s["max"] == v


def test_histogram_empty_and_invalid():
    h = Histogram()
    assert h.summary() == {"n": 0}
    assert math.isnan(h.percentile(50))
    with pytest.raises(ValueError):
        h.add(-0.5)
    with pytest.raises(ValueError):
        h.add(float("nan"))
    with pytest.raises(ValueError):
        Histogram(min_value=0.0)
    with pytest.raises(ValueError):
        h.merge(Histogram(growth=2.0))
    h.add(1.0, n=0)                                  # no-op, not an error
    assert h.count == 0


def test_histogram_bounded_buckets():
    h = Histogram()
    rng = np.random.default_rng(0)
    for v in rng.lognormal(0.0, 4.0, size=20000):
        h.add(float(v))
    assert h.count == 20000
    assert len(h) <= h.max_buckets
    d = h.to_dict()
    assert sum(d["counts"]) == 20000
    assert len(d["le"]) == len(d["counts"]) == len(h)


def test_server_metrics_memory_flat_without_timelines():
    """keep_timelines=False really is O(1) per request now: no raw
    latency lists, timelines dropped on fold, histograms bucket-bounded."""
    m = ServerMetrics(keep_timelines=False)
    rng = np.random.default_rng(1)
    n = 500
    for rid in range(n):
        t0 = float(rid)
        m.on_submit(rid, t0, deadline_t=t0 + 2.0)
        m.on_admit(rid, t0 + float(min(rng.exponential(0.1), 0.25)))
        m.on_tokens(rid, t0 + 0.3, 4)
        m.on_tokens(rid, t0 + 0.5, 4)
        m.on_finish(rid, t0 + 0.6)
    m.check_conservation()
    assert not m.timelines                       # nothing retained
    for h in (m._ttft, m._itl, m._queue, m._service):
        assert isinstance(h, Histogram) and len(h) <= h.max_buckets
    s = m.summary()
    assert s["latency"]["ttft_s"]["n"] == n
    assert s["deadlines"]["with_deadline"] == n


def test_server_metrics_single_sample_latency_exact():
    m = ServerMetrics()
    m.on_submit(0, 10.0)
    m.on_admit(0, 11.0)
    m.on_tokens(0, 11.5, 2)
    m.on_finish(0, 12.0)
    s = m.summary()
    assert s["latency"]["queue_s"]["p50"] == pytest.approx(1.0)
    assert s["latency"]["ttft_s"]["p50"] == pytest.approx(1.5)
    assert s["latency"]["service_s"]["max"] == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Histogram properties (hypothesis)
# ---------------------------------------------------------------------------

_vals = st.lists(st.floats(min_value=1e-3, max_value=1e6,
                           allow_nan=False, allow_infinity=False),
                 min_size=1, max_size=80)


@given(a=_vals, b=_vals)
@settings(max_examples=80, deadline=None)
def test_histogram_merge_equals_single_pass(a, b):
    h1, h2, ref = Histogram(), Histogram(), Histogram()
    h1.extend(a)
    h2.extend(b)
    ref.extend(a + b)
    h1.merge(h2)
    assert h1.buckets == ref.buckets
    assert h1.count == ref.count
    assert h1.vmin == ref.vmin and h1.vmax == ref.vmax
    assert h1.total == pytest.approx(ref.total, rel=1e-9)
    for q in (50, 99):
        assert h1.percentile(q) == ref.percentile(q)


@given(vals=_vals, q=st.integers(1, 100))
@settings(max_examples=80, deadline=None)
def test_histogram_percentile_within_one_bucket(vals, q):
    """The bucket holding the exact k-th order statistic represents it:
    the estimate is within one bucket's relative width (× growth)."""
    h = Histogram()
    h.extend(vals)
    exact = percentile(vals, q)
    est = h.percentile(q)
    assert exact / h.growth <= est <= exact * h.growth


# ---------------------------------------------------------------------------
# Tracer units
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 0.5
        return self.t


def _scripted_trace(tracer):
    tracer.thread_name(0, "lane0")
    tracer.begin_async("queued", 7, rid=7)
    with tracer.span("tick", tid=0, step=0):
        with tracer.span("decode", tid=0, rows=2):
            pass
        tracer.counter("occupancy", 2, tid=0)
    tracer.end_async("queued", 7)
    tracer.instant("shed", tid=0, rid=9)


def test_tracer_deterministic_dumps():
    t1, t2 = Tracer(clock=_FakeClock()), Tracer(clock=_FakeClock())
    _scripted_trace(t1)
    _scripted_trace(t2)
    assert t1.dumps() == t2.dumps()              # byte-identical
    assert validate(t1.export()) == []


def test_tracer_event_cap_keeps_structure():
    """Once full, new begins are dropped (and counted) but recorded
    spans still close: the capped trace passes structural validation."""
    t = Tracer(clock=_FakeClock(), max_events=6)
    for i in range(5):
        t.begin_async("queued", i)
        with t.span("tick", tid=0):
            with t.span("decode", tid=0):
                pass
        t.end_async("queued", i)
    assert t.dropped > 0
    assert validate(t.export()) == []
    # an end whose begin was dropped is skipped, not emitted unbalanced
    t.end_async("queued", 4999)
    assert validate(t.export()) == []


def test_null_tracer_is_inert():
    with NULL_TRACER.span("tick", tid=3, step=1):
        NULL_TRACER.counter("occupancy", 1)
    NULL_TRACER.begin_async("queued", 0)
    NULL_TRACER.end_async("queued", 0)
    NULL_TRACER.instant("shed")
    NULL_TRACER.thread_name(0, "x")
    assert not NULL_TRACER.enabled


def test_check_trace_rejects_malformed():
    base = {"pid": 1, "tid": 0}
    # E with no open B
    assert validate([{**base, "ph": "E", "name": "x", "ts": 1.0}])
    # bad nesting: E closes the wrong span
    assert validate([
        {**base, "ph": "B", "name": "a", "ts": 1.0},
        {**base, "ph": "B", "name": "b", "ts": 2.0},
        {**base, "ph": "E", "name": "a", "ts": 3.0},
        {**base, "ph": "E", "name": "b", "ts": 4.0},
    ])
    # retrograde timestamps on one track
    assert validate([
        {**base, "ph": "B", "name": "a", "ts": 5.0},
        {**base, "ph": "E", "name": "a", "ts": 1.0},
    ])
    # unclosed B at EOF
    assert validate([{**base, "ph": "B", "name": "a", "ts": 1.0}])
    # async end with no begin
    assert validate([{**base, "ph": "e", "cat": "request", "id": 3,
                      "name": "queued", "ts": 1.0}])
    # counter args must be finite numbers
    assert validate([{**base, "ph": "C", "name": "occ", "ts": 1.0,
                      "args": {"v": float("nan")}}])
    assert validate({"notTraceEvents": []})
    # and the empty trace is fine
    assert validate({"traceEvents": []}) == []


# ---------------------------------------------------------------------------
# End-to-end: serve_load replay determinism + telemetry
# ---------------------------------------------------------------------------

from benchmarks import serve_load  # noqa: E402


# victim with loose deadline fills the 10-block pool; later tight-
# deadline arrivals out-key it under EDF and must preempt it to the
# swap pool (verified: preemptions >= 1 below)
_PREEMPT_TRACE = [
    {"arrival_s": 0.0, "prompt_reps": 6, "max_new_tokens": 16,
     "deadline_s": 60.0, "seed": 1},
    {"arrival_s": 0.6, "prompt_reps": 2, "max_new_tokens": 8,
     "deadline_s": 2.0, "seed": 2},
    {"arrival_s": 0.7, "prompt_reps": 2, "max_new_tokens": 8,
     "deadline_s": 2.5, "seed": 3},
]


@pytest.fixture(scope="module")
def paged_engine():
    return serve_load._build_engine(smoke=True, paged=True)


def _traced_replay(engine, params):
    clock = serve_load.VirtualClock()
    tracer = Tracer(clock=clock.read)
    summary = serve_load.replay(engine, params, _PREEMPT_TRACE,
                                admission="edf", shed=False,
                                clock=clock, tracer=tracer)
    return summary, tracer


def test_replay_traces_byte_identical_with_preempt_spans(paged_engine):
    engine, params = paged_engine
    s1, t1 = _traced_replay(engine, params)
    s2, t2 = _traced_replay(engine, params)

    # two identical virtual-clock replays: byte-identical Perfetto JSON
    assert t1.dumps() == t2.dumps()
    assert validate(t1.export()) == []

    names = {e["name"] for e in t1.events}
    # request lifecycle + per-step + preempt/swap span taxonomy
    assert {"queued", "running", "preempted",          # lifecycle (async)
            "tick", "admit", "decode", "harvest",      # per-tick phases
            "prefill", "append_blocks",                # paged data plane
            "preempt", "swap_out", "swap_in"} <= names

    # the preempted lifecycle phase balances (ended on resume)
    opened = sum(1 for e in t1.events
                 if e["ph"] == "b" and e["name"] == "preempted")
    closed = sum(1 for e in t1.events
                 if e["ph"] == "e" and e["name"] == "preempted")
    assert opened == closed >= 1

    kv = s1["kv_cache"]
    assert kv["preemptions"] >= 1
    assert kv["swap_out_blocks"] >= 1
    assert kv["swap_in_blocks"] == kv["swap_out_blocks"]
    assert kv["swap_out_bytes"] > 0 and kv["swap_in_bytes"] > 0
    assert kv["prefix_hits"] >= 1                  # the shared family
    assert kv["cow_forks"] >= 1
    assert kv["prefix_hit_rate"] == pytest.approx(
        kv["prefix_hits"] / (kv["prefix_hits"] + kv["prefix_misses"]))
    assert kv["pools"]                             # per-lane gauges

    acc = s1["acceptance"]
    assert "ngram:bf16" in acc
    e = acc["ngram:bf16"]
    assert e["steps"] == s1["counters"]["decode_steps"]
    assert e["accept_len"]["n"] >= e["steps"]
    # every streamed token was counted as an accepted commit
    assert e["committed_tokens"] == s1["counters"]["stream_tokens"]
    # virtual clock: each step costs exactly the modeled step time
    assert e["step_s"]["max"] == pytest.approx(serve_load.STEP_COST_S)

    # the two replays agree on every aggregate, not just the trace
    assert s1 == s2


def test_generation_bit_identical_tracing_on_vs_off(paged_engine):
    engine, params = paged_engine
    rng = np.random.default_rng(3)
    pat = rng.integers(0, engine.model.cfg.vocab_size, 6)
    reqs = [GenerationRequest(np.tile(pat, 2), max_new_tokens=6, seed=i)
            for i in range(3)]
    plain = engine.generate_requests(params, reqs)
    tracer = Tracer()
    traced = engine.generate_requests(params, reqs, tracer=tracer)
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.steps == b.steps and a.accept_len == b.accept_len
    assert validate(tracer.export()) == []
    assert {"tick", "decode", "prefill", "queued", "running"} <= {
        e["name"] for e in tracer.events}
    # batch-path telemetry accumulated on the engine itself
    assert engine.telemetry.mean_accept("ngram:bf16") is not None


# ---------------------------------------------------------------------------
# Prometheus exposition
# ---------------------------------------------------------------------------

def test_expose_text_format():
    m = ServerMetrics()
    m.on_submit(0, 0.0)
    m.on_admit(0, 0.5)
    m.on_tokens(0, 1.0, 3)
    m.on_finish(0, 1.5)
    m.on_step(1.5, 1, 2)
    m.on_decode_step("ngram:bf16", [2, 3], 0.1)
    text = m.expose_text()
    assert text.endswith("\n")
    lines = text.splitlines()
    assert 'serve_requests_total{event="submitted"} 1' in lines
    assert 'serve_requests_total{event="completed"} 1' in lines
    assert "# TYPE serve_requests_total counter" in lines
    assert "# TYPE serve_accept_len gauge" in lines
    assert any(l.startswith('serve_accept_len{drafter="ngram",'
                            'verifier="bf16",stat="tokens"} 5')
               for l in lines)
    assert 'serve_latency_queue_s{stat="n"} 1' in lines
    assert 'serve_kv_cache_total{event="preemptions"} 0' in lines
    # None-valued samples (no SLOs, no prefix probes) are omitted, but
    # their HELP/TYPE headers still render deterministically
    assert "# TYPE serve_deadline_hit_rate gauge" in lines
    assert not any(l.startswith("serve_deadline_hit_rate ") for l in lines)
    # deterministic: a second render is byte-identical
    assert m.expose_text() == text


def test_summary_is_json_serialisable():
    import json
    m = ServerMetrics()
    m.on_submit(0, 0.0)
    m.on_shed(0, 1.0)
    m.on_decode_step("ngram:w8a8", [1], 0.01)
    out = json.loads(json.dumps(m.summary()))
    assert out["counters"]["shed"] == 1
    assert out["acceptance"]["ngram:w8a8"]["steps"] == 1
