"""Paged KV-cache subsystem: pool invariants, layout reconstruction,
kernel parity, and the serving-level losslessness bar.

Three layers of guarantees:

1. **BlockPool invariants** (model-free, property-based): arbitrary
   admit / append / release sequences never double-allocate a block,
   never touch the scratch block, and conserve the pool exactly.
2. **Layout reconstruction**: writes through the block table followed by
   the logical gather reproduce the contiguous cache contents exactly —
   the write/read pair is a bijection on the written region.
3. **Serving losslessness**: paged scheduler generation is
   **bit-identical** to contiguous scheduler generation (and therefore,
   by ``tests/test_continuous_batching.py``, to solo serving) for every
   drafter × verifier at T=0 and T>0, including int8 KV — the same bar
   PRs 2-4 set for scheduling, trees and kernel dispatch.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import SpecConfig
from repro.core.paged_cache import (
    SCRATCH_BLOCK,
    BlockPool,
    blocks_for_tokens,
    gather_block_rows,
    init_paged_cache,
    physical_slots,
    plan_group,
    request_demand_tokens,
)
from repro.core.tree import TreeTemplate
from repro.kernels.flash_decode import flash_decode_paged
from repro.models import Model
from repro.models.attention import _quant_kv, attend, write_cache, write_cache_paged
from repro.serving import GenerationRequest, SpecEngine


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def model_int8(model):
    return Model(dataclasses.replace(model.cfg, kv_cache_dtype="int8"))


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


def _requests(cfg, *, temps=(None,), spec=((5, 6, 11), (4, 8, 22),
                                           (3, 7, 33), (2, 5, 44))):
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 6)
    return [GenerationRequest(np.tile(pat, k), max_new_tokens=n, seed=s,
                              temperature=temps[i % len(temps)])
            for i, (k, n, s) in enumerate(spec)]


# ---------------------------------------------------------------------------
# 1. BlockPool invariants
# ---------------------------------------------------------------------------

def test_block_pool_lifecycle_and_errors():
    pool = BlockPool(num_blocks=9, block_size=4)      # 8 allocatable
    assert pool.capacity == 8 and pool.free_blocks == 8
    pool.reserve(0, 3)
    a = pool.alloc(0, 2)
    assert len(a) == 2 and SCRATCH_BLOCK not in a
    pool.check_invariants()
    # alloc beyond the reservation is a bug, not an OOM
    with pytest.raises(ValueError, match="beyond reservation"):
        pool.alloc(0, 2)
    # alloc without a reservation is a bug
    with pytest.raises(ValueError, match="no reservation"):
        pool.alloc(7, 1)
    # over-committing reservations is refused
    pool.reserve(1, 5)
    assert not pool.can_reserve(1)
    with pytest.raises(ValueError, match="over-committed"):
        pool.reserve(2, 1)
    # release returns everything
    freed = pool.release(0)
    assert sorted(freed) == sorted(a)
    assert pool.can_reserve(3)
    pool.check_invariants()


@given(ops=st.lists(st.tuples(st.integers(0, 2),       # 0=admit 1=append 2=release
                              st.integers(0, 7),       # request id
                              st.integers(1, 6)),      # blocks
                    min_size=1, max_size=60),
       num_blocks=st.integers(4, 24))
@settings(max_examples=60, deadline=None)
def test_block_pool_conservation_property(ops, num_blocks):
    """Property: under ANY admit/append/release sequence (invalid steps
    skipped the way the engine's admission control skips them), no block
    is double-allocated, the scratch block is never handed out, and
    free + allocated == capacity after every step."""
    pool = BlockPool(num_blocks=num_blocks, block_size=4)
    reserved = {}
    for kind, rid, n in ops:
        if kind == 0 and rid not in reserved and pool.can_reserve(n):
            pool.reserve(rid, n)
            reserved[rid] = n
        elif kind == 1 and rid in reserved:
            room = reserved[rid] - len(pool.owned(rid))
            if room:
                pool.alloc(rid, min(n, room))
        elif kind == 2 and rid in reserved:
            pool.release(rid)
            del reserved[rid]
        pool.check_invariants()
    for rid in list(reserved):
        pool.release(rid)
    pool.check_invariants()
    assert pool.free_blocks == pool.capacity


# ---------------------------------------------------------------------------
# 2. Layout reconstruction: block-table writes == contiguous writes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("int8", [False, True])
def test_paged_write_reconstructs_contiguous(int8):
    """Random windows scattered through random (disjoint) block tables,
    then gathered back, must equal the same windows written into a
    contiguous cache — the paged write/read pair is exact."""
    B, T, Hkv, dh, bs, nb = 3, 4, 2, 8, 8, 5
    S = nb * bs
    rng = np.random.default_rng(0)
    # disjoint per-row tables out of a shared pool (+ scratch)
    perm = rng.permutation(np.arange(1, 1 + B * nb))
    bt = jnp.asarray(perm.reshape(B, nb), jnp.int32)
    N = 1 + B * nb
    dt = jnp.int8 if int8 else jnp.float32
    paged = {"k": jnp.zeros((N, bs, Hkv, dh), dt),
             "v": jnp.zeros((N, bs, Hkv, dh), dt)}
    cont = {"k": jnp.zeros((B, S, Hkv, dh), dt),
            "v": jnp.zeros((B, S, Hkv, dh), dt)}
    if int8:
        for c in (paged, cont):
            shp = (N, bs, Hkv) if c is paged else (B, S, Hkv)
            c["k_scale"] = jnp.zeros(shp, jnp.float32)
            c["v_scale"] = jnp.zeros(shp, jnp.float32)
    key = jax.random.PRNGKey(1)
    written = np.zeros((B, S), bool)
    for step in range(6):
        key, k1, k2, k3 = jax.random.split(key, 4)
        k = jax.random.normal(k1, (B, T, Hkv, dh), jnp.float32)
        v = jax.random.normal(k2, (B, T, Hkv, dh), jnp.float32)
        starts = jax.random.randint(k3, (B,), 0, S - T)
        slots = starts[:, None] + jnp.arange(T)[None, :]
        paged = write_cache_paged(paged, k, v, slots, bt)
        cont = write_cache(cont, k, v, slots)
        written[np.arange(B)[:, None], np.asarray(slots)] = True
    for name in paged:
        logical = np.asarray(gather_block_rows(paged[name], bt))
        expect = np.asarray(cont[name])
        np.testing.assert_array_equal(logical[written], expect[written])
    # physical_slots clips out-of-range logical slots onto scratch
    far = jnp.full((B, T), S + 17, jnp.int32)
    phys = np.asarray(physical_slots(bt, far, bs))
    assert (phys // bs == SCRATCH_BLOCK).all()


# ---------------------------------------------------------------------------
# 3. Paged Pallas kernel: interpret-mode parity vs the gathered oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("int8,tree", [(False, False), (True, False),
                                       (False, True), (True, True)])
def test_flash_decode_paged_matches_oracle(int8, tree):
    B, T, Hkv, G, dh = 2, 4, 2, 2, 32
    bs, nb, N = 16, 8, 12
    kq, kk, kv, kb = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(kq, (B, T, Hkv * G, dh), jnp.float32)
    pool_k = jax.random.normal(kk, (N, bs, Hkv, dh), jnp.float32)
    pool_v = jax.random.normal(kv, (N, bs, Hkv, dh), jnp.float32)
    bt = jax.random.randint(kb, (B, nb), 0, N)
    start = jnp.array([40, 17], jnp.int32)
    ks = vs = tm = ws = None
    if int8:
        pool_k, ks = _quant_kv(pool_k)
        pool_v, vs = _quant_kv(pool_v)
    if tree:
        tpl = TreeTemplate((3,))                      # 4 nodes == T
        tm, ws = tpl.mask_dev, start
        qpos = start[:, None] + tpl.depths_dev[None, :]
    else:
        qpos = start[:, None] + jnp.arange(T)[None, :]
    kg, vg = gather_block_rows(pool_k, bt), gather_block_rows(pool_v, bt)
    ref = attend(q, kg, vg, qpos, jnp.arange(nb * bs, dtype=jnp.int32),
                 k_scale=gather_block_rows(ks, bt) if int8 else None,
                 v_scale=gather_block_rows(vs, bt) if int8 else None,
                 tree_mask=tm, win_start=ws, impl="jnp")
    out = flash_decode_paged(q, pool_k, pool_v, bt, qpos,
                             k_scale=ks, v_scale=vs,
                             tree_mask=tm, win_start=ws, interpret=True)
    np.testing.assert_allclose(out, ref, atol=1e-5)


# ---------------------------------------------------------------------------
# 4. Serving losslessness: paged == contiguous per drafter x verifier
# ---------------------------------------------------------------------------

def _serve_both_layouts(model, params, drafter, verifier, scfg, reqs,
                        batch_slots=2, block_size=8):
    base = SpecEngine(model, scfg, drafter=drafter, verifier=verifier)
    r0 = base.generate_requests(params, reqs, batch_slots=batch_slots)
    scp = dataclasses.replace(scfg, kv_layout="paged",
                              kv_block_size=block_size)
    eng = SpecEngine(model, scp, drafter=drafter, verifier=verifier)
    assert eng.step_traces == 0
    r1 = eng.generate_requests(params, reqs, batch_slots=batch_slots)
    # paged admission + block appends must never retrace the decode step
    # (one compile per temperature group)
    temps = {scfg.temperature if r.temperature is None else r.temperature
             for r in reqs}
    assert eng.step_traces == len(temps)
    return r0, r1


@pytest.mark.parametrize("drafter,verifier", [
    ("ngram", "bf16"), ("ngram", "w8a8"),
    ("vanilla", "bf16"), ("vanilla", "w8a8"),
    ("pruned", "bf16"), ("pruned", "w8a8"),
    ("ngram-tree", "bf16"), ("ngram-tree", "w8a8"),
])
def test_paged_matches_contiguous_all_combos(model, params, drafter,
                                             verifier):
    """The acceptance bar: paged scheduler serving is bit-identical to
    contiguous scheduler serving for every registered drafter × verifier
    at T=0 AND T>0 (mixed-temperature request set exercises both jitted
    steps in one call), through 2 slots at 2x oversubscription with a
    non-power-of-two block size."""
    scfg = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5,
                      tree_branches=(2, 1, 1))
    reqs = _requests(model.cfg, temps=(0.0, 0.8))
    r0, r1 = _serve_both_layouts(model, params, drafter, verifier, scfg,
                                 reqs, block_size=8)
    for req, a, b in zip(reqs, r0, r1):
        assert b.new_tokens == req.max_new_tokens
        np.testing.assert_array_equal(a.tokens, b.tokens)


@pytest.mark.parametrize("drafter,verifier", [
    ("ngram", "w8a8"), ("ngram-tree", "bf16"),
])
def test_paged_matches_contiguous_int8_kv(model_int8, params, drafter,
                                          verifier):
    """Paged × int8-KV: the scale pools ride the same block layout and
    the composition stays bit-identical (chain and tree routes)."""
    scfg = SpecConfig(temperature=0.0, gamma=3, tree_branches=(2, 1, 1))
    reqs = _requests(model_int8.cfg, temps=(0.0, 0.8))
    r0, r1 = _serve_both_layouts(model_int8, params, drafter, verifier,
                                 scfg, reqs, block_size=8)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_paged_small_pool_serializes_but_stays_exact(model, params):
    """A pool too small for two concurrent requests degrades to
    sequential serving (admission waits on block reservations) without
    changing a single token."""
    scfg = SpecConfig(temperature=0.0, gamma=3)
    reqs = _requests(model.cfg)
    demand = max(blocks_for_tokens(
        request_demand_tokens(r.prompt.size, r.max_new_tokens, 3), 8)
        for r in reqs)
    scp = dataclasses.replace(scfg, kv_layout="paged", kv_block_size=8,
                              kv_pool_blocks=demand + 1)   # fits ONE at a time
    eng = SpecEngine(model, scp, verifier="bf16")
    r1 = eng.generate_requests(params, reqs, batch_slots=2)
    base = SpecEngine(model, scfg, verifier="bf16")
    r0 = base.generate_requests(params, reqs, batch_slots=2)
    for a, b in zip(r0, r1):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_paged_request_larger_than_pool_raises(model, params):
    scfg = SpecConfig(temperature=0.0, gamma=3, kv_layout="paged",
                      kv_block_size=8, kv_pool_blocks=3)
    eng = SpecEngine(model, scfg, verifier="bf16")
    with pytest.raises(ValueError, match="exceeds pool capacity"):
        eng.generate_requests(params, _requests(model.cfg), batch_slots=2)


# ---------------------------------------------------------------------------
# 5. Admission-aware slot sizing (dynamic batch_slots)
# ---------------------------------------------------------------------------

def test_plan_group_dynamic_slots():
    """Pool occupancy drives the slot count: short-request mixes get more
    concurrent rows than the contiguous default out of the same
    capacity; a forced batch_slots is respected; oversized requests are
    rejected up front."""
    lens, buds = [16] * 12, [8] * 12
    plan = plan_group(lens, buds, gamma=3, buf=32, block_size=8,
                      default_slots=2)
    # default pool = 2 worst-case demands => dynamic slots still 2
    assert plan.slots == 2
    # triple the pool: occupancy-derived slots grow past the default
    big = plan_group(lens, buds, gamma=3, buf=32, block_size=8,
                     pool_blocks=3 * (plan.num_blocks - 1) + 1,
                     default_slots=2)
    assert big.slots == 6
    forced = plan_group(lens, buds, gamma=3, buf=32, block_size=8,
                        pool_blocks=big.num_blocks, batch_slots=3)
    assert forced.slots == 3
    with pytest.raises(ValueError, match="exceeds pool capacity"):
        plan_group([400], [100], gamma=3, buf=512, block_size=8,
                   pool_blocks=4)


def test_paged_dynamic_slots_served_in_parallel(model, params):
    """With no forced batch_slots, a short-request mix is served on
    occupancy-derived slots (> the request count here, so one wave) and
    stays solo-exact."""
    reqs = _requests(model.cfg)
    scfg = SpecConfig(temperature=0.0, gamma=3, kv_layout="paged",
                      kv_block_size=8)
    eng = SpecEngine(model, scfg, verifier="bf16")
    r1 = eng.generate_requests(params, reqs)    # dynamic slots
    base = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                      verifier="bf16")
    for req, res in zip(reqs, r1):
        solo = base.generate_requests(params, [
            GenerationRequest(req.prompt, req.max_new_tokens,
                              seed=req.seed)], batch_slots=1)[0]
        np.testing.assert_array_equal(res.tokens, solo.tokens)


# ---------------------------------------------------------------------------
# 6. Gating: the layouts that cannot page
# ---------------------------------------------------------------------------

def test_paged_rejects_recurrent_and_ring():
    scfg = SpecConfig(temperature=0.0, gamma=2, kv_layout="paged")
    req = [GenerationRequest(np.arange(2, 8), max_new_tokens=2, seed=0)]
    ssm = Model(get_config("mamba2-370m").reduced())
    with pytest.raises(ValueError, match="recurrent"):
        SpecEngine(ssm, scfg, verifier="bf16").generate_requests(
            ssm.init_params(jax.random.PRNGKey(0)), req)
    ring_cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), sliding_window=32)
    ring = Model(ring_cfg)
    with pytest.raises(ValueError, match="sliding-window"):
        SpecEngine(ring, scfg, verifier="bf16").generate_requests(
            ring.init_params(jax.random.PRNGKey(0)), req)
    with pytest.raises(ValueError, match="kv_layout"):
        SpecEngine(ssm, dataclasses.replace(scfg, kv_layout="ringed"))
