"""Prefix caching + copy-on-write sharing + preemption/swap harness.

Locks down the refcounted prefix cache (``core/paged_cache.PrefixIndex``
+ sharing ``BlockPool``) and the scheduler's preemption-and-swap path
(``PagedGroup.preempt`` / resume) behind three layers:

1. **Scheduler fuzz harness** — the batch ``Scheduler.run`` path admits
   in key order, so preemption structurally never fires there; these
   tests drive ``Scheduler.tick`` directly with mid-loop submissions
   (the open-loop front-end's shape) so a better-keyed arrival really
   does evict a running victim to the host swap pool.  The bar is the
   same as PRs 2-5: every request's tokens are **bit-identical** to the
   contiguous scheduler run (and therefore, transitively, to solo
   serving) for every drafter × verifier at T=0 and T>0 — through
   sharing, boundary COW forks, eviction and bit-exact resume — and the
   jitted decode step compiles exactly once (swap-in never retraces).
2. **Allocator property suite** (hypothesis, model-free): arbitrary
   admit/share/fork/swap/release interleavings over a shared-prefix
   prompt universe conserve the pool exactly, never free a block
   another request still references, and never touch the scratch block.
3. **Data-plane units**: COW forks never mutate the shared original,
   host swap round-trips are bit-exact for bf16 and int8 (including the
   f32 scale pools), and a release racing an eviction frees blocks
   exactly once (the double-free regression).
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import SpecConfig
from repro.core.paged_cache import (
    SCRATCH_BLOCK,
    BlockPool,
    PrefixIndex,
    blocks_for_tokens,
    clone_block,
    init_paged_cache,
    plan_group,
    request_demand_tokens,
    swap_in_blocks,
    swap_out_blocks,
)
from repro.core.spec_engine import init_state
from repro.models import Model
from repro.serving import GenerationRequest, SpecEngine
from repro.serving.faults import FaultPlan, InjectedFault
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


BS = 8          # paged block size under test (non-power-of-round prompts)
BASE_SCFG = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5,
                       tree_branches=(2, 1, 1))

ALL_COMBOS = [
    ("ngram", "bf16"), ("ngram", "w8a8"),
    ("vanilla", "bf16"), ("vanilla", "w8a8"),
    ("pruned", "bf16"), ("pruned", "w8a8"),
    ("ngram-tree", "bf16"), ("ngram-tree", "w8a8"),
]


# ---------------------------------------------------------------------------
# Manually-driven paged serving loop
# ---------------------------------------------------------------------------

class Harness:
    """Drive ``Scheduler.tick`` by hand over the paged serving state.

    Mirrors ``SpecEngine.generate_requests``'s paged setup exactly
    (plan → ``PagedGroup`` → paged cache → ``init_state``) but exposes
    mid-loop ``submit`` so better-keyed arrivals can trigger the
    preemption hook — which the batch ``run()`` path never does.
    """

    def __init__(self, model, params, reqs, *, drafter, verifier, temp,
                 slots=2, pool_blocks=None, sharing=True):
        demands = [blocks_for_tokens(
            request_demand_tokens(r.prompt.size, r.max_new_tokens, 8), BS)
            for r in reqs]
        if pool_blocks is None:
            pool_blocks = 1 + max(demands) + 2
        scp = dataclasses.replace(
            BASE_SCFG, temperature=temp, kv_layout="paged",
            kv_block_size=BS, kv_pool_blocks=pool_blocks,
            kv_prefix_sharing=sharing)
        self.eng = SpecEngine(model, scp, drafter=drafter,
                              verifier=verifier)
        self.params = self.eng._prepare_cached(params)
        self._step, self.drafter = self.eng._step_for_temperature(temp)
        self.reqs = list(reqs)
        self.pmax = max(r.prompt.size for r in reqs)
        buf = max(r.prompt.size + r.max_new_tokens for r in reqs) \
            + self.drafter.gamma + 2
        plan = plan_group([r.prompt.size for r in reqs],
                          [r.max_new_tokens for r in reqs],
                          self.drafter.gamma, buf, block_size=BS,
                          pool_blocks=pool_blocks, batch_slots=slots)
        self.ctx = self.eng.paged_group(num_blocks=plan.num_blocks,
                                        block_size=plan.block_size,
                                        gamma=self.drafter.gamma)
        cache = init_paged_cache(model.cfg, slots, plan.max_blocks,
                                 plan.num_blocks, plan.block_size)
        self.state = init_state(
            model, slots, buf, jnp.zeros((slots, 2), jnp.uint32),
            drafter_state=self.drafter.alloc_state(model, self.params,
                                                   slots, buf),
            target=jnp.zeros((slots,), jnp.int32), cache=cache)
        self.sched = Scheduler([], slots)

    def submit(self, j: int) -> int:
        i = self.sched.submit(self.reqs[j])
        self.ctx.register(i, self.reqs[j])
        return i

    def tick(self):
        def admit(st, slot, i):
            return self.ctx.admit(st, slot, i, params=self.params,
                                  pmax=self.pmax, drafter=self.drafter)

        self.state, done = self.sched.tick(
            self.state, admit=admit,
            step=lambda st: self._step(self.params,
                                       self.ctx.prepare_step(st)),
            can_admit=self.ctx.can_admit, release=self.ctx.release,
            preempt=self.ctx.preempt)
        self.ctx.check_invariants()
        return done

    def drain(self, max_ticks=300):
        for _ in range(max_ticks):
            if not self.sched.busy:
                return
            self.tick()
        raise AssertionError("scheduler failed to drain")


def _reference(model, params, reqs, *, drafter, verifier, temp):
    """Solo-equivalent tokens: the contiguous scheduler run (bit-equal
    to solo serving by tests/test_continuous_batching.py)."""
    eng = SpecEngine(model, dataclasses.replace(BASE_SCFG,
                                                temperature=temp),
                     drafter=drafter, verifier=verifier)
    return eng.generate_requests(params, reqs, batch_slots=2)


def _preempt_workload(cfg):
    """A victim that fills the pool + a shared-prefix family that must
    evict it: the victim (worst key) has the strictly-largest demand,
    so ``Harness`` sizes the pool to it and the family's head is denied
    while the victim runs."""
    rng = np.random.default_rng(17)
    pat = rng.integers(0, cfg.vocab_size, 6)
    other = rng.integers(0, cfg.vocab_size, 18)
    victim = GenerationRequest(other, max_new_tokens=10, seed=1,
                               priority=2)
    fam = [GenerationRequest(np.tile(pat, 2), max_new_tokens=4, seed=2),
           GenerationRequest(np.concatenate([np.tile(pat, 2), pat[:3]]),
                             max_new_tokens=5, seed=3)]
    return [victim] + fam


@pytest.mark.parametrize("drafter,verifier", ALL_COMBOS)
def test_preempt_resume_bit_identity_all_combos(model, params, drafter,
                                                verifier):
    """The headline bar: a running low-priority request is preempted
    mid-decode (blocks swapped to host memory), higher-priority
    shared-prefix arrivals are served through the freed blocks, the
    victim resumes — and every request's tokens are bit-identical to
    the no-preemption contiguous run, at T=0 and T>0, with exactly one
    decode-step compile (swap-in never retraces)."""
    reqs = _preempt_workload(model.cfg)
    for temp in (0.0, 0.8):
        h = Harness(model, params, reqs, drafter=drafter,
                    verifier=verifier, temp=temp)
        # pool = victim demand + donate headroom: victim alone fills it
        d_vic = blocks_for_tokens(request_demand_tokens(
            reqs[0].prompt.size, reqs[0].max_new_tokens,
            h.drafter.gamma), BS)
        h = Harness(model, params, reqs, drafter=drafter,
                    verifier=verifier, temp=temp,
                    pool_blocks=1 + d_vic + 1)
        h.submit(0)
        h.tick()                      # victim admitted, starts decoding
        h.tick()                      # ... and commits some tokens
        h.submit(1)
        h.submit(2)
        h.drain()
        assert h.sched.preemptions >= 1
        assert h.ctx.swaps >= 1
        assert h.ctx.shared_blocks >= 1       # family shared the prefix
        assert not h.ctx.swap                 # victim resumed + finished
        ref = _reference(model, params, reqs, drafter=drafter,
                         verifier=verifier, temp=temp)
        for i, r in enumerate(ref):
            got = h.sched.results[i]
            assert got.tokens.size == reqs[i].max_new_tokens
            np.testing.assert_array_equal(got.tokens, r.tokens)
        # swap-in is pure host work: one compile for the whole episode
        assert h.eng.step_traces == 1
        # drained pool: every block back (free or cached), none leaked
        assert h.ctx.pool.unique_allocated == 0
        assert h.ctx.pool.free_blocks == h.ctx.pool.capacity


def _fuzz_universe(cfg, rng):
    """Mixed workload: two shared-prefix families (incl. an exact
    duplicate prompt and a boundary-LCP tail) + unrelated prompts,
    random priorities and budgets."""
    a = rng.integers(0, cfg.vocab_size, 8)
    b = rng.integers(0, cfg.vocab_size, 8)
    prompts = [
        np.tile(a, 2),                                # family A
        np.tile(a, 2),                                # exact duplicate
        np.concatenate([np.tile(a, 2), a[:5]]),       # A + boundary tail
        np.tile(b, 2),                                # family B
        np.concatenate([b, b[:4]]),                   # B, shorter chain
        rng.integers(0, cfg.vocab_size, 14),          # cold
    ]
    return [GenerationRequest(p, max_new_tokens=int(rng.integers(3, 7)),
                              seed=i, priority=int(rng.integers(0, 3)))
            for i, p in enumerate(prompts)]


@pytest.mark.parametrize("seed,drafter,verifier,temp", [
    (0, "ngram", "bf16", 0.0),
    (1, "ngram", "bf16", 0.0),
    (2, "pruned", "bf16", 0.0),
    (3, "vanilla", "w8a8", 0.8),
])
def test_scheduler_fuzz_random_interleavings(model, params, seed, drafter,
                                             verifier, temp):
    """Seeded random interleavings of submit/step over a tight pool:
    arrival order, gaps and priorities are random, so admission, prefix
    hits, boundary forks, preemption and resume interleave arbitrarily —
    tokens must still be bit-identical per request to the contiguous
    run, with pool invariants checked after every tick."""
    rng = np.random.default_rng(100 + seed)
    reqs = _fuzz_universe(model.cfg, rng)
    h = Harness(model, params, reqs, drafter=drafter, verifier=verifier,
                temp=temp)
    order = rng.permutation(len(reqs))
    k = 0
    while k < len(order) or h.sched.busy:
        while k < len(order) and rng.random() < 0.55:
            h.submit(int(order[k]))
            k += 1
        if k < len(order) and not h.sched.busy:
            continue                  # nothing running: submit more
        h.tick()
    assert len(h.sched.results) == len(reqs)
    ref = _reference(model, params, reqs, drafter=drafter,
                     verifier=verifier, temp=temp)
    for j, i in enumerate(order):     # results keyed by submission index
        got = h.sched.results[j]
        np.testing.assert_array_equal(got.tokens, ref[int(i)].tokens)
    assert h.eng.step_traces == 1
    assert h.ctx.pool.unique_allocated == 0


def test_unshared_paged_run_unchanged(model, params):
    """kv_prefix_sharing=False collapses to PR 5's reservation formulas:
    the manual harness serves the shared-prefix workload with zero
    index hits and the same tokens."""
    reqs = _preempt_workload(model.cfg)
    h = Harness(model, params, reqs, drafter="ngram", verifier="bf16",
                temp=0.0, sharing=False)
    for j in range(len(reqs)):
        h.submit(j)
    h.drain()
    assert h.ctx.shared_blocks == 0 and h.ctx.index is None
    ref = _reference(model, params, reqs, drafter="ngram",
                     verifier="bf16", temp=0.0)
    for i, r in enumerate(ref):
        np.testing.assert_array_equal(h.sched.results[i].tokens, r.tokens)


def test_serving_loop_paged_lane_preempts_and_stays_exact(model, params):
    """Open-loop front-end over a paged lane: a later better-keyed
    arrival really preempts the running low-priority request via the
    swap pool, and every request's tokens still match the batch engine
    path bit-for-bit."""
    from repro.serving.server import ServerConfig, ServingLoop
    scp = dataclasses.replace(BASE_SCFG, kv_layout="paged",
                              kv_block_size=BS, kv_pool_blocks=8)
    eng = SpecEngine(model, scp, drafter="ngram", verifier="bf16")
    reqs = _preempt_workload(model.cfg)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=2, max_prompt_len=24,
                                    max_new_tokens=16),
                       clock=lambda: clock[0])
    handles = [loop.submit(reqs[0])]
    for _ in range(2):                      # victim admitted + decoding
        loop.poll()
        clock[0] += 0.25
    handles += [loop.submit(r) for r in reqs[1:]]
    polls = 0
    while loop.busy:
        loop.poll()
        clock[0] += 0.25
        polls += 1
        assert polls < 500
    lane = next(iter(loop._lanes.values()))
    assert lane.ctx is not None and lane.ctx.swaps >= 1
    assert lane.sched.preemptions >= 1
    loop.metrics.check_conservation()
    expected = _reference(model, params, reqs, drafter="ngram",
                          verifier="bf16", temp=0.0)
    for h, res in zip(handles, expected):
        assert h.status == "done"
        got = h.result(timeout=0.0)
        np.testing.assert_array_equal(got.tokens, res.tokens)
        np.testing.assert_array_equal(h.collected(), got.tokens)


def _faulted_paged_loop(model, params, *, spec, batch_slots):
    from repro.serving.server import ServerConfig, ServingLoop
    scp = dataclasses.replace(BASE_SCFG, kv_layout="paged",
                              kv_block_size=BS, kv_pool_blocks=12)
    eng = SpecEngine(model, scp, drafter="ngram", verifier="bf16")
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=batch_slots,
                                    max_prompt_len=24, max_new_tokens=16),
                       clock=lambda: clock[0],
                       faults=FaultPlan.parse(spec, seed=0))
    return eng, loop, clock


def _drain_loop(loop, clock):
    polls = 0
    while loop.busy:
        loop.poll()
        clock[0] += 0.25
        polls += 1
        assert polls < 500, "loop failed to drain (deadlock?)"


def test_alloc_failure_mid_admission_is_contained(model, params):
    """Injected ``BlockPool.alloc`` failure during the *first* admission
    fails that request alone: the second request's tokens are
    bit-identical to a fault-free run, the pool conserves exactly (no
    leaked blocks from the aborted admission), and the failure is
    visible in the robustness counters."""
    rng = np.random.default_rng(5)
    reqs = [GenerationRequest(rng.integers(0, model.cfg.vocab_size, 9),
                              max_new_tokens=8, seed=s) for s in (1, 2)]
    eng, loop, clock = _faulted_paged_loop(model, params, spec="alloc@0",
                                           batch_slots=2)
    handles = [loop.submit(r) for r in reqs]
    _drain_loop(loop, clock)
    assert handles[0].status == "failed"
    with pytest.raises(InjectedFault, match="alloc failure"):
        handles[0].result(timeout=0.0)
    ref = _reference(model, params, reqs, drafter="ngram",
                     verifier="bf16", temp=0.0)
    np.testing.assert_array_equal(handles[1].result(timeout=0.0).tokens,
                                  ref[1].tokens)
    lane = next(iter(loop._lanes.values()))
    lane.ctx.pool.check_invariants()
    assert lane.ctx.pool.unique_allocated == 0          # nothing leaked
    loop.metrics.check_conservation()
    assert loop.metrics.summary()["robustness"]["request_faults"] == 1


def test_alloc_failure_mid_append_is_contained(model, params):
    """Injected alloc failure during the decode-growth top-up
    (``_append_paged_blocks``): the growing request fails mid-service
    with its blocks returned (including the admission-time ones), and a
    queued request then runs to a bit-identical completion in the same
    lane."""
    rng = np.random.default_rng(6)
    reqs = [GenerationRequest(rng.integers(0, model.cfg.vocab_size, 9),
                              max_new_tokens=16, seed=s) for s in (3, 4)]
    # alloc calls: 0 = first admission, 1 = its first append top-up
    eng, loop, clock = _faulted_paged_loop(model, params, spec="alloc@1",
                                           batch_slots=1)
    handles = [loop.submit(r) for r in reqs]
    _drain_loop(loop, clock)
    assert handles[0].status == "failed"
    with pytest.raises(InjectedFault, match="alloc failure"):
        handles[0].result(timeout=0.0)
    ref = _reference(model, params, reqs, drafter="ngram",
                     verifier="bf16", temp=0.0)
    np.testing.assert_array_equal(handles[1].result(timeout=0.0).tokens,
                                  ref[1].tokens)
    lane = next(iter(loop._lanes.values()))
    lane.ctx.pool.check_invariants()
    assert lane.ctx.pool.unique_allocated == 0
    loop.metrics.check_conservation()
    assert loop.metrics.summary()["robustness"]["request_faults"] == 1


# ---------------------------------------------------------------------------
# Allocator property suite (hypothesis)
# ---------------------------------------------------------------------------

_BSP = 4
_PROMPTS = [
    np.array([1, 2, 3, 4, 5, 6, 7, 8, 9]),        # 2-block chain
    np.array([1, 2, 3, 4, 5, 6, 7, 8, 9]),        # exact duplicate
    np.array([1, 2, 3, 4, 5, 6, 7, 8, 10, 11]),   # boundary LCP
    np.array([1, 2, 3, 4, 5, 6]),                 # shorter, same chain
    np.array([9, 8, 7, 6, 5, 4, 3]),              # unrelated
    np.array([9, 8, 7, 6, 5, 4, 3, 2, 1, 0, 1]),  # unrelated, longer
]


def _admit(pool, index, rid, prompt):
    """Mirror ``PagedGroup``'s admission arithmetic at the pool level
    (no device arrays): probe → share → boundary fork → fresh alloc →
    register.  Returns False when the pool cannot admit."""
    d = pool.blocks_for(prompt.size + 3)          # +small decode budget
    ids, rows = index.lookup(prompt)
    n_res = sum(1 for b in ids if pool.ref(b) == 0)
    fork = 1 if ids and rows % _BSP != 0 else 0
    need = d - len(ids) + fork
    if not (ids and pool.can_reserve(need + n_res)):
        ids, rows, n_res, fork = [], 0, 0, 0
        need = d
    if not pool.can_reserve(need + n_res):
        return False
    pool.reserve(rid, need)
    if ids:
        pool.share(rid, ids)
    if fork:
        old = pool.owned(rid)[len(ids) - 1]
        new = pool.cow(rid, old)
        if new == old:                # sole owner: stale entry evicted
            index.evict_block(old)
    pool.alloc(rid, d - len(ids))
    index.register(prompt, pool.owned(rid))
    return True


@given(ops=st.lists(st.tuples(st.integers(0, 4),   # admit/release/swap/
                              #                      resume/failing-admit
                              st.integers(0, 4),   # request id
                              st.integers(0, len(_PROMPTS) - 1)),
                    min_size=1, max_size=60),
       num_blocks=st.integers(6, 24))
@settings(max_examples=60, deadline=None)
def test_pool_sharing_invariants_property(ops, num_blocks):
    """Property: under ANY admit/share/fork/swap/release interleaving
    over a shared-prefix prompt universe —

    * ``free + cached + unique_allocated == capacity`` after every op;
    * no block is freed while another request still references it;
    * the scratch block is never shared, allocated or cached;
    * a swapped request's release frees nothing (exactly-once);
    * an admission whose ``alloc`` raises (injected allocator failure)
      leaks nothing after the exactly-once containment release.
    """
    index = PrefixIndex(_BSP)
    pool = BlockPool(num_blocks, _BSP, prefix=index)
    active, swapped = {}, {}
    for kind, rid, pi in ops:
        if kind == 0 and rid not in active and rid not in swapped:
            if _admit(pool, index, rid, _PROMPTS[pi]):
                active[rid] = set(pool.owned(rid))
        elif kind == 1 and rid in active:
            mine = active.pop(rid)
            theirs = {b for r, s in active.items() for b in s}
            freed = pool.release(rid)
            assert set(freed) <= mine
            for b in freed:
                assert pool.ref(b) == 0
            for b in mine & theirs:   # still referenced elsewhere
                assert pool.ref(b) >= 1
        elif kind == 1 and rid in swapped:
            assert pool.release(rid) == []       # exactly-once
            swapped.pop(rid)
        elif kind == 2 and rid in active:
            n = len(pool.owned(rid))
            pool.swap_out(rid)
            active.pop(rid)
            swapped[rid] = n
        elif kind == 3 and rid in swapped:
            n = swapped[rid]
            if pool.can_reserve(n):
                pool.reserve(rid, n)
                pool.alloc(rid, n)
                active[rid] = set(pool.owned(rid))
                swapped.pop(rid)
        elif kind == 4 and rid not in active and rid not in swapped:
            # failing-alloc rule: the allocator raises mid-admission
            # (after probe/share/fork may already hold blocks).  The
            # containment path releases the partial reservation once;
            # a second release must be a no-op (no double-free).
            def _boom(n):
                raise InjectedFault("injected alloc failure")
            pool.fault_hook = _boom
            try:
                if _admit(pool, index, rid, _PROMPTS[pi]):
                    # admission needed zero fresh draws (fully shared):
                    # the hook never fired and the request is live
                    active[rid] = set(pool.owned(rid))
            except InjectedFault:
                pool.release(rid)
                assert pool.release(rid) == []     # exactly-once
            finally:
                pool.fault_hook = None
        pool.check_invariants()
        assert pool.free_blocks + pool.unique_allocated == pool.capacity
        for r in active:
            assert SCRATCH_BLOCK not in pool.owned(r)


def test_scratch_block_never_shared_or_indexed():
    index = PrefixIndex(_BSP)
    pool = BlockPool(6, _BSP, prefix=index)
    pool.reserve(0, 3)
    with pytest.raises(ValueError, match="scratch"):
        pool.share(0, [SCRATCH_BLOCK])
    ids = pool.alloc(0, 3)
    assert SCRATCH_BLOCK not in ids
    prompt = np.arange(1, 11)
    index.register(prompt, ids)
    got, rows = index.lookup(prompt)
    assert SCRATCH_BLOCK not in got and rows == prompt.size - 1


# ---------------------------------------------------------------------------
# Data-plane units: COW isolation, swap round-trip, double-free regression
# ---------------------------------------------------------------------------

def _filled_layers(cfg, num_blocks, rng):
    layers = init_paged_cache(cfg, 1, 4, num_blocks, _BSP)["layers"]
    def fill(x):
        if x.dtype == jnp.int8:
            return jnp.asarray(rng.integers(-128, 128, x.shape), jnp.int8)
        return jnp.asarray(rng.standard_normal(x.shape), x.dtype)
    return jax.tree.map(fill, layers)


def test_cow_fork_never_mutates_shared_block(model):
    """COW isolation: after a sharer forks and rewrites its copy, the
    original block's bytes (and the other owner's view) are untouched."""
    rng = np.random.default_rng(0)
    index = PrefixIndex(_BSP)
    pool = BlockPool(6, _BSP, prefix=index)
    pool.reserve(0, 2)
    b = pool.alloc(0, 1)[0]
    pool.reserve(1, 2)
    pool.share(1, [b])                   # ref(b) == 2
    layers = _filled_layers(model.cfg, 6, rng)
    before = [np.asarray(pl["k"][b]).copy() for pl in layers]
    new = pool.cow(1, b)
    assert new != b and pool.ref(b) == 1 and pool.ref(new) == 1
    layers = clone_block(layers, b, new)
    layers = [dict(pl, k=pl["k"].at[new].set(0.0)) for pl in layers]
    for pl, snap in zip(layers, before):
        np.testing.assert_array_equal(np.asarray(pl["k"][b]), snap)
    assert pool.owned(0) == [b] and pool.owned(1) == [new]
    pool.check_invariants()


@pytest.mark.parametrize("kv", ["bf16", "int8"])
def test_swap_roundtrip_bit_exact(model, kv):
    """Host swap round-trip is bit-exact for both KV dtypes — int8
    includes the f32 k_scale/v_scale pools."""
    cfg = dataclasses.replace(model.cfg, kv_cache_dtype=kv)
    rng = np.random.default_rng(1)
    layers = _filled_layers(cfg, 8, rng)
    if kv == "int8":
        assert "k_scale" in layers[0] and "v_scale" in layers[0]
    ids = [2, 5, 7]
    before = [{k: np.asarray(v)[np.asarray(ids)].copy()
               for k, v in pl.items()} for pl in layers]
    host = swap_out_blocks(layers, ids)
    # the pool reuses the blocks for someone else meanwhile
    layers = [{k: v.at[jnp.asarray(ids)].set(0) for k, v in pl.items()}
              for pl in layers]
    layers = swap_in_blocks(layers, ids, host)
    for pl, snap in zip(layers, before):
        for name, want in snap.items():
            np.testing.assert_array_equal(
                np.asarray(pl[name])[np.asarray(ids)], want)


def test_release_after_swap_out_frees_exactly_once():
    """Regression: a finish/shed racing an eviction must not double-free.
    ``swap_out`` already returned the blocks; the subsequent ``release``
    returns ``[]`` and the free list holds each block exactly once."""
    index = PrefixIndex(_BSP)
    pool = BlockPool(8, _BSP, prefix=index)
    pool.reserve(7, 3)
    ids = pool.alloc(7, 3)
    assert sorted(pool.swap_out(7)) == sorted(ids)
    assert pool.free_blocks == pool.capacity
    assert pool.release(7) == []                 # the racing release
    pool.check_invariants()
    assert pool.free_blocks == pool.capacity
    # no duplicate free-list entries: two admissions get disjoint blocks
    pool.reserve(1, 4)
    a = pool.alloc(1, 4)
    pool.reserve(2, 3)
    b = pool.alloc(2, 3)
    assert len(set(a) | set(b)) == 7 and not set(a) & set(b)
    pool.release(1)
    pool.release(2)
    # the swapped mark was consumed: a resumed request releases normally
    pool.reserve(7, 2)
    c = pool.alloc(7, 2)
    assert sorted(pool.release(7)) == sorted(c)
    pool.check_invariants()
