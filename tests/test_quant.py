"""SmoothQuant calibration + quantization invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.configs import get_config
from repro.core.config import QuantConfig
from repro.kernels.ref import quantize_symmetric
from repro.models import Model
from repro.quant import quantize_params
from repro.quant.smoothquant import smoothing_factors


@settings(max_examples=20, deadline=None)
@given(
    din=st.integers(2, 64),
    dout=st.integers(2, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_smoothing_invariance(din, dout, seed):
    """(W diag(s)^-1)(diag(s) X) == W X exactly in fp (paper Eq. 4)."""
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = jax.random.normal(k0, (din, dout))
    x = jax.random.normal(k1, (5, din))
    amax = jnp.abs(jax.random.normal(k2, (din,))) + 0.1
    s = smoothing_factors(w, amax, alpha=0.5)
    y1 = x @ w
    y2 = (x * s) @ (w / s[:, None])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 64))
def test_quantize_symmetric_error_bound(seed, n):
    """|x - dequant(quant(x))| <= Δ/2 per element (uniform quantizer)."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 32)) * 4.0
    q, scale = quantize_symmetric(x, axis=0)
    deq = q.astype(jnp.float32) * scale[None, :]
    err = jnp.abs(deq - x)
    assert bool(jnp.all(err <= scale[None, :] * 0.5 + 1e-6))


def test_quantize_params_structure():
    cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    q = quantize_params(params, None, QuantConfig())
    l0 = q["layers"][0]
    # attention linears quantized
    assert "w_int8" in l0["attn"]["q"] and l0["attn"]["q"]["w_int8"].dtype == jnp.int8
    assert l0["attn"]["q"]["w_scale"].shape == (cfg.q_dim,)
    assert l0["attn"]["q"]["smooth"].shape == (cfg.d_model,)
    # expert tensors quantized per-expert
    assert l0["moe"]["up"]["w_int8"].shape == (cfg.num_experts, cfg.d_model, cfg.moe_d_ff)
    assert l0["moe"]["up"]["w_scale"].shape == (cfg.num_experts, cfg.moe_d_ff)
    # router and embeddings stay high precision
    assert "w_int8" not in l0["moe"]["router"]
    assert "w_int8" not in q["embed"]
    # norms untouched
    assert "scale" in q["final_norm"]


def test_calibrated_quantization_improves_or_matches_fidelity():
    """Calibrated smoothing should not be worse than s=1 on model KL."""
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(5), (4, 48), 0, cfg.vocab_size)

    collect = {}
    m.forward(params, toks, collect=collect)
    assert len(collect) > 0
    q_cal = quantize_params(params, collect, QuantConfig())
    q_raw = quantize_params(params, None, QuantConfig())

    lf, _ = m.forward(params, toks)
    def kl(qp):
        lq, _ = m.forward(qp, toks)
        p = jax.nn.softmax(lf, -1)
        return float(jnp.mean(jnp.sum(
            p * (jnp.log(p + 1e-9) - jax.nn.log_softmax(lq, -1)), -1)))
    kl_cal, kl_raw = kl(q_cal), kl(q_raw)
    assert kl_cal < 0.05 and kl_raw < 0.05
    # calibration is not catastrophically worse (both KLs are ~1e-5 noise on
    # a random-init model; the margin only guards against gross regressions)
    assert kl_cal <= kl_raw * 3.0 + 1e-4


def test_quantized_model_memory_halved():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    q = quantize_params(params, None, QuantConfig())

    def linear_bytes(t):
        return sum(
            x.size * x.dtype.itemsize
            for x in jax.tree.leaves(t)
            if hasattr(x, "dtype") and x.ndim >= 2
        )
    # int8 linears ≈ half the bf16/f32 source (f32 smoke params → ~4x)
    assert linear_bytes(q["layers"]) < 0.6 * linear_bytes(params["layers"])
