"""Scan (stacked layer groups) vs canonical loop layout equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models.scan import scan_pattern, stack_cache, unstack_cache

FAMS = ["smollm-135m", "phi3.5-moe-42b-a6.6b", "mamba2-370m", "zamba2-2.7b",
        "llama-3.2-vision-90b", "whisper-small"]


def _aux(cfg, B):
    n = cfg.num_image_tokens or cfg.num_audio_frames
    if not n:
        return None
    return jax.random.normal(jax.random.PRNGKey(9), (B, n, cfg.d_model), cfg.dtype)


@pytest.mark.parametrize("arch", FAMS)
def test_scan_equals_loop(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    sparams = m.to_scan(params)
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab_size)
    aux = _aux(cfg, B)

    l1, _ = m.forward(params, toks, aux_embeds=aux)
    l2, _ = m.forward(sparams, toks, aux_embeds=aux)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4, atol=2e-4)

    # cached verify path
    cache = m.init_cache(B, 64)
    scache = m.init_cache(B, 64, scan=True)
    cache = m.prefill(params, cache, toks[:, :5], aux_embeds=aux)
    scache = m.prefill(sparams, scache, toks[:, :5], aux_embeds=aux)
    start = jnp.full((B,), 5, jnp.int32)
    lv1, cand1 = m.verify_step(params, cache, toks[:, 5:8], start)
    lv2, cand2 = m.verify_step(sparams, scache, toks[:, 5:8], start)
    np.testing.assert_allclose(np.asarray(lv1), np.asarray(lv2), rtol=2e-4, atol=2e-4)

    # commit keeps layouts equivalent
    n_last = jnp.array([0, 2], jnp.int32)
    c1 = m.commit(cand1, n_last)
    c2 = m.commit(cand2, n_last)
    c2u = unstack_cache(c2, cfg)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2u)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-4, atol=2e-4)


def test_scan_pattern_shapes():
    assert scan_pattern(get_config("smollm-135m")) == (["dense"], 30, False)
    assert scan_pattern(get_config("mamba2-370m")) == (["ssm"], 48, False)
    p, n, sh = scan_pattern(get_config("zamba2-2.7b"))
    assert p == ["ssm"] * 6 and n == 9 and sh
    p, n, sh = scan_pattern(get_config("llama-3.2-vision-90b"))
    assert p == ["dense"] * 4 + ["cross"] and n == 20 and not sh
    assert scan_pattern(get_config("whisper-small")) == (["audio"], 12, False)
    assert scan_pattern(get_config("arctic-480b")) == (["moe"], 35, False)


def test_stack_unstack_roundtrip():
    cfg = get_config("zamba2-2.7b").reduced()
    m = Model(cfg)
    cache = m.init_cache(2, 32)
    rt = unstack_cache(stack_cache(cache, cfg), cfg)
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(rt)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scan_train_step_runs():
    from repro.train.optimizer import AdamWConfig, adamw_init
    from repro.train.trainer import make_train_step

    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.to_scan(m.init_params(jax.random.PRNGKey(0)))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(), remat=True, scan=True))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    p2, o2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
