"""Serving front-end: per-token streaming, SLO-aware admission, shedding.

Three layers under test:

* **scheduler** (model-free) — EDF admission order, deadline shedding,
  the ``completed + shed == submitted`` conservation property, and the
  bounded-events audit trail;
* **engine** — the streaming contract (per-request deltas concatenate
  bit-identically to the blocking result) for every drafter × verifier,
  at T=0 and T>0, and EDF-vs-FIFO token invariance;
* **server** — the ServingLoop on a virtual clock (deterministic
  shedding, degrade-to-chain) and the threaded StreamingServer
  end-to-end.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core import SpecConfig
from repro.models import Model
from repro.serving import (
    GenerationRequest,
    GenResult,
    RequestResult,
    RequestTimeline,
    ServerConfig,
    ServerMetrics,
    ServingLoop,
    SpecEngine,
    StreamingServer,
    safe_rate,
)
from repro.serving.scheduler import Scheduler


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


def _requests(cfg, *, seed=3, spec=((5, 6, 11), (4, 9, 22), (3, 7, 33),
                                    (2, 5, 44))):
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6)
    return [GenerationRequest(np.tile(pat, k), max_new_tokens=n, seed=s)
            for k, n, s in spec]


# ---------------------------------------------------------------------------
# Rate guards (satellite: zero wall/service time must not crash or spike)
# ---------------------------------------------------------------------------

def test_safe_rate_guards():
    assert safe_rate(10, 2.0) == 5.0
    assert safe_rate(10, 0.0) == 0.0
    assert safe_rate(10, -1.0) == 0.0
    assert safe_rate(0, 0.0) == 0.0


def test_gen_result_rate_zero_wall():
    r = GenResult(tokens=jnp.zeros((1, 4), jnp.int32),
                  lengths=jnp.ones((1,), jnp.int32),
                  mean_accept_len=1.0, steps=1, wall_s=0.0, new_tokens=4)
    assert r.tokens_per_s == 0.0


def test_request_result_rate_zero_service():
    req = GenerationRequest(np.arange(4), max_new_tokens=3)
    r = RequestResult(request=req, tokens=np.ones((3,), np.int32),
                      prompt_len=4, accept_len=1.0, steps=3,
                      queue_s=0.0, service_s=0.0)
    assert r.tokens_per_s == 0.0
    assert r.wall_s == 0.0


# ---------------------------------------------------------------------------
# Model-free open loop: EDF order, shedding, conservation, events cap
# ---------------------------------------------------------------------------

def _open_loop(arrivals, batch_slots, *, policy="edf", shed_at=None,
               accept_seed=0):
    """Drive Scheduler open-loop with a synthetic decode step on a
    virtual clock.  ``arrivals``: (budget, deadline_abs|None) per
    request, all submitted at t=0.  ``shed_at``: virtual times at which
    shed_pending fires.  Returns the scheduler."""
    reqs = [GenerationRequest(np.arange(4) % 7, max_new_tokens=b, seed=i)
            for i, (b, _) in enumerate(arrivals)]
    buf = max(r.prompt.size + r.max_new_tokens for r in reqs) + 4
    state = {
        "tokens": np.zeros((batch_slots, buf), np.int32),
        "length": np.zeros((batch_slots,), np.int32),
        "target": np.zeros((batch_slots,), np.int32),
        "stats": {"commits": np.zeros((batch_slots,), np.int32),
                  "row_steps": np.zeros((batch_slots,), np.int32)},
    }
    rng = np.random.default_rng(accept_seed)

    def admit(st, slot, i):
        r = reqs[i]
        st["tokens"][slot, : r.prompt.size] = r.prompt
        st["length"][slot] = r.prompt.size
        st["target"][slot] = r.prompt.size + r.max_new_tokens
        st["stats"]["commits"][slot] = 0
        st["stats"]["row_steps"][slot] = 0
        return st

    def step(st):
        for s in range(batch_slots):
            if st["length"][s] < st["target"][s]:
                n = min(int(rng.integers(1, 4)),
                        int(st["target"][s] - st["length"][s]))
                pos = int(st["length"][s])
                st["tokens"][s, pos: pos + n] = 1 + (s % 5)
                st["length"][s] += n
                st["stats"]["commits"][s] += n
                st["stats"]["row_steps"][s] += 1
        return st

    sched = Scheduler([], batch_slots, policy=policy)
    for r, (_, dl) in zip(reqs, arrivals):
        sched.submit(r, arrival_t=0.0, deadline=dl)
    t = 0.0
    shed_at = sorted(shed_at or [])
    while sched.busy:
        while shed_at and shed_at[0] <= t:
            sched.shed_pending(shed_at.pop(0))
        state, _ = sched.tick(state, admit=admit, step=step, clock=lambda: t)
        t += 1.0
        assert sched.steps < 10_000
    return sched


def test_edf_admission_order():
    """One slot, all arrivals at t=0: EDF admits by absolute deadline,
    deadline-free requests (inf) last, FIFO tiebreak."""
    sched = _open_loop([(2, 50.0), (2, 10.0), (2, None), (2, 30.0),
                        (2, 10.0)], batch_slots=1, policy="edf")
    order = [ev.request_index for ev in
             sorted(sched.events, key=lambda e: e.admit_step)]
    assert order == [1, 4, 3, 0, 2]
    assert sched.completed == sched.submitted


def test_fifo_ignores_deadlines():
    sched = _open_loop([(2, 50.0), (2, 10.0), (2, None), (2, 30.0)],
                       batch_slots=1, policy="fifo")
    order = [ev.request_index for ev in
             sorted(sched.events, key=lambda e: e.admit_step)]
    assert order == [0, 1, 2, 3]


def test_shed_pending_drops_only_queued_late_work():
    """Shedding drops queued requests whose deadline passed; a running
    request is never shed even past its own deadline; future-deadline
    requests survive; conservation holds."""
    # EDF through 1 slot: request 0 (earliest deadline, 12-token budget
    # -> >= 4 steps) is admitted at t=0 and still *running* at t=3
    sched = _open_loop([(12, 1.0), (2, 2.0), (2, 100.0)], batch_slots=1,
                       shed_at=[3.0])
    # request 0 running at t=3 (its passed deadline is irrelevant);
    # request 1's deadline 2.0 <= 3 while queued -> shed; request 2 served
    assert sched.shed_indices == [1]
    assert sorted(sched.results) == [0, 2]
    assert sched.completed + sched.shed == sched.submitted


def test_shed_slack_presheds():
    s0 = Scheduler([], 1)
    i = s0.submit(GenerationRequest(np.arange(4), 2), arrival_t=0.0,
                  deadline=10.0)
    assert s0.shed_pending(5.0) == []            # deadline not yet passed
    assert s0.shed_pending(5.0, slack=6.0) == [i]  # would miss anyway
    assert s0.shed == 1 and s0.submitted == 1 and not s0.busy


@given(
    mix=st.lists(
        st.tuples(st.integers(min_value=1, max_value=12),      # budget
                  st.integers(min_value=-5, max_value=40)),    # deadline
        min_size=1, max_size=16),
    batch_slots=st.integers(min_value=1, max_value=4),
    policy=st.sampled_from(["fifo", "edf"]),
    accept_seed=st.integers(min_value=0, max_value=2 ** 16),
)
@settings(max_examples=40, deadline=None)
def test_conservation_with_shedding_property(mix, batch_slots, policy,
                                             accept_seed):
    """Property: for ANY mix of budgets and deadlines (some already in
    the past), with shedding firing throughout the run, every request is
    either completed or shed — exactly once, never both."""
    arrivals = [(b, float(d)) for b, d in mix]
    sched = _open_loop(arrivals, batch_slots, policy=policy,
                       shed_at=[0.0, 2.0, 5.0, 9.0], accept_seed=accept_seed)
    assert sched.completed + sched.shed == sched.submitted
    assert set(sched.results) | set(sched.shed_indices) \
        == set(range(sched.submitted))
    assert set(sched.results) & set(sched.shed_indices) == set()
    # shed requests never held a slot
    served = {ev.request_index for ev in sched.events}
    assert served == set(sched.results)


def test_conservation_with_shedding_random_mixes():
    """Seeded fallback for the property above: always runs, even where
    hypothesis is unavailable (offline containers)."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        n = int(rng.integers(1, 16))
        arrivals = [(int(rng.integers(1, 12)), float(rng.integers(-5, 40)))
                    for _ in range(n)]
        sched = _open_loop(arrivals, int(rng.integers(1, 5)),
                           policy=("fifo", "edf")[trial % 2],
                           shed_at=[0.0, 2.0, 5.0, 9.0],
                           accept_seed=trial)
        assert sched.completed + sched.shed == sched.submitted
        assert set(sched.results) | set(sched.shed_indices) \
            == set(range(sched.submitted))
        assert set(sched.results) & set(sched.shed_indices) == set()


def test_events_cap_and_on_event_stream():
    """max_events bounds the retained audit trail (oldest dropped) while
    on_event still sees every completed occupancy."""
    seen = []
    reqs = [GenerationRequest(np.arange(4), 2, seed=i) for i in range(8)]
    buf = 4 + 2 + 4
    state = {
        "tokens": np.zeros((1, buf), np.int32),
        "length": np.zeros((1,), np.int32),
        "target": np.zeros((1,), np.int32),
        "stats": {"commits": np.zeros((1,), np.int32),
                  "row_steps": np.zeros((1,), np.int32)},
    }

    def admit(st, slot, i):
        st["length"][slot] = 4
        st["target"][slot] = 6
        return st

    def step(st):
        st["length"][0] = min(int(st["length"][0]) + 1,
                              int(st["target"][0]))
        st["stats"]["commits"][0] += 1
        st["stats"]["row_steps"][0] += 1
        return st

    sched = Scheduler(reqs, 1, max_events=3, on_event=seen.append)
    sched.run(state, admit=admit, step=step)
    assert len(sched.events) == 3                 # capped, oldest dropped
    assert [ev.request_index for ev in sched.events] == [5, 6, 7]
    assert [ev.request_index for ev in seen] == list(range(8))
    assert all(ev.harvest_step > ev.admit_step for ev in seen)


def test_events_uncapped_by_default():
    sched = _open_loop([(2, None)] * 6, batch_slots=2)
    assert len(sched.events) == 6


def test_scheduler_rejects_bad_policy():
    with pytest.raises(ValueError, match="policy"):
        Scheduler([], 1, policy="sjf")


# ---------------------------------------------------------------------------
# Engine-level streaming contract: every drafter x verifier, T=0 and T>0
# ---------------------------------------------------------------------------

def _assert_streaming_matches(eng, params, reqs, *, admission="fifo"):
    chunks = {i: [] for i in range(len(reqs))}
    results = eng.generate_requests(
        params, reqs, batch_slots=2, admission=admission,
        on_tokens=lambda i, toks: chunks[i].append(toks))
    for i, res in enumerate(results):
        streamed = np.concatenate(chunks[i])
        np.testing.assert_array_equal(streamed, res.tokens)
        assert streamed.size == reqs[i].max_new_tokens
    return results


@pytest.mark.parametrize("drafter,verifier", [
    ("ngram", "bf16"), ("ngram", "w8a8"),
    ("vanilla", "bf16"), ("vanilla", "w8a8"),
    ("pruned", "bf16"), ("pruned", "w8a8"),
    ("ngram-tree", "bf16"), ("ngram-tree", "w8a8"),
])
def test_streaming_concat_equals_result_T0(model, params, drafter, verifier):
    """The streaming contract: per-request on_tokens deltas concatenate
    bit-identically to the blocking RequestResult.tokens — for every
    registered drafter x verifier pair at T=0."""
    branches = (2, 1, 1) if drafter.endswith("-tree") else None
    scfg = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5,
                      tree_branches=branches)
    eng = SpecEngine(model, scfg, drafter=drafter, verifier=verifier)
    _assert_streaming_matches(eng, params, _requests(model.cfg))


@pytest.mark.parametrize("drafter,temperature", [
    ("ngram", 1.0), ("pruned", 0.7),
])
def test_streaming_concat_equals_result_sampling(model, params, drafter,
                                                 temperature):
    """Streaming must not perturb the per-request PRNG streams: the
    deltas still concatenate to the sampled blocking result at T>0."""
    scfg = SpecConfig(temperature=temperature, gamma=3, pruned_retention=0.5)
    eng = SpecEngine(model, scfg, drafter=drafter, verifier="bf16")
    _assert_streaming_matches(eng, params, _requests(model.cfg))


def test_edf_admission_never_changes_tokens(model, params):
    """EDF reorders admission only: with deadlines forcing a different
    admission order, every request's tokens stay bit-identical to the
    FIFO run (and the streaming contract holds under EDF too)."""
    scfg = SpecConfig(temperature=0.0, gamma=3)
    eng = SpecEngine(model, scfg, verifier="bf16")
    base = _requests(model.cfg)
    # reversed-urgency deadlines: EDF admits in reverse arrival order
    with_dl = [GenerationRequest(r.prompt, r.max_new_tokens, seed=r.seed,
                                 deadline_s=100.0 - 10.0 * i)
               for i, r in enumerate(base)]
    fifo = eng.generate_requests(params, base, batch_slots=2)
    edf = _assert_streaming_matches(eng, params, with_dl, admission="edf")
    for a, b in zip(fifo, edf):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    # and at T>0 the per-request seed streams carry the invariance
    eng_t = SpecEngine(model, SpecConfig(temperature=1.0, gamma=3),
                       verifier="bf16")
    fifo_t = eng_t.generate_requests(params, base, batch_slots=2)
    edf_t = eng_t.generate_requests(params, with_dl, batch_slots=2,
                                    admission="edf")
    for a, b in zip(fifo_t, edf_t):
        np.testing.assert_array_equal(a.tokens, b.tokens)


def test_generate_requests_rejects_bad_admission(model, params):
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                     verifier="bf16")
    with pytest.raises(ValueError, match="policy"):
        eng.generate_requests(params, _requests(model.cfg),
                              admission="lifo")


# ---------------------------------------------------------------------------
# Metrics
# ---------------------------------------------------------------------------

def test_timeline_ttft_and_itl():
    tl = RequestTimeline(rid=0, arrival_t=10.0, deadline_t=20.0)
    tl.emits = [(12.0, 1), (13.0, 2), (13.5, 1)]
    assert tl.ttft == pytest.approx(2.0)
    # the 2-token delta's 1.0s gap is split per token; then one 0.5s gap
    assert tl.itl == pytest.approx([0.5, 0.5, 0.5])
    tl.finish_t, tl.status = 13.5, "done"
    assert tl.deadline_hit is True
    tl.finish_t = 21.0
    assert tl.deadline_hit is False


def test_timeline_shed_counts_as_miss():
    tl = RequestTimeline(rid=0, arrival_t=0.0, deadline_t=5.0)
    tl.status = "shed"
    tl.finish_t = 1.0
    assert tl.deadline_hit is False
    assert RequestTimeline(rid=1, arrival_t=0.0).deadline_hit is None


def test_metrics_conservation_and_summary(tmp_path):
    m = ServerMetrics()
    m.on_submit(0, 0.0, deadline_t=10.0)
    m.on_submit(1, 0.5, deadline_t=1.0)
    m.on_admit(0, 1.0)
    m.on_tokens(0, 2.0, 3)
    m.on_step(2.0, 1, 4)
    m.on_finish(0, 3.0)
    with pytest.raises(AssertionError, match="conservation"):
        m.check_conservation()
    m.on_shed(1, 3.0)
    m.check_conservation()
    s = m.summary()
    assert s["counters"]["submitted"] == 2
    assert s["counters"]["completed"] == 1
    assert s["counters"]["shed"] == 1
    assert s["occupancy"]["mean"] == 1.0 and s["occupancy"]["slots"] == 4
    assert s["latency"]["ttft_s"]["n"] == 1
    assert s["latency"]["queue_s"]["p50"] == pytest.approx(1.0)
    assert s["deadlines"] == {"with_deadline": 2, "hits": 1,
                              "hit_rate": 0.5}
    # JSON round-trip (the schema documented in docs/decoding_api.md)
    path = m.save(str(tmp_path / "metrics.json"))
    assert json.load(open(path))["counters"]["submitted"] == 2


def test_metrics_without_timelines_keeps_aggregates():
    m = ServerMetrics(keep_timelines=False)
    for rid in range(3):
        m.on_submit(rid, 0.0, deadline_t=4.0)
        m.on_admit(rid, 1.0)
        m.on_tokens(rid, 2.0, 1)
        m.on_finish(rid, 3.0)
    assert m.timelines == {}
    s = m.summary(include_requests=True)
    assert "requests" not in s
    assert s["latency"]["ttft_s"]["n"] == 3
    assert s["deadlines"]["hit_rate"] == 1.0


# ---------------------------------------------------------------------------
# ServingLoop on a virtual clock (deterministic end-to-end)
# ---------------------------------------------------------------------------

def _loop_engine(model):
    return SpecEngine(model, SpecConfig(temperature=0.0, gamma=3),
                      drafter="ngram", verifier="bf16")


def _drive(loop, clock, step_cost=0.25, max_polls=2000):
    polls = 0
    while loop.busy:
        before = loop.total_steps
        loop.poll()
        clock[0] += (loop.total_steps - before) * step_cost
        polls += 1
        assert polls < max_polls
    return loop


def test_serving_loop_streams_and_conserves(model, params):
    """Virtual-clock ServingLoop: all requests served, per-handle deltas
    concatenate to the result tokens, conservation checked, and the
    tokens match the batch engine path bit-for-bit."""
    eng = _loop_engine(model)
    reqs = _requests(model.cfg)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=2, max_prompt_len=40,
                                    max_new_tokens=16, admission="edf"),
                       clock=lambda: clock[0])
    handles = [loop.submit(r) for r in reqs]
    _drive(loop, clock)
    loop.metrics.check_conservation()
    expected = eng.generate_requests(params, reqs, batch_slots=2)
    for h, res in zip(handles, expected):
        assert h.status == "done"
        got = h.result(timeout=0.0)
        np.testing.assert_array_equal(got.tokens, res.tokens)
        np.testing.assert_array_equal(h.collected(), got.tokens)
    s = loop.metrics.summary()
    assert s["counters"]["completed"] == len(reqs)
    assert s["counters"]["stream_tokens"] == sum(
        r.max_new_tokens for r in reqs)
    assert s["occupancy"]["max"] <= 2


def test_serving_loop_sheds_hopeless_deadline(model, params):
    """A queued request whose deadline passes before a slot frees is
    shed (handle resolves to None), on-time work still completes, and
    completed + shed == submitted."""
    eng = _loop_engine(model)
    cfg = model.cfg
    rng = np.random.default_rng(3)
    pat = rng.integers(0, cfg.vocab_size, 6)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=40,
                                    max_new_tokens=16, admission="edf",
                                    shed_late=True),
                       clock=lambda: clock[0])
    # slot-hogging request admitted first (one poll), THEN the
    # tight-deadline arrival queues behind it — its 0.5s budget expires
    # long before the 16-token occupant frees the slot
    h_long = loop.submit(GenerationRequest(np.tile(pat, 4), 16, seed=1))
    before = loop.total_steps
    loop.poll()
    clock[0] += (loop.total_steps - before) * 0.25
    h_tight = loop.submit(GenerationRequest(np.tile(pat, 4), 4, seed=2,
                                            deadline_s=0.5))
    _drive(loop, clock)          # 0.25 virtual s per step >> 0.5s deadline
    loop.metrics.check_conservation()
    assert h_long.status == "done" and h_long.result(0.0) is not None
    assert h_tight.status == "shed" and h_tight.result(0.0) is None
    assert h_tight.collected().size == 0
    c = loop.metrics.counters
    assert (c["submitted"], c["completed"], c["shed"]) == (2, 1, 1)
    assert loop.metrics.deadline_hit_rate == 0.0


def test_serving_loop_degrade_tree_to_chain_T0(model, params):
    """Under overload with degrade_on_overload, arrivals route to the
    chain-drafter lane; at T=0 every request's tokens stay bit-identical
    to the un-degraded tree engine (speculative decoding is lossless)."""
    scfg = SpecConfig(temperature=0.0, gamma=3, tree_branches=(2, 1, 1))
    eng = SpecEngine(model, scfg, drafter="ngram-tree", verifier="bf16")
    reqs = _requests(model.cfg, spec=((5, 6, 11), (4, 5, 22), (3, 4, 33),
                                      (2, 5, 44), (4, 4, 55), (3, 6, 66)))
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=40,
                                    max_new_tokens=8,
                                    degrade_on_overload=True,
                                    overload_factor=1.0),
                       clock=lambda: clock[0])
    handles = [loop.submit(r) for r in reqs]
    _drive(loop, clock)
    loop.metrics.check_conservation()
    assert loop.metrics.counters["degraded"] > 0   # overload actually hit
    expected = eng.generate_requests(params, reqs, batch_slots=1)
    for h, res in zip(handles, expected):
        np.testing.assert_array_equal(h.result(0.0).tokens, res.tokens)


def test_serving_loop_rejects_oversized_request(model, params):
    """An oversized request fails *alone* — terminal ``failed`` handle
    carrying the ValueError (re-raised by result()) — instead of
    raising into submit(); well-formed traffic sharing the loop is
    untouched and conservation counts the reject."""
    clock = [0.0]
    loop = ServingLoop(_loop_engine(model), params,
                       ServerConfig(batch_slots=1, max_prompt_len=8,
                                    max_new_tokens=4),
                       clock=lambda: clock[0])
    h_long = loop.submit(GenerationRequest(np.arange(12), 2))
    h_budget = loop.submit(GenerationRequest(np.arange(4), 8))
    h_ok = loop.submit(GenerationRequest(np.arange(4), 2))
    _drive(loop, clock)
    assert h_long.status == "failed" and h_budget.status == "failed"
    with pytest.raises(ValueError, match="max_prompt_len"):
        h_long.result(timeout=0.0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        h_budget.result(timeout=0.0)
    assert h_ok.result(timeout=0.0) is not None
    loop.metrics.check_conservation()
    c = loop.metrics.counters
    assert (c["submitted"], c["completed"], c["failed"]) == (3, 1, 2)
    assert loop.metrics.robustness["rejected"] == 2


def test_serving_loop_accepts_paged_layout(model, params):
    """Paged engines get a paged lane (PagedGroup admission/release
    wired through the scheduler hooks) instead of the PR 6 rejection;
    the full preemption/sharing behaviour is locked down in
    tests/test_prefix_sharing.py."""
    eng = SpecEngine(model, SpecConfig(temperature=0.0, gamma=3,
                                       kv_layout="paged", kv_block_size=8),
                     drafter="ngram", verifier="bf16")
    req = GenerationRequest(np.arange(8), 4, seed=1)
    clock = [0.0]
    loop = ServingLoop(eng, params,
                       ServerConfig(batch_slots=1, max_prompt_len=8,
                                    max_new_tokens=4),
                       clock=lambda: clock[0])
    h = loop.submit(req)
    _drive(loop, clock)
    lane = next(iter(loop._lanes.values()))
    assert lane.ctx is not None           # paged group, not contiguous
    assert lane.ctx.pool.unique_allocated == 0   # drained clean
    expected = eng.generate_requests(params, [req], batch_slots=1)
    np.testing.assert_array_equal(h.result(0.0).tokens,
                                  expected[0].tokens)


# ---------------------------------------------------------------------------
# Threaded StreamingServer end-to-end (real clock)
# ---------------------------------------------------------------------------

def test_streaming_server_end_to_end(model, params):
    """Background-thread server: concurrent submits, blocking per-token
    iteration from the caller thread, results bit-identical to the batch
    engine path."""
    eng = _loop_engine(model)
    reqs = _requests(model.cfg)
    expected = eng.generate_requests(params, reqs, batch_slots=2)
    cfg = ServerConfig(batch_slots=2, max_prompt_len=40, max_new_tokens=16,
                       admission="edf")
    with StreamingServer(eng, params, cfg) as srv:
        handles = [srv.submit(r) for r in reqs]
        for h, res in zip(handles, expected):
            streamed = np.concatenate(list(h.tokens()))
            got = h.result(timeout=120.0)
            np.testing.assert_array_equal(streamed, got.tokens)
            np.testing.assert_array_equal(got.tokens, res.tokens)
    srv.loop.metrics.check_conservation()
    assert srv.loop.metrics.counters["completed"] == len(reqs)


def test_streaming_server_requires_start(model, params):
    srv = StreamingServer(_loop_engine(model), params,
                          ServerConfig(batch_slots=1, max_prompt_len=40,
                                       max_new_tokens=4))
    with pytest.raises(RuntimeError, match="not started"):
        srv.submit(GenerationRequest(np.arange(4), 2))
