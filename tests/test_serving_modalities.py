"""Speculative serving for the modality archs (whisper enc-dec, VLM) and
the launch CLIs (subprocess smoke)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import SpecConfig
from repro.models import Model
from repro.serving.engine import SpecEngine

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}


@pytest.mark.parametrize("arch", ["whisper-small", "llama-3.2-vision-90b"])
def test_spec_serving_with_aux_embeds(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    n = cfg.num_image_tokens or cfg.num_audio_frames
    B = 2
    aux = jax.random.normal(jax.random.PRNGKey(7), (B, n, cfg.d_model), cfg.dtype)
    rng = np.random.default_rng(0)
    prompt = jnp.array(np.tile(rng.integers(0, cfg.vocab_size, 5), 4)
                       [None].repeat(B, 0).astype(np.int32))
    scfg = SpecConfig(gamma=3, temperature=0.0)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(params, prompt, 8, aux_embeds=aux)
    rs = SpecEngine(m, scfg, mode="spec").generate(params, prompt, 8, aux_embeds=aux)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, :P + 8] == rs.tokens[:, :P + 8]))
    assert rs.mean_accept_len >= 1.0


def test_serve_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "smollm-135m",
         "--reduced", "--verifier", "w8a8", "--gamma", "3",
         "--batch", "2", "--prompt-len", "24", "--new-tokens", "8"],
        capture_output=True, text=True, timeout=600, env=ENV, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "mean acceptance length" in out.stdout


def test_train_cli_smoke():
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "smollm-135m",
         "--reduced", "--steps", "3", "--batch", "2", "--seq-len", "32"],
        capture_output=True, text=True, timeout=600, env=ENV, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout
