"""Sharding-rule unit tests (mock mesh — no placeholder devices needed)."""
import types

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.roofline import collective_bytes
from repro.launch.sharding import _fit, _param_spec, _state_spec

MESH = types.SimpleNamespace(shape={"data": 16, "model": 16})
MESH3 = types.SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16})


def test_fit_divisibility():
    assert _fit(MESH, 64, "model") == "model"
    assert _fit(MESH, 9, "model") is None            # smollm heads
    assert _fit(MESH, 50280, "model") is None        # mamba2 vocab
    assert _fit(MESH3, 64, ("pod", "data")) == ("pod", "data")
    assert _fit(MESH3, 16, ("pod", "data")) is None  # 16 % 32


def test_param_rules_column_row():
    assert _param_spec("layers/0/attn/q/w", (4096, 4096), MESH, None) == P(None, "model")
    assert _param_spec("layers/0/attn/o/w", (4096, 4096), MESH, None) == P("model", None)
    assert _param_spec("layers/0/ffn/down/w", (13824, 5120), MESH, None) == P("model", None)
    # FSDP shards the other dim
    assert _param_spec("layers/0/attn/q/w", (4096, 4096), MESH, ("data",)) == P("data", "model")


def test_param_rules_fallback_replicates():
    # whisper vocab 51865 is indivisible → head out-dim replicated
    assert _param_spec("lm_head/w", (768, 51865), MESH, None) == P(None, None)
    # embed vocab-sharded when divisible
    assert _param_spec("embed/w", (32064, 4096), MESH, None) == P("model", None)
    assert _param_spec("embed/w", (50280, 1024), MESH, None) == P(None, "model")


def test_param_rules_experts():
    spec = _param_spec("layers/0/moe/up/w", (128, 7168, 4864), MESH, ("data",))
    assert spec == P("model", "data", None)
    # few experts → tensor-parallel inside experts
    spec = _param_spec("layers/0/moe/up/w", (4, 7168, 4864), MESH, None)
    assert spec == P(None, None, "model")
    assert _param_spec("layers/0/moe/up/w_scale", (128, 4864), MESH, None) == P("model", None)
    # router is column-parallel for sharding (it stays BF16 for *quantization*,
    # which is a separate concern)
    assert _param_spec("layers/0/moe/router/w", (7168, 128), MESH, None) == P(None, "model")
    assert _param_spec("layers/0/moe/router/w", (2048, 60), MESH, None) == P(None, None)


def test_param_rules_scan_layout():
    spec = _param_spec("scan/0/attn/q/w", (30, 576, 576), MESH, None)
    assert spec == P(None, None, "model")
    spec = _param_spec("scan/0/moe/up/w", (32, 16, 4096, 6400), MESH, None)
    assert spec == P(None, "model", None, None)


def test_state_rules():
    dp = ("data",)
    # KV cache: heads sharded when divisible
    assert _state_spec("cache/layers/0/k", (128, 33024, 32, 128), MESH, dp) == \
        P("data", None, "model", None)
    # GQA kv=8 < 16 → fall back to head_dim
    assert _state_spec("cache/layers/0/k", (128, 33024, 8, 160), MESH, dp) == \
        P("data", None, None, "model")
    # batch=1 (long_500k) replicated
    assert _state_spec("cache/layers/0/k", (1, 4224, 32, 128), MESH, dp) == \
        P(None, None, "model", None)
    # SSD state
    assert _state_spec("cache/layers/0/state", (128, 32, 64, 128), MESH, dp) == \
        P("data", "model", None, None)
    # stacked scan cache: leading layer dim replicated
    assert _state_spec("cache/scan/0/k", (30, 128, 33024, 32, 128), MESH, dp) == \
        P(None, "data", None, "model", None)
    # loop-layout shared cache (zamba2, 32 kv heads divisible) not stacked
    assert _state_spec("cache/shared/0/k", (128, 4224, 32, 80), MESH, dp) == \
        P("data", None, "model", None)
    assert _state_spec("tokens", (128, 33000), MESH, dp) == P("data", None)
    assert _state_spec("length", (128,), MESH, dp) == P("data")


def test_collective_bytes_parser():
    hlo = """
HloModule test

%region_1.2 (a: f32[8,8]) -> f32[8,8] {
  %ar = f32[8,8]{1,0} all-reduce(%a), channel_id=1, to_apply=%add
}

ENTRY %main (p: f32[16,16]) -> f32[16,16] {
  %w = f32[16,16]{1,0} while(%p), condition=%cond, body=%region_1.2
  %ag = f32[16,16]{1,0} all-gather(%w), channel_id=2, dimensions={0}
}
"""
    out = collective_bytes(hlo, loop_trips=10)
    assert out["all-reduce"] == 8 * 8 * 4 * 2.0 * 10   # ring factor × trips
    assert out["all-gather"] == 16 * 16 * 4


def test_full_sharding_tree_on_real_params():
    """param_shardings covers every leaf without error on a real tree."""
    from repro.launch.sharding import param_shardings, state_shardings
    from repro.models import Model

    from repro.launch.mesh import make_mesh
    cfg = get_config("zamba2-2.7b").reduced()
    m = Model(cfg)
    params = jax.eval_shape(m.init_params, jax.random.PRNGKey(0))
    mesh = make_mesh((1, 1), ("data", "model"))
    tree = param_shardings(params, mesh, fsdp=("data",))
    assert len(jax.tree.leaves(tree, is_leaf=lambda x: x is None)) > 0
    cache = jax.eval_shape(lambda: m.init_cache(2, 64, scan=True))
    st = state_shardings({"cache": cache}, mesh)
    assert jax.tree.structure(st) == jax.tree.structure({"cache": cache})
