"""End-to-end speculative decoding: the lossless guarantee at system level."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.models import Model
from repro.quant import quantize_params
from repro.serving.engine import SpecEngine


def _prompt(cfg, B=2, reps=5, seed=0):
    rng = np.random.default_rng(seed)
    pat = rng.integers(0, cfg.vocab_size, 6)
    return jnp.array(np.tile(pat, reps)[None, :].repeat(B, 0).astype(np.int32))


@pytest.mark.parametrize("arch", ["smollm-135m", "mamba2-370m", "zamba2-2.7b",
                                  "phi3.5-moe-42b-a6.6b"])
def test_spec_equals_vanilla_greedy(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(cfg)
    N = 12
    scfg = SpecConfig(temperature=0.0, gamma=4)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(params, prompt, N)
    rs = SpecEngine(m, scfg, mode="spec").generate(params, prompt, N)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, : P + N] == rs.tokens[:, : P + N]))
    assert rs.mean_accept_len >= 1.0
    assert rs.steps <= rv.steps


def test_quasar_w8a8_lossless_wrt_quantized_verifier():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    collect = {}
    m.forward(params, _prompt(cfg, B=1, seed=3)[:, :24], collect=collect)
    qparams = quantize_params(params, collect, QuantConfig())
    prompt = _prompt(cfg)
    N = 12
    scfg = SpecConfig(temperature=0.0, gamma=4)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(qparams, prompt, N)
    rq = SpecEngine(m, scfg, mode="spec").generate(qparams, prompt, N)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, : P + N] == rq.tokens[:, : P + N]))


def test_pruned_drafter_lossless():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(cfg)
    N = 10
    scfg = SpecConfig(temperature=0.0, gamma=3, pruned_retention=0.5)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(params, prompt, N)
    rp = SpecEngine(m, scfg, mode="pruned").generate(params, prompt, N)
    P = prompt.shape[1]
    assert bool(jnp.all(rv.tokens[:, : P + N] == rp.tokens[:, : P + N]))


def test_stochastic_spec_stats_sane():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    prompt = _prompt(cfg, B=4)
    scfg = SpecConfig(temperature=1.0, gamma=4)
    r = SpecEngine(m, scfg, mode="spec").generate(params, prompt, 10,
                                                  key=jax.random.PRNGKey(7))
    assert 1.0 <= r.mean_accept_len <= scfg.gamma + 1
    assert r.new_tokens >= 4 * 10
    toks = np.asarray(r.tokens)
    assert toks.min() >= 0 and toks.max() < cfg.vocab_size


def test_repetitive_prompt_gives_higher_L_than_random():
    """n-gram drafting exploits repetition — core PLD behaviour."""
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    scfg = SpecConfig(temperature=0.0, gamma=4)
    rep = SpecEngine(m, scfg, mode="spec").generate(params, _prompt(cfg), 12)
    rng = np.random.default_rng(1)
    rand_prompt = jnp.array(rng.integers(0, cfg.vocab_size, (2, 30)).astype(np.int32))
    rnd = SpecEngine(m, scfg, mode="spec").generate(params, rand_prompt, 12)
    assert rep.mean_accept_len >= rnd.mean_accept_len
