"""End-to-end system behaviour: train → calibrate → quantize → serve with
quantized verification (the full Quasar pipeline), plus a reduced-mesh
dry-run executed in a subprocess (the 512-device override must not leak
into this process)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.config import QuantConfig, SpecConfig
from repro.data import lm_batches, task_prompts
from repro.models import Model
from repro.quant import quantize_params
from repro.serving.engine import SpecEngine
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_quasar_pipeline():
    cfg = get_config("smollm-135m").reduced()
    m = Model(cfg)

    # 1) train briefly so logits have structure
    tr = Trainer(m, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30))
    params, opt = tr.init(jax.random.PRNGKey(0))
    params, _, _ = tr.fit(params, opt, lm_batches(8, 48, cfg.vocab_size),
                          steps=20, log_every=20, log_fn=None)

    # 2) calibrate + quantize (offline weight preparation, paper §3.3)
    collect = {}
    batch = next(lm_batches(4, 48, cfg.vocab_size, seed=1))
    m.forward(params, jnp.asarray(batch["tokens"]), collect=collect)
    qparams = quantize_params(params, collect, QuantConfig())

    # 3) fidelity: W8A8 keeps top-1 in high agreement (Table 4 proxy)
    toks = jnp.asarray(next(lm_batches(4, 48, cfg.vocab_size, seed=2))["tokens"])
    lf, _ = m.forward(params, toks)
    lq, _ = m.forward(qparams, toks)
    agree = float(jnp.mean((jnp.argmax(lf, -1) == jnp.argmax(lq, -1)).astype(jnp.float32)))
    assert agree > 0.9, agree

    # 4) serve: Quasar (spec + W8A8 verify) ≡ vanilla with the same verifier
    prompts = jnp.asarray(task_prompts("gsm8k", 2, 40, cfg.vocab_size))
    scfg = SpecConfig(temperature=0.0, gamma=4)
    rq = SpecEngine(m, scfg, mode="spec").generate(qparams, prompts, 12)
    rv = SpecEngine(m, scfg, mode="vanilla").generate(qparams, prompts, 12)
    P = prompts.shape[1]
    assert bool(jnp.all(rq.tokens[:, : P + 12] == rv.tokens[:, : P + 12]))
    assert rq.steps < rv.steps          # fewer verifier passes than tokens
    assert rq.mean_accept_len > 1.0


def test_dryrun_subprocess_reduced_mesh():
    """Real lower+compile of the speculative serve step on a 2×4 mesh of
    placeholder devices, in a subprocess (flag isolation)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, %r)
import jax
from repro.launch.dryrun import lower_combo
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
with mesh:
    row = lower_combo("smollm-135m", "decode_32k", mesh, "w8a8", gamma=5,
                      skip_loop_costs=True)
print("ROW" + json.dumps({k: row[k] for k in
    ("dominant", "coll_gbytes_per_chip", "temp_bytes_per_dev")}))
""" % (os.path.join(ROOT, "src"),)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("ROW")][0]
    row = json.loads(line[3:])
    assert row["coll_gbytes_per_chip"] > 0      # model-parallel collectives exist
    assert jax.device_count() == 1              # override did not leak here


def test_w8a8_verifier_halves_weight_bytes_in_hlo():
    """The paper's core claim, structurally: the verify step's weight
    streaming halves under W8A8 (int8 vs bf16 params in the compiled HLO
    argument buffers)."""
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(), dtype=jnp.bfloat16)
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    qparams = quantize_params(params, None, QuantConfig())

    def nbytes(t):
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t)
                   if hasattr(x, "dtype"))
    linb = lambda t: sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(t["layers"])
        if hasattr(x, "dtype") and x.ndim >= 2)
    assert linb(qparams) < 0.62 * linb(params)
