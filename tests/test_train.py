"""Trainer, optimizer, checkpointing, data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import lm_batches, synthetic_corpus, task_prompts
from repro.models import Model
from repro.train.checkpoint import load_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.train.trainer import Trainer


def test_loss_decreases():
    cfg = get_config("smollm-135m").reduced()
    tr = Trainer(Model(cfg), AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60))
    params, opt = tr.init(jax.random.PRNGKey(0))
    it = lm_batches(8, 64, cfg.vocab_size, seed=0)
    params, opt, hist = tr.fit(params, opt, it, steps=30, log_every=10, log_fn=None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.05


def test_grad_clip_bounds_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-6, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4, 4))}
    opt = adamw_init(params)
    grads = {"w": jnp.full((4, 4), 1e6)}
    p2, _, m = adamw_update(cfg, params, grads, opt)
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 1.5  # clipped step


def test_cosine_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(cosine_lr(cfg, jnp.int32(5))) == 0.5
    assert abs(float(cosine_lr(cfg, jnp.int32(10))) - 1.0) < 1e-6
    assert float(cosine_lr(cfg, jnp.int32(110))) < 1e-6


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("zamba2-2.7b").reduced()
    m = Model(cfg)
    params = m.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, {"params": params, "opt": opt, "step": 7})
    back = load_checkpoint(path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(back["step"]) == 7
    # structure preserved: layer list stays a list
    assert isinstance(back["params"]["layers"], list)
    assert len(back["params"]["layers"]) == cfg.num_layers


def test_synthetic_corpus_repetition_controls_ngram_hits():
    rng = np.random.default_rng(0)
    low = synthetic_corpus(rng, 2000, 64, repeat_prob=0.05)
    rng = np.random.default_rng(0)
    high = synthetic_corpus(rng, 2000, 64, repeat_prob=0.6)

    def hit_rate(seq, k=3):
        seen = set()
        hits = 0
        for i in range(len(seq) - k):
            t = tuple(seq[i : i + k])
            hits += t in seen
            seen.add(t)
        return hits / (len(seq) - k)

    assert hit_rate(high) > hit_rate(low) + 0.1


def test_task_prompts_shapes():
    p = task_prompts("gsm8k", 4, 128, 1000)
    assert p.shape == (4, 128) and p.dtype == np.int32
    assert p.min() >= 0 and p.max() < 1000
