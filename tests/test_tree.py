"""Token-tree speculation: template topology, degenerate-tree ↔ chain
bit-equality for every drafter × verifier, tree-masked flash_decode vs the
pure-jnp oracle, and the acceptance-length win over the γ-chain on the
ambiguous-repetition workload under the W8A8 verifier."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.configs import get_config
from repro.core import (
    ChainTreeAdapter,
    NgramTreeDrafter,
    SpecConfig,
    TreeTemplate,
    get_drafter,
)
from repro.core.drafting import draft_tokens, draft_tree_tokens
from repro.data import ambiguous_prompts, lm_batches
from repro.kernels.flash_decode import flash_decode
from repro.models import Model
from repro.models.attention import attend
from repro.serving import GenerationRequest, SpecEngine

BRANCH_CHOICES = [(1, 1, 1), (2, 2), (3, 1), (2, 1, 2), (4,)]


@pytest.fixture(scope="module")
def model():
    return Model(get_config("smollm-135m").reduced())


@pytest.fixture(scope="module")
def params(model):
    return model.init_params(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Template topology
# ---------------------------------------------------------------------------

def test_template_chain_is_degenerate():
    tpl = TreeTemplate.chain(4)
    assert tpl.is_chain and tpl.num_nodes == 5 and tpl.gamma == 4
    np.testing.assert_array_equal(tpl.parents, [-1, 0, 1, 2, 3])
    np.testing.assert_array_equal(tpl.depths, [0, 1, 2, 3, 4])
    np.testing.assert_array_equal(tpl.mask, np.tril(np.ones((5, 5), bool)))
    np.testing.assert_array_equal(tpl.paths, [[0, 1, 2, 3, 4]])
    assert TreeTemplate.chain(0).num_nodes == 1


def test_template_wide_topology():
    tpl = TreeTemplate((2, 2))
    # BFS packing: root, level 1 = {1, 2}, level 2 = {3, 4} ∪ {5, 6}
    assert tpl.num_nodes == 7 and tpl.num_leaves == 4 and not tpl.is_chain
    np.testing.assert_array_equal(tpl.parents, [-1, 0, 0, 1, 1, 2, 2])
    np.testing.assert_array_equal(tpl.depths, [0, 1, 1, 2, 2, 2, 2])
    np.testing.assert_array_equal(
        tpl.children, [[1, 2], [3, 4], [5, 6],
                       [-1, -1], [-1, -1], [-1, -1], [-1, -1]])
    np.testing.assert_array_equal(
        tpl.paths, [[0, 1, 3], [0, 1, 4], [0, 2, 5], [0, 2, 6]])
    # ancestor-or-self: node 5's path is {0, 2, 5}; siblings masked out
    assert list(np.where(tpl.mask[5])[0]) == [0, 2, 5]
    # representative leaf = smallest leaf ordinal under the node
    np.testing.assert_array_equal(tpl.src_leaf, [0, 0, 2, 0, 1, 2, 3])


@given(branches=st.lists(st.integers(1, 3), min_size=1, max_size=4))
@settings(max_examples=25, deadline=None)
def test_template_invariants(branches):
    tpl = TreeTemplate(tuple(branches))
    N = tpl.num_nodes
    # every non-root node: parent is earlier in packed order, one level up
    for i in range(1, N):
        p = tpl.parents[i]
        assert 0 <= p < i and tpl.depths[i] == tpl.depths[p] + 1
        # mask rows are inherited: ancestors(i) = ancestors(p) ∪ {i}
        expect = tpl.mask[p].copy()
        expect[i] = True
        np.testing.assert_array_equal(tpl.mask[i], expect)
    assert tpl.num_leaves == int(np.prod(branches))
    # paths are root-to-leaf chains through `parents`
    for path in tpl.paths:
        assert path[0] == 0
        for a, b in zip(path, path[1:]):
            assert tpl.parents[b] == a


def test_template_validation():
    with pytest.raises(ValueError, match="branch factors"):
        TreeTemplate((2, 0))
    with pytest.raises(ValueError, match="gamma"):
        TreeTemplate.chain(-1)
    with pytest.raises(ValueError, match="too wide"):
        TreeTemplate((5, 5, 5))


# ---------------------------------------------------------------------------
# Tree drafting
# ---------------------------------------------------------------------------

def test_chain_template_drafts_match_chain_drafter():
    """draft_tree_tokens over the degenerate template is bit-identical to
    the chain prompt-lookup drafter."""
    rng = np.random.default_rng(0)
    pat = rng.integers(0, 50, 7)
    tokens = jnp.asarray(np.tile(pat, 6)[None].repeat(3, 0).astype(np.int32))
    length = jnp.array([42, 30, 17], jnp.int32)
    tpl = TreeTemplate.chain(5)
    chain = draft_tokens(tokens, length, gamma=5)
    tree = draft_tree_tokens(tokens, length, tpl)
    np.testing.assert_array_equal(np.asarray(chain), np.asarray(tree))


def test_tree_drafts_diversify_siblings():
    """Root children must cover *distinct* continuations when the trailing
    gram has divergent matches (most recent first = the chain draft)."""
    # "a b X ... a b Y ... a b" — matches continue with X (old), Y (recent)
    a, b, X, Y = 1, 2, 7, 9
    row = [a, b, X, 3, 4, 5, a, b, Y, 6, 8, 10, a, b]
    tokens = jnp.asarray(np.asarray(row, np.int32)[None])
    length = jnp.full((1,), len(row), jnp.int32)
    tpl = TreeTemplate((2, 1))
    drafts = np.asarray(draft_tree_tokens(tokens, length, tpl))[0]
    root_children_tokens = {drafts[tpl.children[0, 0] - 1],
                           drafts[tpl.children[0, 1] - 1]}
    assert root_children_tokens == {X, Y}
    # child 0 carries the chain (most recent match) proposal
    assert drafts[tpl.children[0, 0] - 1] == Y


# ---------------------------------------------------------------------------
# (a) Degenerate single-path tree ↔ chain bit-equality
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("drafter", ["ngram", "vanilla", "pruned"])
@pytest.mark.parametrize("verifier", ["bf16", "w8a8"])
def test_degenerate_tree_bit_equals_chain(model, params, drafter, verifier):
    """The chain decode path is exactly the single-branch tree: running any
    registered chain drafter through the tree route (depth positions,
    ancestor mask, path commit, tree rejection sampling) must reproduce
    the chain route bit-for-bit — at T=0 and T>0, on the same per-request
    seed streams."""
    scfg = SpecConfig(gamma=3, temperature=0.0, pruned_retention=0.5)
    rng = np.random.default_rng(11)
    pat = rng.integers(0, model.cfg.vocab_size, 6)
    requests = [
        GenerationRequest(np.tile(pat, 4), max_new_tokens=8, seed=5),
        GenerationRequest(np.tile(pat, 5), max_new_tokens=11, seed=6,
                          temperature=1.0),
        GenerationRequest(np.tile(pat, 3), max_new_tokens=6, seed=7,
                          temperature=1.0),
    ]
    chain_eng = SpecEngine(model, scfg, drafter=drafter, verifier=verifier)
    tree_eng = SpecEngine(
        model, scfg, drafter=ChainTreeAdapter(get_drafter(drafter, scfg)),
        verifier=verifier)
    r_chain = chain_eng.generate_requests(params, requests, batch_slots=2)
    r_tree = tree_eng.generate_requests(params, requests, batch_slots=2)
    for rc, rt in zip(r_chain, r_tree):
        np.testing.assert_array_equal(rc.tokens, rt.tokens)
        assert rc.steps == rt.steps and rc.accept_len == rt.accept_len


def test_ngram_tree_chain_template_bit_equals_ngram(model, params):
    """The registered tree drafter with the default (chain) template is
    bit-identical to the chain ngram drafter end to end."""
    scfg = SpecConfig(gamma=4, temperature=0.0)
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(
        np.tile(rng.integers(0, model.cfg.vocab_size, 6), 5)[None]
        .repeat(2, 0).astype(np.int32))
    P = prompt.shape[1]
    a = SpecEngine(model, scfg, drafter="ngram", verifier="bf16").generate(
        params, prompt, 12)
    b = SpecEngine(model, scfg, drafter="ngram-tree",
                   verifier="bf16").generate(params, prompt, 12)
    assert bool(jnp.all(a.tokens[:, : P + 12] == b.tokens[:, : P + 12]))
    assert a.steps == b.steps


def test_wide_tree_lossless_greedy(model, params):
    """Whatever the template proposes, T=0 verification commits exactly
    the autoregressive stream (losslessness is topology-independent)."""
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(
        np.tile(rng.integers(0, model.cfg.vocab_size, 6), 5)[None]
        .repeat(2, 0).astype(np.int32))
    P = prompt.shape[1]
    van = SpecEngine(model, SpecConfig(gamma=0, temperature=0.0),
                     drafter="vanilla", verifier="bf16").generate(
        params, prompt, 12)
    for branches in [(2, 2), (3, 1, 2)]:
        scfg = SpecConfig(temperature=0.0, tree_branches=branches)
        tree = SpecEngine(model, scfg, drafter="ngram-tree",
                          verifier="bf16").generate(params, prompt, 12)
        assert bool(jnp.all(
            van.tokens[:, : P + 12] == tree.tokens[:, : P + 12])), branches


def test_tree_gating_recurrent_and_windowed():
    """Recurrent caches and ring buffers cannot hold a tree window."""
    ssm = Model(get_config("mamba2-370m").reduced())
    with pytest.raises(ValueError, match="recurrent"):
        SpecEngine(ssm, SpecConfig(tree_branches=(2, 1)),
                   drafter="ngram-tree", verifier="bf16")
    import dataclasses
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              sliding_window=64)
    with pytest.raises(ValueError, match="contiguous"):
        SpecEngine(Model(cfg), SpecConfig(tree_branches=(2, 1)),
                   drafter="ngram-tree", verifier="bf16")


# ---------------------------------------------------------------------------
# (b) Tree-masked flash_decode vs the pure-jnp oracle
# ---------------------------------------------------------------------------

def _tree_mask_oracle(tpl, start, B, T, S):
    """Brute-force validity: committed context ∪ ancestor-or-self."""
    mask = np.zeros((B, T, S), bool)
    for bb in range(B):
        for t in range(T):
            for s in range(S):
                if s < start[bb]:
                    mask[bb, t, s] = True
                elif s < start[bb] + T:
                    mask[bb, t, s] = tpl.mask[t, s - start[bb]]
    return mask


def test_attend_tree_mask_matches_bruteforce():
    """The attend() oracle's tree override against an O(B·T·S) loop."""
    tpl = TreeTemplate((2, 2))
    B, S, H, dh = 2, 24, 2, 8
    T = tpl.num_nodes
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, T, H, dh))
    k = jax.random.normal(kk, (B, S, H, dh))
    v = jax.random.normal(kv, (B, S, H, dh))
    start = np.array([3, 10])
    qpos = jnp.asarray(start)[:, None] + tpl.depths_dev[None, :]
    o = attend(q, k, v, qpos, jnp.arange(S, dtype=jnp.int32),
               tree_mask=tpl.mask_dev, win_start=jnp.asarray(start),
               impl="jnp")  # this test validates the jnp oracle itself
    mask = _tree_mask_oracle(tpl, start, B, T, S)
    qn, kn, vn = map(np.asarray, (q, k, v))
    for bb in range(B):
        for h in range(H):
            s = qn[bb, :, h] @ kn[bb, :, h].T * dh ** -0.5
            s = np.where(mask[bb], s, -1e30)
            p = np.exp(s - s.max(-1, keepdims=True))
            p = np.where(mask[bb], p, 0.0)
            p /= p.sum(-1, keepdims=True)
            np.testing.assert_allclose(np.asarray(o)[bb, :, h],
                                       p @ vn[bb, :, h],
                                       rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(
    tidx=st.integers(0, len(BRANCH_CHOICES) - 1),
    b=st.integers(1, 2),
    s=st.integers(16, 96),
    hkv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2 ** 31 - 1),
)
def test_flash_decode_tree_matches_attend(tidx, b, s, hkv, g, dh, seed):
    """Tree-masked flash_decode ≡ the attend() oracle, in interpret mode,
    across template shapes, GQA group sizes and window placements."""
    tpl = TreeTemplate(BRANCH_CHOICES[tidx])
    t = tpl.num_nodes
    s = max(s, t + 2)
    key = jax.random.PRNGKey(seed)
    kq, kk, kv, kp = jax.random.split(key, 4)
    hq = hkv * g
    q = jax.random.normal(kq, (b, t, hq, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + tpl.depths_dev[None, :]
    o_flash = flash_decode(q, k, v, qpos, tree_mask=tpl.mask_dev,
                           win_start=start, block_s=32, interpret=True)
    # impl="jnp" pins the oracle: under REPRO_USE_PALLAS=1 (CI parity
    # step) auto mode would dispatch the oracle to the kernel itself
    o_ref = attend(q, k, v, qpos, jnp.arange(s, dtype=jnp.int32),
                   tree_mask=tpl.mask_dev, win_start=start, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("branches", BRANCH_CHOICES)
def test_flash_decode_tree_template_sweep(branches):
    """Deterministic template sweep (runs with or without hypothesis):
    tree-masked flash_decode ≡ attend() in interpret mode, including a
    cache length that is not a multiple of the block size."""
    tpl = TreeTemplate(branches)
    t = tpl.num_nodes
    b, s, hkv, g, dh = 2, 50, 2, 2, 8
    key = jax.random.PRNGKey(hash(branches) % 2 ** 31)
    kq, kk, kv, kp = jax.random.split(key, 4)
    q = jax.random.normal(kq, (b, t, hkv * g, dh))
    k = jax.random.normal(kk, (b, s, hkv, dh))
    v = jax.random.normal(kv, (b, s, hkv, dh))
    start = jax.random.randint(kp, (b,), 0, s - t + 1)
    qpos = start[:, None] + tpl.depths_dev[None, :]
    o_flash = flash_decode(q, k, v, qpos, tree_mask=tpl.mask_dev,
                           win_start=start, block_s=16, interpret=True)
    o_ref = attend(q, k, v, qpos, jnp.arange(s, dtype=jnp.int32),
                   tree_mask=tpl.mask_dev, win_start=start, impl="jnp")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_chain_unchanged():
    """tree_mask=None keeps the original kernel path bit-compatible."""
    key = jax.random.PRNGKey(5)
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (2, 4, 4, 16))
    k = jax.random.normal(kk, (2, 64, 2, 16))
    v = jax.random.normal(kv, (2, 64, 2, 16))
    qpos = jnp.tile(jnp.arange(30, 34)[None], (2, 1))
    o = flash_decode(q, k, v, qpos, block_s=32, interpret=True)
    o_ref = attend(q, k, v, qpos, jnp.arange(64, dtype=jnp.int32),
                   impl="jnp")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# (c) Wider-than-chain template beats the γ-chain (W8A8 verifier)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def trained(model):
    """Briefly trained stand-in (Markov corpus) so greedy continuations
    follow the successor table the ambiguous workload is built from."""
    from repro.train.optimizer import AdamWConfig
    from repro.train.trainer import Trainer
    tr = Trainer(model, AdamWConfig(lr=1.5e-3, warmup_steps=20,
                                    total_steps=250))
    params, opt = tr.init(jax.random.PRNGKey(0))
    params, _, _ = tr.fit(
        params, opt,
        lm_batches(8, 96, model.cfg.vocab_size, seed=0, markov_alpha=0.97),
        steps=250, log_every=250, log_fn=None)
    return params


def test_wide_tree_beats_chain_acceptance(model, trained):
    """On the ambiguous-repetition workload (older matches carry the
    model-likely continuations, the most recent match carries junk) a
    wider-than-chain template must achieve *strictly* higher mean
    acceptance length than the γ-chain of the same depth, under the W8A8
    verifier — and both must commit the identical (lossless) stream."""
    V = model.cfg.vocab_size
    prompts = jnp.asarray(ambiguous_prompts(6, 64, V, depth=4, seed=0))
    P = prompts.shape[1]
    chain_scfg = SpecConfig(gamma=4, temperature=0.0, verifier="w8a8")
    tree_scfg = SpecConfig(temperature=0.0, verifier="w8a8",
                           tree_branches=(3, 2, 1, 1))
    r_chain = SpecEngine(model, chain_scfg, drafter="ngram").generate(
        trained, prompts, 10)
    r_tree = SpecEngine(model, tree_scfg, drafter="ngram-tree").generate(
        trained, prompts, 10)
    assert bool(jnp.all(
        r_chain.tokens[:, : P + 10] == r_tree.tokens[:, : P + 10]))
    assert r_tree.mean_accept_len > r_chain.mean_accept_len, (
        r_tree.mean_accept_len, r_chain.mean_accept_len)


def test_tree_drafter_through_scheduler(model, trained):
    """Tree drafting composes with continuous batching: scheduled serving
    through recycled slots stays bit-identical to solo runs."""
    scfg = SpecConfig(temperature=0.0, tree_branches=(2, 2, 1))
    eng = SpecEngine(model, scfg, drafter="ngram-tree", verifier="bf16")
    rng = np.random.default_rng(7)
    pat = rng.integers(0, model.cfg.vocab_size, 6)
    reqs = [GenerationRequest(np.tile(pat, 4), max_new_tokens=7, seed=1),
            GenerationRequest(np.tile(pat, 5), max_new_tokens=5, seed=2),
            GenerationRequest(np.tile(pat, 3), max_new_tokens=9, seed=3)]
    results = eng.generate_requests(trained, reqs, batch_slots=1)
    for req, res in zip(reqs, results):
        solo = eng.generate_requests(trained, [req], batch_slots=1)[0]
        np.testing.assert_array_equal(res.tokens, solo.tokens)
