"""Rejection-sampling verification: correctness + the lossless guarantee."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis optional

from repro.core.verification import verify


def test_greedy_prefix_acceptance():
    """T=0: accept exactly the longest prefix of drafts matching argmax."""
    V = 11
    logits = jnp.full((1, 4, V), -10.0)
    # target argmaxes: 3, 7, 2 (then bonus position predicts 5)
    for i, t in enumerate([3, 7, 2, 5]):
        logits = logits.at[0, i, t].set(10.0)
    drafts = jnp.array([[3, 7, 9]])      # third draft wrong
    res = verify(logits, drafts, 0.0, jax.random.PRNGKey(0))
    assert int(res.n_accept[0]) == 2
    assert int(res.next_token[0]) == 2   # corrective = argmax at rejected pos
    assert int(res.n_commit[0]) == 3

    drafts_ok = jnp.array([[3, 7, 2]])
    res2 = verify(logits, drafts_ok, 0.0, jax.random.PRNGKey(0))
    assert int(res2.n_accept[0]) == 3
    assert int(res2.next_token[0]) == 5  # bonus from position γ


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_greedy_never_commits_nonargmax(seed):
    key = jax.random.PRNGKey(seed)
    k0, k1, k2 = jax.random.split(key, 3)
    B, g, V = 4, 5, 23
    logits = jax.random.normal(k0, (B, g + 1, V))
    drafts = jax.random.randint(k1, (B, g), 0, V)
    res = verify(logits, drafts, 0.0, k2)
    am = np.asarray(jnp.argmax(logits, -1))
    n = np.asarray(res.n_accept)
    d = np.asarray(drafts)
    for b in range(B):
        for i in range(n[b]):
            assert d[b, i] == am[b, i]          # accepted ⇒ argmax
        assert np.asarray(res.next_token)[b] == am[b, n[b]]


def test_stochastic_output_distribution_matches_target():
    """Monte-Carlo check of losslessness (Eq. 2-3): the first committed
    token's distribution equals the verifier's p, for a one-hot drafter."""
    V, N, T = 5, 40000, 1.0
    logits = jnp.log(jnp.array([[0.45, 0.25, 0.15, 0.10, 0.05]]))
    logits = jnp.repeat(logits[None], 1, 0)          # (1,1,V) -> window γ=0+1?
    # build a γ=1 window: position 0 verifies draft, position 1 is bonus
    logits2 = jnp.concatenate([logits, logits], axis=1)  # (1, 2, V)
    drafts = jnp.array([[2]])                        # drafter always proposes 2

    counts = np.zeros(V)
    keys = jax.random.split(jax.random.PRNGKey(0), N)

    @jax.jit
    def one(key):
        res = verify(logits2, drafts, T, key)
        # first committed token: draft if accepted else corrective
        return jnp.where(res.n_accept[0] >= 1, drafts[0, 0], res.next_token[0])

    toks = np.asarray(jax.vmap(one)(keys))
    for t in toks:
        counts[t] += 1
    emp = counts / N
    target = np.asarray(jax.nn.softmax(logits2[0, 0] / T))
    np.testing.assert_allclose(emp, target, atol=0.012)


def test_stochastic_with_draft_probs_lossless():
    """Same Monte-Carlo, stochastic drafter q ≠ one-hot (pruned baseline)."""
    V, N, T = 4, 40000, 1.0
    p_logits = jnp.log(jnp.array([[[0.5, 0.2, 0.2, 0.1],
                                   [0.25, 0.25, 0.25, 0.25]]]))  # (1,2,V)
    q = jnp.array([[[0.1, 0.6, 0.2, 0.1]]])                      # (1,1,V)

    keys = jax.random.split(jax.random.PRNGKey(1), N)

    @jax.jit
    def one(key):
        kd, kv = jax.random.split(key)
        d = jax.random.categorical(kd, jnp.log(q[0, 0]))[None, None]
        res = verify(p_logits, d, T, kv, draft_probs=q)
        return jnp.where(res.n_accept[0] >= 1, d[0, 0], res.next_token[0])

    toks = np.asarray(jax.vmap(one)(keys))
    counts = np.bincount(toks, minlength=V) / N
    target = np.asarray(jax.nn.softmax(p_logits[0, 0] / T))
    np.testing.assert_allclose(counts, target, atol=0.012)


def test_acceptance_improves_with_alignment():
    """Drafts aligned with p get longer acceptance than random drafts."""
    B, g, V = 64, 5, 50
    key = jax.random.PRNGKey(3)
    k0, k1, k2 = jax.random.split(key, 3)
    # scale 5: peaky enough that argmax-aligned drafts clear the +1 margin
    # (at 3.0 the mean gap is only ~0.9 — this test never ran in the seed,
    # its module errored at collection on the hypothesis import)
    logits = jax.random.normal(k0, (B, g + 1, V)) * 5.0
    aligned = jnp.argmax(logits[:, :g], -1)
    random_d = jax.random.randint(k1, (B, g), 0, V)
    r_al = verify(logits, aligned, 1.0, k2)
    r_rn = verify(logits, random_d, 1.0, k2)
    assert float(jnp.mean(r_al.n_accept)) > float(jnp.mean(r_rn.n_accept)) + 1.0
