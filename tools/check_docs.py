#!/usr/bin/env python
"""Docs link/path checker — the ``docs-check`` CI gate.

Verifies, for ``README.md`` and every ``docs/*.md``:

1. every **relative markdown link** ``[text](target)`` resolves to an
   existing file (anchors stripped; http(s)/mailto links skipped);
2. every **inline-code file reference** that looks like a repo path
   (``src/repro/core/tree.py``, ``benchmarks/run.py``, …) resolves —
   either verbatim from the repo root, relative to the doc's directory,
   or under the conventional prefixes (``src/repro/``, ``tests/``,
   ``docs/``) that prose tends to elide.  Tokens with globs/braces or
   dotted module paths are out of scope.

Run from anywhere: ``python tools/check_docs.py``.  Exit code 1 with a
per-file report when anything dangles, so docs cannot rot silently.
"""
from __future__ import annotations

import glob
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
INLINE_CODE = re.compile(r"`([^`\n]+)`")
# a path-looking token: has a separator, sane chars, known text suffix
PATHY = re.compile(r"^[\w./-]+/[\w./-]+\.(py|md|json|yml|yaml|toml)$")
# prefixes docs conventionally elide ("models/attention.py" etc.)
PREFIXES = ("", "src/repro/", "src/", "tests/", "docs/", "benchmarks/")


def _resolve(target: str, base_dir: str, prefixes=("",)) -> bool:
    if any(c in target for c in "*{}<>$"):
        return True                          # glob / template — not a path
    cands = [os.path.join(base_dir, target)]
    cands += [os.path.join(ROOT, p, target) for p in prefixes]
    return any(os.path.exists(c) for c in cands)


def check_file(path: str) -> list:
    base_dir = os.path.dirname(os.path.abspath(path))
    text = open(path, encoding="utf-8").read()
    # fenced code blocks hold shell lines, not doc links — drop them
    prose = re.sub(r"```.*?```", "", text, flags=re.S)
    errors = []
    for m in MD_LINK.finditer(prose):
        target = m.group(1).split("#")[0]
        if not target or target.startswith(("http://", "https://",
                                            "mailto:")):
            continue
        # links must resolve where a renderer would look: relative to the
        # doc itself (or the repo root) — no prose-prefix leniency here
        if not _resolve(target, base_dir):
            errors.append(f"broken link: ({m.group(1)})")
    for m in INLINE_CODE.finditer(prose):
        parts = m.group(0).strip("`").split()      # `path --flags` → path
        if not parts or not PATHY.match(parts[0]):
            continue
        if not _resolve(parts[0], base_dir, prefixes=PREFIXES):
            errors.append(f"dangling path reference: `{parts[0]}`")
    return errors


def main() -> int:
    files = [os.path.join(ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(ROOT, "docs", "*.md")))
    failed = False
    for path in files:
        errs = check_file(path)
        rel = os.path.relpath(path, ROOT)
        if errs:
            failed = True
            print(f"FAIL {rel}")
            for e in errs:
                print(f"  - {e}")
        else:
            print(f"ok   {rel}")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
