#!/usr/bin/env python
"""Chrome trace-event JSON validator — the ``trace-check`` CI gate.

Validates a trace produced by ``repro.serving.trace.Tracer`` (or any
Chrome trace-event / Perfetto JSON) structurally, so a malformed export
fails CI instead of silently rendering wrong in the viewer:

1. top level is ``{"traceEvents": [...]}`` (or a bare event list);
2. every event has ``name``/``ph``, and non-metadata events carry
   numeric ``ts`` plus ``pid``/``tid``;
3. duration events nest properly per ``(pid, tid)`` track: every ``E``
   closes the innermost open ``B`` of the same name, nothing stays open
   at EOF, and span ends never precede their begins;
4. timestamps are non-decreasing per track in file order (Tracer emits
   in clock order; a violation means a broken clock injection);
5. async events balance per ``(cat, id, name)`` — no ``e`` without an
   open ``b``, nothing left open at EOF;
6. counter events (``C``) carry an ``args`` dict of finite numbers.

Run: ``python tools/check_trace.py TRACE.json [...]``.  Exit code 1
with a per-event report when anything is malformed.  Importable:
``validate(trace_dict) -> list[str]`` returns the error report.
"""
from __future__ import annotations

import json
import math
import sys
from typing import Any, Dict, List

# phases that carry no timestamp/track requirements
_META = {"M"}
_KNOWN = {"B", "E", "b", "e", "i", "C", "M", "X"}


def validate(trace: Any) -> List[str]:
    """Validate a parsed trace; returns a list of error strings."""
    errors: List[str] = []
    if isinstance(trace, dict):
        events = trace.get("traceEvents")
        if not isinstance(events, list):
            return ["top-level dict has no 'traceEvents' list"]
    elif isinstance(trace, list):
        events = trace
    else:
        return [f"trace must be a dict or list, got {type(trace).__name__}"]

    open_spans: Dict[tuple, List[dict]] = {}    # (pid,tid) -> B stack
    last_ts: Dict[tuple, float] = {}            # (pid,tid) -> last ts seen
    async_depth: Dict[tuple, int] = {}          # (cat,id,name) -> depth

    for n, ev in enumerate(events):
        where = f"event {n}"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        name, ph = ev.get("name"), ev.get("ph")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty 'name'")
            continue
        where = f"event {n} ({ph} {name!r})"
        if ph not in _KNOWN:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if ph in _META:
            continue

        ts, pid, tid = ev.get("ts"), ev.get("pid"), ev.get("tid")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errors.append(f"{where}: non-numeric ts {ts!r}")
            continue
        if pid is None or tid is None:
            errors.append(f"{where}: missing pid/tid")
            continue
        track = (pid, tid)
        if ts < last_ts.get(track, float("-inf")):
            errors.append(f"{where}: ts {ts} decreases on track {track} "
                          f"(last {last_ts[track]})")
        last_ts[track] = max(last_ts.get(track, float("-inf")), ts)

        if ph == "B":
            open_spans.setdefault(track, []).append(ev)
        elif ph == "E":
            stack = open_spans.get(track) or []
            if not stack:
                errors.append(f"{where}: E with no open B on track {track}")
            else:
                b = stack.pop()
                if b["name"] != name:
                    errors.append(
                        f"{where}: E closes B {b['name']!r} (bad nesting)")
                if ts < b["ts"]:
                    errors.append(f"{where}: span ends before it begins")
        elif ph in ("b", "e"):
            key = (ev.get("cat", ""), ev.get("id"), name)
            if ev.get("id") is None:
                errors.append(f"{where}: async event missing 'id'")
                continue
            d = async_depth.get(key, 0) + (1 if ph == "b" else -1)
            if d < 0:
                errors.append(f"{where}: async end with no open begin "
                              f"for {key}")
                d = 0
            async_depth[key] = d
        elif ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) and math.isfinite(v)
                    for v in args.values()):
                errors.append(f"{where}: counter args must be a non-empty "
                              f"dict of finite numbers, got {args!r}")

    for track, stack in open_spans.items():
        for b in stack:
            errors.append(f"unclosed B {b['name']!r} on track {track}")
    for key, d in async_depth.items():
        if d != 0:
            errors.append(f"unbalanced async span {key}: depth {d} at EOF")
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: check_trace.py TRACE.json [...]", file=sys.stderr)
        return 2
    bad = 0
    for path in argv:
        try:
            with open(path) as f:
                trace = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}")
            bad += 1
            continue
        errors = validate(trace)
        n = (len(trace.get("traceEvents", []))
             if isinstance(trace, dict) else len(trace))
        if errors:
            print(f"{path}: {len(errors)} problem(s) in {n} events")
            for e in errors[:40]:
                print(f"  - {e}")
            bad += 1
        else:
            print(f"{path}: OK ({n} events)")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
